"""Personalized sparse serving: batched generation from per-client masked
models of an assigned architecture (reduced config on CPU).

    PYTHONPATH=src python examples/serve_personalized.py [arch]
"""
import subprocess
import sys

ARCH = sys.argv[1] if len(sys.argv) > 1 else "gemma3-1b"

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", ARCH,
     "--clients", "4", "--batch", "2", "--prompt-len", "12", "--gen", "8"],
    check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
)
