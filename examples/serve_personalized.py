"""Personalized sparse serving: the repro.serve plane end to end — packed
delta store, LRU unpack cache, micro-batched launches — first over the
matmul-pipeline MLP (ref backend), then over an assigned smoke arch
(reduced config on CPU, vmap backend).

    PYTHONPATH=src python examples/serve_personalized.py [arch]
"""
import os
import subprocess
import sys

ARCH = sys.argv[1] if len(sys.argv) > 1 else "gemma3-1b"

# inherit the caller's environment (jax flags, tmpdirs, PATH) and only
# overlay what the child actually needs
ENV = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--model", "mlp",
     "--backend", "ref", "--users", "32", "--cache-size", "8",
     "--max-batch", "8", "--requests", "128", "--density", "0.3"],
    check=True, env=ENV,
)

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--model", ARCH,
     "--backend", "vmap", "--users", "4", "--cache-size", "2",
     "--max-batch", "2", "--requests", "8", "--rows", "1"],
    check=True, env=ENV,
)
