"""Adding a strategy to the zoo in <100 lines: DFedProx.

A decentralized FedProx variant — Metropolis gossip mixing (as D-PSGD) but
each client's local phase adds a proximal pull toward the model it received
from its neighborhood, damping client drift under non-IID data.  Only three
hooks differ from the defaults; topology sampling, eval cadence, streaming
metrics, checkpointing and comm/FLOP accounting all come from RoundEngine.

    PYTHONPATH=src python examples/custom_strategy.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.data import build_federated_image_task
from repro.fl import FLConfig, RoundEngine, make_cnn_task, make_strategy, register
from repro.fl.decentralized import metropolis_weights
from repro.fl.engine import StrategyBase
from repro.utils.tree import tree_size


@register("dfedprox")
class DFedProx(StrategyBase):
    """State: {"params": [K trees]}.  mu is the proximal strength."""

    def __init__(self, mu: float = 0.1):
        self.mu = mu

    def init_state(self, task, clients, cfg):
        super().init_state(task, clients, cfg)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(clients))
        params = [task.init_fn(k) for k in keys]
        self.n_coords = tree_size(params[0])
        return {"params": params}

    def mix(self, state, ctx):
        w = metropolis_weights(ctx.adjacency)
        params = state["params"]
        state["params"] = [
            jax.tree.map(
                lambda *leaves: sum(w[k, j] * x for j, x in enumerate(leaves)
                                    if w[k, j] != 0.0),
                *params)
            for k in range(len(params))
        ]

    def local_update(self, state, k, ctx):
        c, cfg = self.clients[k], ctx.cfg
        rng = ctx.client_rng(k)
        ref = state["params"][k]                       # neighborhood model
        w = ref
        bs = min(cfg.batch_size, c.n_train)
        for _ in range(cfg.local_epochs):
            order = rng.permutation(c.n_train)
            for i in range(0, len(order), bs):
                s = order[i: i + bs]
                _, g = self.task.value_and_grad(w, c.train_x[s], c.train_y[s])
                w = jax.tree.map(
                    lambda wi, gi, ri: wi - ctx.lr * (
                        gi + cfg.weight_decay * wi + self.mu * (wi - ri)),
                    w, g, ref)
        state["params"][k] = w

    def round_comm(self, state, ctx):
        return decentralized_comm(ctx.adjacency,
                                  [self.n_coords] * len(self.clients),
                                  self.n_coords)

    def round_flops(self, state, ctx):
        return sparse_training_flops(
            self.task.fwd_flops, {k: 1.0 for k in self.task.fwd_flops},
            self.n_samples, ctx.cfg.local_epochs, mask_search_batches=0,
            batch_size=ctx.cfg.batch_size)


def main() -> None:
    clients, _ = build_federated_image_task(
        seed=0, n_clients=8, partition="pathological", classes_per_client=2,
        n_train_per_class=60, n_test_per_client=30, hw=16, noise=0.8)
    task = make_cnn_task("smallcnn", n_classes=10, hw=16, width=8)
    cfg = FLConfig(n_clients=8, rounds=6, local_epochs=2, batch_size=32,
                   degree=3, eval_every=2)
    engine = RoundEngine(make_strategy("dfedprox", mu=0.1), task, clients, cfg)
    for m in engine.rounds():                          # streaming metrics
        acc = (f"acc={m.acc_mean:.3f}±{m.acc_std:.3f}"
               if m.acc_mean is not None else "")
        print(f"round {m.round + 1}/{cfg.rounds} lr={m.lr:.3f} "
              f"comm={m.comm_busiest_mb:.2f}MB {acc}")
    res = engine.result()
    print(f"final personalized acc: {res.final_acc:.3f} "
          f"(per-client std {np.std(res.final_accs):.3f})")


if __name__ == "__main__":
    main()
