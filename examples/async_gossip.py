"""Asynchronous DisPFL on a simulated heterogeneous network — packed payloads.

Eight clients with 0.2x..1.0x compute speeds train decentralized sparse
models through ``repro.sim.SimEngine``, three times on identical data:

* synchronous barrier — every round waits for the slowest client,
* async gossip (staleness <= 2) — fast clients keep training and mix
  whichever neighbor models have physically arrived,
* async on *faulty* links — every message risks a 15% Bernoulli drop
  (resent after a timeout, retransmitted bytes measured on the wire) and
  each sender's concurrent pushes serialize FIFO on one shared uplink.

Messages are ``repro.sparse`` packed trees (uint32 mask bitmap + the nnz
values — what DisPFL actually ships), each activation mixes them with the
O(degree · nnz) ``mix_one`` hook, and every simulated transfer is stamped
with the exact wire-codec frame size — the busiest-node MB, wall-clock and
retransmit overhead below are observed, not assumed.

    PYTHONPATH=src python examples/async_gossip.py
"""
from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, make_strategy
from repro.sim import (
    LinkModel,
    LossModel,
    SimEngine,
    hetero_speeds,
    measure_payload,
)
from repro.sim.report import time_to_target
from repro.utils.tree import tree_bytes

K, ROUNDS = 8, 10

clients, _ = build_federated_image_task(
    0, n_clients=K, partition="dirichlet", alpha=0.3,
    n_train_per_class=40, n_test_per_client=24, hw=8, noise=0.8)
task = make_cnn_task("smallcnn", n_classes=10, hw=8, width=8)
cfg = FLConfig(n_clients=K, rounds=ROUNDS, local_epochs=2, batch_size=16,
               degree=3, eval_every=2)

speeds = hetero_speeds(K, seed=0)          # 0.2x .. 1.0x, shuffled
links = LinkModel.uniform(K, mbps=50, latency_ms=20)
print(f"clients={K} speeds={[round(float(s), 1) for s in speeds]}")

engines = {
    "sync": SimEngine(make_strategy("dispfl"), task, clients, cfg,
                      mode="sync", links=links, round_s=1.0,
                      compute_speeds=speeds),
    "async": SimEngine(make_strategy("dispfl"), task, clients, cfg,
                       mode="async", staleness=2, links=links,
                       round_s=1.0, compute_speeds=speeds),
    "lossy": SimEngine(make_strategy("dispfl"), task, clients, cfg,
                       mode="async", staleness=2, links=links,
                       round_s=1.0, compute_speeds=speeds,
                       uplink="fifo",
                       loss=LossModel(0.15, timeout_s=0.25, seed=0)),
}

# what one message physically is: the codec frame of a packed sparse tree
payload = engines["sync"].strategy.snapshot_message(engines["sync"].state, 0)
val_b, wire_b = measure_payload(payload)
dense_b = tree_bytes(engines["sync"].state["params"][0])
print(f"one message: {wire_b} B on the wire "
      f"({val_b:.0f} B values + bitmap/header) vs {dense_b} B dense "
      f"-> {wire_b / dense_b:.0%} of the dense tree")

for mode, eng in engines.items():
    for m in eng.rounds():
        if m.acc_mean is not None:
            print(f"  [{mode}] round {m.round + 1:2d} "
                  f"acc={m.acc_mean:.3f} t_sim={m.sim_time_s:7.2f}s "
                  f"busiest={m.busiest_up_mb:.2f}MB up")

target = min(max(a for _, a in e.acc_trace) for e in engines.values()) - 1e-9
print(f"\ncommon target accuracy: {target:.3f}")
for mode, eng in engines.items():
    hit = time_to_target(eng.acc_trace, target)
    rep = eng.report(targets=(target,))
    print(f"{mode:>5}: wall={eng.sim_time:7.2f}s  to-target={hit:7.2f}s  "
          f"busiest-node={rep.busiest_node} "
          f"({rep.busiest_up_mb:.2f}MB up / {rep.busiest_down_mb:.2f}MB down)")
print(f"async observed staleness spread: "
      f"{engines['async'].observed_spread} rounds "
      f"(bound {engines['async'].staleness})")

# the price of unreliable links, measured from what was actually resent
lossy = engines["lossy"].stats
clean = engines["async"].stats
print(f"lossy links: {lossy.n_retransmits} retransmits = "
      f"{lossy.retrans_mb:.3f}MB extra on the wire "
      f"({lossy.retrans_mb / lossy.total_mb:.0%} of its "
      f"{lossy.total_mb:.2f}MB total; clean async moved "
      f"{clean.total_mb:.2f}MB), {lossy.n_lost} message(s) lost for good")
