"""K=256 DisPFL clients per round, sharded over 8 (forced) host devices.

This is the ``repro.scale`` regime: the whole communication round — the
intersection gossip (an adjacency-weighted masked einsum over the stacked
client dim, whose K-sharded contraction GSPMD turns into collectives), the
masked local-SGD phase and the batched prune/regrow mask search — is ONE
jitted SPMD program.  256 personalized sparse models train per round with
a single XLA dispatch; the same run through the loop engine would make
tens of thousands of per-client dispatches.

The device count is forced *before* jax initializes (the same trick the
multi-pod dry-run uses), so this demonstrates the sharded execution path
on any CPU box:

    PYTHONPATH=src python examples/scale_mesh.py

On a real mesh, replace ``make_test_mesh`` with
``launch.mesh.make_production_mesh`` — the stacked state shardings
(``sharding.rules.tree_stacked_shardings``) put the K dim on the
('pod', 'data') client axes either way.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

from repro.data import build_federated_image_task  # noqa: E402
from repro.fl import FLConfig, make_cnn_task, make_strategy  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.scale import ScaleEngine  # noqa: E402
from repro.sparse import encoded_nbytes  # noqa: E402

K, ROUNDS = 256, 2

# ~20 samples per client: 512 per class split over the ~51 clients holding
# each class — tiny shards, but 256 of them, which is the point
clients, _ = build_federated_image_task(
    0, n_clients=K, partition="pathological", classes_per_client=2,
    n_train_per_class=512, n_test_per_client=10, hw=8, noise=0.8)
task = make_cnn_task("smallcnn", n_classes=10, hw=8, width=8)
cfg = FLConfig(n_clients=K, rounds=ROUNDS, local_epochs=1, batch_size=8,
               degree=8, density=0.5, eval_every=ROUNDS)

mesh = make_test_mesh(data=8, model=1)
print(f"mesh {dict(mesh.shape)} -> {K} clients, "
      f"{K // mesh.shape['data']} per device shard")

engine = ScaleEngine(make_strategy("dispfl"), task, clients, cfg, mesh=mesh)
for m in engine.rounds():
    acc = f" acc={m.acc_mean:.3f}±{m.acc_std:.3f}" if m.acc_mean else ""
    print(f"round {m.round + 1}/{ROUNDS}: busiest-node "
          f"{m.comm_busiest_mb:.2f} MB, lr={m.lr:.3f}, "
          f"wall {m.wall_s:.1f}s{acc}")

frames = [encoded_nbytes(msg["packed"]) for msg in engine.snapshot_messages()]
print(f"per-message codec frame: mean {np.mean(frames) / 1e3:.1f} kB "
      f"(density {cfg.density}); {K} models mixed per round, one dispatch")
