"""End-to-end driver: DisPFL-train a transformer LM for a few hundred steps
on synthetic non-IID corpora (one Markov domain per client).

Default is CPU-sized (~6M params/client, 200 steps).  For the ~100M-model
run on a real machine:

    PYTHONPATH=src python examples/train_e2e.py --d-model 768 --layers 12 \
        --steps 300 --clients 4

This wraps ``repro.launch.train lm`` — the same code path the mesh-scale
train step uses (gossip_average_stacked + masked SGD + mask evolution).
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--clients", default="4")
ap.add_argument("--steps", default="200")
ap.add_argument("--rounds", default="10")
ap.add_argument("--d-model", default="256", dest="d_model")
ap.add_argument("--layers", default="2")
ap.add_argument("--seq", default="128")
args = ap.parse_args()

subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "lm",
     "--arch", args.arch, "--clients", args.clients, "--steps", args.steps,
     "--rounds", args.rounds, "--d-model", args.d_model,
     "--layers", args.layers, "--seq", args.seq],
    check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
)
