"""Client heterogeneity (paper §4.3, Table 3 / Fig 4): five capacity groups
{20%, 40%, 60%, 80%, 100%} federate together; every group still learns.

    PYTHONPATH=src python examples/heterogeneous_clients.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, run_strategy
from repro.fl.decentralized import run_dpsgd


def main() -> None:
    k = 10
    clients, _ = build_federated_image_task(
        seed=1, n_clients=k, partition="pathological", classes_per_client=2,
        n_train_per_class=80, hw=16)
    task = make_cnn_task("smallcnn", 10, 16, width=12)
    levels = [0.2, 0.4, 0.6, 0.8, 1.0]
    caps = [levels[i % 5] for i in range(k)]
    cfg = FLConfig(n_clients=k, rounds=8, local_epochs=3, batch_size=32,
                   degree=4, capacities=caps, eval_every=4)

    res = run_strategy("dispfl", task, clients, cfg)
    print(f"DisPFL (heterogeneous capacities): acc={res.final_acc:.3f}")
    accs = np.array(res.final_accs)
    for lvl in levels:
        sel = [i for i, c in enumerate(caps) if c == lvl]
        print(f"  capacity {int(lvl*100):3d}% -> acc {accs[sel].mean():.3f}")

    # baseline confined to the weakest device
    res_d = run_dpsgd(task, clients, cfg, finetune=True, param_fraction=0.2)
    print(f"D-PSGD-FT @20% params (weakest-device bound): "
          f"acc={res_d.final_acc:.3f}")


if __name__ == "__main__":
    main()
