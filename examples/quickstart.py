"""Quickstart: DisPFL vs Local / D-PSGD-FT on a non-IID synthetic task.

    PYTHONPATH=src python examples/quickstart.py

Ten clients, pathological label split (2 classes each), 8 rounds.  Shows the
paper's headline effects: personalized accuracy above both local-only and
consensus-model training, at roughly half the busiest-node communication.
"""
import sys

sys.path.insert(0, "src")

from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, run_strategy


def main() -> None:
    clients, _ = build_federated_image_task(
        seed=0, n_clients=10, partition="pathological", classes_per_client=2,
        n_train_per_class=80, n_test_per_client=40, hw=16, noise=0.8)
    task = make_cnn_task("smallcnn", n_classes=10, hw=16, width=12)
    cfg = FLConfig(n_clients=10, rounds=8, local_epochs=3, batch_size=32,
                   degree=4, density=0.5, eval_every=2)

    print(f"{'method':12s} {'acc':>7s} {'comm(MB)':>9s} {'GFLOP/round':>12s}")
    for method in ("local", "dpsgd", "dpsgd_ft", "dispfl"):
        res = run_strategy(method, task, clients, cfg)
        print(f"{method:12s} {res.final_acc:7.3f} "
              f"{res.comm_busiest_mb:9.2f} {res.flops_per_round/1e9:12.2f}")


if __name__ == "__main__":
    main()
