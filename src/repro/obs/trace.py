"""Nestable span tracing on named tracks, wall- and virtual-clock.

One ``Tracer`` records ``Span`` intervals into a bounded ring buffer (or an
unbounded list in ``mode="full"``).  Spans carry a *clock domain*: ``WALL``
spans are measured with ``time.perf_counter`` relative to the tracer's
enable epoch; ``VIRTUAL`` spans are stamped by the caller with simulator
seconds (``repro.sim``'s ``VirtualClock`` timeline, ``repro.serve``'s
request arrivals).  The two domains export as separate Perfetto processes
(``repro.obs.export``) so a run renders as per-client / per-link /
per-slot timelines next to the host's measured phase timings.

Overhead contract: when the tracer is disabled, ``span(...)`` returns a
shared no-op context manager — one attribute check and no allocation on
the hot path — so instrumentation can live permanently in engine loops
(``benchmarks/engine_vmap.py`` gates the enabled-mode ratio, and
``tests/test_obs.py`` smokes the disabled call cost).

The module-level ``span`` / ``get_tracer`` operate on a process default
tracer; ``set_tracer`` swaps it (benchmarks use a private instance so an
overhead probe never clobbers a run-level ``--trace`` capture).
"""
from __future__ import annotations

import functools
import threading
import time
from collections import deque
from typing import Any, Iterable, Optional

WALL = "wall"
VIRTUAL = "virtual"
CLOCKS = (WALL, VIRTUAL)
MODES = ("ring", "full")
DEFAULT_CAPACITY = 65536


class Span:
    """One closed interval on a named track."""

    __slots__ = ("name", "track", "t0", "t1", "clock", "seq", "attrs")

    def __init__(self, name: str, track: str, t0: float, t1: float,
                 clock: str, seq: int, attrs: dict):
        self.name = name
        self.track = track
        self.t0 = float(t0)
        self.t1 = float(t1)
        self.clock = clock
        self.seq = seq
        self.attrs = attrs

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "track": self.track, "t0": self.t0,
                "t1": self.t1, "clock": self.clock, "seq": self.seq,
                "attrs": dict(self.attrs)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, track={self.track!r}, "
                f"t0={self.t0:.6f}, t1={self.t1:.6f}, clock={self.clock})")


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    @property
    def attrs(self) -> dict:
        # a fresh throwaway dict: callers may annotate unconditionally
        return {}


_NULL = _NullSpan()


class _SpanCM:
    """Live wall-clock span context manager; ``attrs`` is mutable until
    ``__exit__`` so callers can annotate results computed inside."""

    __slots__ = ("_tracer", "name", "track", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.attrs = attrs

    def __enter__(self):
        self._t0 = self._tracer.now()
        return self

    def __exit__(self, *exc):
        t = self._tracer
        t._append(self.name, self.track, self._t0, t.now(), WALL, self.attrs)
        return False


class _OpenSpan:
    """Handle for a begin()/end() span (slot residency, SSP waits)."""

    __slots__ = ("name", "track", "t0", "clock", "attrs")

    def __init__(self, name: str, track: str, t0: float, clock: str,
                 attrs: dict):
        self.name = name
        self.track = track
        self.t0 = float(t0)
        self.clock = clock
        self.attrs = attrs


class Tracer:
    def __init__(self, mode: str = "ring",
                 capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self.mode = mode
        self.capacity = int(capacity)
        self.dropped = 0
        self._seq = 0
        self._epoch = 0.0
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=self.capacity)
        self._open: dict[int, _OpenSpan] = {}

    # -- lifecycle ---------------------------------------------------------
    def enable(self, mode: Optional[str] = None,
               capacity: Optional[int] = None) -> "Tracer":
        """(Re)arm recording with an empty buffer; the wall epoch resets so
        exported wall timestamps are run-relative."""
        if mode is not None:
            if mode not in MODES:
                raise ValueError(f"trace mode must be one of {MODES}, "
                                 f"got {mode!r}")
            self.mode = mode
        if capacity is not None:
            if capacity < 1:
                raise ValueError("trace capacity must be >= 1")
            self.capacity = int(capacity)
        with self._lock:
            self._spans = deque(
                maxlen=self.capacity if self.mode == "ring" else None)
            self._open = {}
            self.dropped = 0
            self._seq = 0
            self._epoch = time.perf_counter()
            self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._open = {}
            self.dropped = 0
            self._seq = 0

    def now(self) -> float:
        """Wall seconds since the enable epoch."""
        return time.perf_counter() - self._epoch

    def __len__(self) -> int:
        return len(self._spans)

    # -- recording ---------------------------------------------------------
    def _append(self, name: str, track: str, t0: float, t1: float,
                clock: str, attrs: dict) -> None:
        with self._lock:
            if (self._spans.maxlen is not None
                    and len(self._spans) == self._spans.maxlen):
                self.dropped += 1
            seq = self._seq
            self._seq = seq + 1
            self._spans.append(Span(name, track, t0, t1, clock, seq, attrs))

    def span(self, name: str, track: str = "main", **attrs):
        """Wall-clock span context manager (no-op when disabled)."""
        if not self.enabled:
            return _NULL
        return _SpanCM(self, name, track, attrs)

    def add_span(self, name: str, t0: float, t1: float, track: str = "main",
                 clock: str = VIRTUAL, **attrs) -> None:
        """Record an already-measured interval (virtual timelines)."""
        if not self.enabled:
            return
        self._append(name, track, t0, t1, clock, attrs)

    def begin(self, name: str, track: str = "main", clock: str = WALL,
              t: Optional[float] = None, **attrs) -> Optional[_OpenSpan]:
        """Open a span whose end is not yet known (pool-slot residency,
        staleness waits).  Returns a handle for ``end``, or None when
        disabled (``end(None)`` is a no-op)."""
        if not self.enabled:
            return None
        t0 = self.now() if t is None else float(t)
        h = _OpenSpan(name, track, t0, clock, attrs)
        with self._lock:
            self._open[id(h)] = h
        return h

    def end(self, handle: Optional[_OpenSpan],
            t: Optional[float] = None, **attrs) -> None:
        if handle is None:
            return
        with self._lock:
            live = self._open.pop(id(handle), None)
        if live is None:      # tracer re-enabled/cleared since begin
            return
        t1 = self.now() if t is None else float(t)
        if attrs:
            handle.attrs.update(attrs)
        self._append(handle.name, handle.track, handle.t0, t1,
                     handle.clock, handle.attrs)

    def end_all(self, t: Optional[float] = None) -> int:
        """Close every still-open span (export calls this so residency
        spans reach the trace).  Returns how many were closed."""
        with self._lock:
            pending = list(self._open.values())
            self._open = {}
        for h in pending:
            t1 = (self.now() if h.clock == WALL else h.t0) if t is None \
                else float(t)
            self._append(h.name, h.track, h.t0, max(t1, h.t0), h.clock,
                         h.attrs)
        return len(pending)

    # -- queries -----------------------------------------------------------
    def spans(self, clock: Optional[str] = None,
              track: Optional[str] = None) -> list[Span]:
        out: Iterable[Span] = list(self._spans)
        if clock is not None:
            out = [s for s in out if s.clock == clock]
        if track is not None:
            out = [s for s in out if s.track == track]
        return list(out)

    def tracks(self, clock: Optional[str] = None) -> list[str]:
        return sorted({s.track for s in self.spans(clock=clock)})


# ---------------------------------------------------------------------------
# process default tracer
# ---------------------------------------------------------------------------

_DEFAULT = Tracer()


def get_tracer() -> Tracer:
    return _DEFAULT


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process default tracer; returns the previous one."""
    global _DEFAULT
    old = _DEFAULT
    _DEFAULT = tracer
    return old


def span(name: str, track: str = "main", **attrs):
    """Module-level ``with span("phase"):`` against the default tracer —
    the form the engine hot paths use (near-zero cost when disabled)."""
    t = _DEFAULT
    if not t.enabled:
        return _NULL
    return _SpanCM(t, name, track, attrs)


def traced(name: Optional[str] = None, track: str = "main"):
    """Decorator form: time every call of ``fn`` as one span."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            t = _DEFAULT
            if not t.enabled:
                return fn(*args, **kwargs)
            with _SpanCM(t, label, track, {}):
                return fn(*args, **kwargs)

        return wrapper

    return deco
