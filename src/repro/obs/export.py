"""Trace/metrics export: Chrome/Perfetto ``trace_event`` JSON + the one
place the repo's JSONL streaming schema is versioned.

``to_trace_events`` renders a ``Tracer``'s buffer as the Trace Event
Format both ``chrome://tracing`` and https://ui.perfetto.dev load
directly: complete ``"X"`` events with microsecond ``ts``/``dur``, one
*process* per clock domain (pid 1 = wall clock, pid 2 = the simulator's
virtual clock) and one *thread* per track (``client/3``, ``link/0->2``,
``slot/5``, ...).  Thread ids are assigned by sorted track name, so the
same run always exports the same (pid, tid) layout — track assignment is
deterministic, which the trace tests pin down.

Counter state rides in ``otherData.counters`` (a ``snapshot_counters()``
taken at export time), which is what lets a trace artifact reconcile
exactly against ``LinkStats`` bytes and ``ModelStore`` hit/miss counts.

``JSONL_SCHEMA_VERSION`` is the version stamp for the repo's streaming
JSON-lines protocol (``sim.report.MetricsStream`` headers, round metrics);
bump it when a streamed record's shape changes.  See
``docs/observability.md`` for the full schema.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.obs.counters import snapshot_counters
from repro.obs.trace import CLOCKS, VIRTUAL, WALL, Span, Tracer, get_tracer

#: version of the streaming JSON-lines records (MetricsStream et al.)
JSONL_SCHEMA_VERSION = 1
#: version of the exported trace document's repo-specific otherData
TRACE_SCHEMA_VERSION = 1

#: one Perfetto "process" per clock domain
CLOCK_PIDS = {WALL: 1, VIRTUAL: 2}
_CLOCK_LABELS = {WALL: "wall clock (s)", VIRTUAL: "virtual clock (sim s)"}


def to_trace_events(tracer: Optional[Tracer] = None,
                    close_open: bool = True) -> dict:
    """Render the tracer's spans as a Chrome trace_event JSON object."""
    tracer = tracer or get_tracer()
    if close_open:
        tracer.end_all()
    spans = sorted(tracer.spans(), key=lambda s: s.seq)

    tids: dict[str, dict[str, int]] = {}      # clock -> track -> tid
    for clock in CLOCKS:
        tracks = sorted({s.track for s in spans if s.clock == clock})
        tids[clock] = {track: i + 1 for i, track in enumerate(tracks)}

    events: list[dict] = []
    for clock in CLOCKS:
        if not tids[clock]:
            continue
        pid = CLOCK_PIDS[clock]
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "args": {"name": _CLOCK_LABELS[clock]}})
        events.append({"name": "process_sort_index", "ph": "M", "pid": pid,
                       "args": {"sort_index": pid}})
        for track, tid in tids[clock].items():
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": track}})
    for s in spans:
        events.append({
            "name": s.name,
            "cat": s.clock,
            "ph": "X",
            "ts": round(s.t0 * 1e6, 3),
            "dur": round(max(s.dur, 0.0) * 1e6, 3),
            "pid": CLOCK_PIDS[s.clock],
            "tid": tids[s.clock][s.track],
            "args": dict(s.attrs),
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "traceSchemaVersion": TRACE_SCHEMA_VERSION,
            "jsonlSchemaVersion": JSONL_SCHEMA_VERSION,
            "spans": len(spans),
            "droppedSpans": tracer.dropped,
            "mode": tracer.mode,
            "counters": snapshot_counters(),
        },
    }


def write_trace(path: str, tracer: Optional[Tracer] = None,
                close_open: bool = True) -> dict:
    """Export the tracer to a Perfetto-loadable JSON file; returns the
    document (callers print event counts / reconcile in tests)."""
    doc = to_trace_events(tracer, close_open=close_open)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, default=str)
    return doc


def validate_trace(doc: dict) -> list[str]:
    """Cheap structural validation of an exported trace document (the
    invariants Perfetto's JSON importer relies on).  Returns problems —
    empty means loadable."""
    problems: list[str] = []
    if not isinstance(doc.get("traceEvents"), list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(doc["traceEvents"]):
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing name")
        if not isinstance(ev.get("pid"), int):
            problems.append(f"event {i}: missing pid")
        if ph == "X":
            for key in ("ts", "dur"):
                if not isinstance(ev.get(key), (int, float)):
                    problems.append(f"event {i}: missing {key}")
            if isinstance(ev.get("dur"), (int, float)) and ev["dur"] < 0:
                problems.append(f"event {i}: negative dur")
            if not isinstance(ev.get("tid"), int):
                problems.append(f"event {i}: missing tid")
    try:
        json.dumps(doc)
    except TypeError as e:
        problems.append(f"not JSON-serializable: {e}")
    return problems


_CLOCK_BY_PID = {pid: clock for clock, pid in CLOCK_PIDS.items()}


def spans_from_trace_doc(doc: dict) -> list[Span]:
    """Inverse of ``to_trace_events``: rebuild ``Span`` objects from an
    exported trace document so the health rollups (``repro.obs.health``)
    compute identically from a live tracer and a loaded artifact.

    Track names come from the ``thread_name`` metadata events;
    timestamps return as seconds (the export's microsecond rounding
    bounds them to 1e-9 s — byte attrs, which the exact reconciliations
    sum, round-trip bit-exactly through JSON).
    """
    tracks: dict[tuple[int, int], str] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tracks[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    spans: list[Span] = []
    for i, ev in enumerate(doc.get("traceEvents", [])):
        if ev.get("ph") != "X":
            continue
        clock = _CLOCK_BY_PID.get(ev.get("pid"), WALL)
        track = tracks.get((ev.get("pid"), ev.get("tid")),
                           f"tid/{ev.get('tid')}")
        t0 = float(ev["ts"]) * 1e-6
        t1 = t0 + float(ev["dur"]) * 1e-6
        spans.append(Span(ev["name"], track, t0, t1, clock, i,
                          dict(ev.get("args", {}))))
    return spans


def phase_summary(spans_or_tracer=None, clock: Optional[str] = None,
                  track: Optional[str] = None) -> dict:
    """Aggregate spans by name: ``{name: {count, total_s, mean_s, max_s}}``
    — the measured side of the roofline's predicted-vs-observed table."""
    if spans_or_tracer is None:
        spans_or_tracer = get_tracer()
    spans = (spans_or_tracer.spans(clock=clock, track=track)
             if isinstance(spans_or_tracer, Tracer) else
             [s for s in spans_or_tracer
              if (clock is None or s.clock == clock)
              and (track is None or s.track == track)])
    out: dict[str, dict] = {}
    for s in spans:
        agg = out.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                      "max_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += max(s.dur, 0.0)
        agg["max_s"] = max(agg["max_s"], s.dur)
    for agg in out.values():
        agg["mean_s"] = agg["total_s"] / agg["count"]
    return out
