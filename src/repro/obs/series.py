"""Typed time-series and bounded-memory quantile histograms (obs layer 2).

PR 7 gave the process spans (``repro.obs.trace``) and counters
(``repro.obs.counters``).  This module adds the *monitorable* layer on
top: values sampled over time, and value distributions with error-bounded
percentiles — both with hard memory bounds so they can live inside engine
loops for millions of rounds/requests without growing unboundedly.

* ``TimeSeries`` — ``(t, value)`` samples on a declared clock domain
  (``WALL`` or ``VIRTUAL``, same constants as the tracer) and a declared
  kind: ``"gauge"`` (point-in-time readings, e.g. busiest-node MB) or
  ``"counter"`` (cumulative readings of a monotonic counter, e.g. bytes
  on wire — ``deltas()``/``delta_sum()`` recover per-window increments,
  and the telescoping identity ``delta_sum() == last - initial`` is what
  the reconciliation tests pin against ``snapshot_counters()``).  When a
  series exceeds its point budget it decimates to every second sample
  (always keeping the newest); cumulative counter samples survive this
  losslessly in total (telescoping sum), gauges become subsampled.

* ``LogHistogram`` — a DDSketch-style log-bucket sketch: sparse integer
  buckets at geometric boundaries ``gamma^i`` with
  ``gamma = (1+alpha)/(1-alpha)``.  Any reported quantile is within
  relative error ``alpha`` of the exact sample quantile; two sketches
  with the same ``alpha`` merge exactly (bucket-count addition), and
  memory is capped at ``max_buckets`` by collapsing the lowest buckets
  (the DDSketch policy: tail quantiles — the ones dashboards read —
  keep full accuracy).  This replaces the unbounded Python lists that
  previously backed serve wait/service percentiles and link transfer
  times.

* ``SeriesSet`` — a namespaced bundle (one per engine/store, mirroring
  ``CounterSet``) weakly registered process-wide so ``snapshot_series()``
  can archive every live series/histogram as one JSON-serializable doc
  (``repro.obs.runs`` stores that doc in the run archive;
  ``launch/dash.py`` renders sparklines from it).

Importing this module never imports jax or numpy — it is safe in the
hottest engine loops.
"""
from __future__ import annotations

import math
import threading
import weakref
from typing import Optional

from repro.obs.trace import CLOCKS, WALL

GAUGE = "gauge"
COUNTER = "counter"
KINDS = (GAUGE, COUNTER)

#: schema version for the series snapshot doc stored in run archives
SERIES_SCHEMA_VERSION = 1

DEFAULT_MAX_POINTS = 4096
DEFAULT_ALPHA = 0.01
DEFAULT_MAX_BUCKETS = 1024


class TimeSeries:
    """Bounded ``(t, value)`` samples on one clock, gauge- or counter-kind."""

    __slots__ = ("name", "clock", "kind", "max_points", "initial", "_pts")

    def __init__(self, name: str, clock: str = WALL, kind: str = GAUGE,
                 max_points: int = DEFAULT_MAX_POINTS, initial: float = 0.0):
        if clock not in CLOCKS:
            raise ValueError(f"clock must be one of {CLOCKS}, got {clock!r}")
        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        if max_points < 2:
            raise ValueError("max_points must be >= 2")
        self.name = name
        self.clock = clock
        self.kind = kind
        self.max_points = int(max_points)
        #: baseline for counter-kind deltas (value before the first sample)
        self.initial = float(initial)
        self._pts: list[tuple[float, float]] = []

    def observe(self, t: float, value: float) -> None:
        """Record one sample.  Counter-kind series record the *cumulative*
        counter value (not the increment)."""
        self._pts.append((float(t), float(value)))
        if len(self._pts) > self.max_points:
            # decimate to every 2nd sample, always keeping the newest:
            # for cumulative counter samples the telescoping delta sum is
            # unchanged; gauges become half-rate subsampled.
            last = self._pts[-1]
            kept = self._pts[:-1:2]
            if kept and kept[-1] == last:
                self._pts = kept
            else:
                kept.append(last)
                self._pts = kept

    def __len__(self) -> int:
        return len(self._pts)

    def points(self) -> list[tuple[float, float]]:
        return list(self._pts)

    @property
    def last(self) -> Optional[tuple[float, float]]:
        return self._pts[-1] if self._pts else None

    def deltas(self) -> list[tuple[float, float]]:
        """Per-window increments of a counter-kind series (first window is
        relative to ``initial``)."""
        if self.kind != COUNTER:
            raise TypeError(f"series {self.name!r} is a gauge; no deltas")
        out, prev = [], self.initial
        for t, v in self._pts:
            out.append((t, v - prev))
            prev = v
        return out

    def delta_sum(self) -> float:
        """Telescoping sum of ``deltas()`` — exactly ``last - initial``
        regardless of decimation (the reconciliation invariant)."""
        if self.kind != COUNTER:
            raise TypeError(f"series {self.name!r} is a gauge; no deltas")
        if not self._pts:
            return 0.0
        return self._pts[-1][1] - self.initial

    def to_dict(self) -> dict:
        return {"name": self.name, "clock": self.clock, "kind": self.kind,
                "initial": self.initial,
                "points": [[t, v] for t, v in self._pts]}

    @classmethod
    def from_dict(cls, d: dict) -> "TimeSeries":
        ts = cls(d["name"], clock=d["clock"], kind=d["kind"],
                 initial=float(d.get("initial", 0.0)))
        ts._pts = [(float(t), float(v)) for t, v in d["points"]]
        return ts


class LogHistogram:
    """DDSketch-style mergeable histogram with ``alpha``-bounded quantiles.

    Buckets sit at geometric boundaries ``gamma^(i-1) < x <= gamma^i``
    with ``gamma = (1+alpha)/(1-alpha)``; a bucket's representative value
    is the midpoint ``2*gamma^i/(gamma+1)``, which is within relative
    error ``alpha`` of every sample in the bucket.  Values must be
    >= 0 (durations, byte counts); exact zeros get their own bucket.

    Memory is bounded: at most ``max_buckets`` non-zero buckets, enforced
    by collapsing the *lowest* pair when exceeded (tail quantiles keep
    the full guarantee; only quantiles that land in collapsed low buckets
    degrade, and only downward in resolution, never in ordering).  With
    the defaults (alpha=0.01, 1024 buckets) the sketch covers ~9 decades
    before any collapse — far more dynamic range than any duration or
    byte-size distribution here produces, so in practice quantiles stay
    within ``alpha`` everywhere.
    """

    __slots__ = ("alpha", "max_buckets", "gamma", "_log_gamma", "_counts",
                 "zero_count", "count", "sum", "min", "max", "collapsed")

    def __init__(self, alpha: float = DEFAULT_ALPHA,
                 max_buckets: int = DEFAULT_MAX_BUCKETS):
        if not (0.0 < alpha < 1.0):
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        if max_buckets < 2:
            raise ValueError("max_buckets must be >= 2")
        self.alpha = float(alpha)
        self.max_buckets = int(max_buckets)
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._log_gamma = math.log(self.gamma)
        self._counts: dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        #: number of low-bucket collapses performed (0 == exact-α sketch)
        self.collapsed = 0

    def _index(self, x: float) -> int:
        return int(math.ceil(math.log(x) / self._log_gamma))

    def _value(self, i: int) -> float:
        return 2.0 * math.pow(self.gamma, i) / (self.gamma + 1.0)

    def add(self, x: float, n: int = 1) -> None:
        x = float(x)
        if x < 0.0:
            raise ValueError(f"LogHistogram values must be >= 0, got {x}")
        if n < 1:
            raise ValueError("n must be >= 1")
        self.count += n
        self.sum += x * n
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        if x == 0.0:
            self.zero_count += n
            return
        i = self._index(x)
        self._counts[i] = self._counts.get(i, 0) + n
        if len(self._counts) > self.max_buckets:
            self._collapse_lowest()

    def _collapse_lowest(self) -> None:
        lows = sorted(self._counts)[:2]
        lo, nxt = lows[0], lows[1]
        self._counts[nxt] += self._counts.pop(lo)
        self.collapsed += 1

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """In-place exact merge (same ``alpha`` required); returns self."""
        if abs(other.alpha - self.alpha) > 1e-12:
            raise ValueError(
                f"cannot merge sketches with alpha {self.alpha} and "
                f"{other.alpha}")
        for i, n in other._counts.items():
            self._counts[i] = self._counts.get(i, 0) + n
        while len(self._counts) > self.max_buckets:
            self._collapse_lowest()
        self.zero_count += other.zero_count
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        self.collapsed += other.collapsed
        return self

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def n_buckets(self) -> int:
        return len(self._counts) + (1 if self.zero_count else 0)

    def quantile(self, q: float) -> float:
        """Sample quantile within relative error ``alpha`` (nearest-rank
        over buckets).  Returns 0.0 on an empty sketch."""
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = self.zero_count
        if rank < seen:
            return 0.0
        for i in sorted(self._counts):
            seen += self._counts[i]
            if rank < seen:
                return self._value(i)
        return self._value(max(self._counts))    # pragma: no cover

    def percentiles(self, qs=(0.5, 0.9, 0.99)) -> dict:
        return {f"p{round(q * 100):d}": self.quantile(q) for q in qs}

    def to_dict(self) -> dict:
        return {"alpha": self.alpha, "max_buckets": self.max_buckets,
                "counts": {str(i): n for i, n in sorted(self._counts.items())},
                "zero_count": self.zero_count, "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "collapsed": self.collapsed}

    @classmethod
    def from_dict(cls, d: dict) -> "LogHistogram":
        h = cls(alpha=float(d["alpha"]),
                max_buckets=int(d.get("max_buckets", DEFAULT_MAX_BUCKETS)))
        h._counts = {int(i): int(n) for i, n in d["counts"].items()}
        h.zero_count = int(d.get("zero_count", 0))
        h.count = int(d["count"])
        h.sum = float(d["sum"])
        h.min = math.inf if d.get("min") is None else float(d["min"])
        h.max = -math.inf if d.get("max") is None else float(d["max"])
        h.collapsed = int(d.get("collapsed", 0))
        return h


_REGISTRY: "weakref.WeakSet[SeriesSet]" = weakref.WeakSet()
_REGISTRY_LOCK = threading.Lock()


class SeriesSet:
    """A namespaced bundle of series/histograms, weakly registered
    process-wide (the owner holds the only strong reference, mirroring
    ``CounterSet`` semantics)."""

    def __init__(self, namespace: str):
        self.namespace = namespace
        self._series: dict[str, TimeSeries] = {}
        self._hists: dict[str, LogHistogram] = {}
        with _REGISTRY_LOCK:
            _REGISTRY.add(self)

    def series(self, name: str, clock: str = WALL, kind: str = GAUGE,
               max_points: int = DEFAULT_MAX_POINTS,
               initial: float = 0.0) -> TimeSeries:
        s = self._series.get(name)
        if s is None:
            s = self._series[name] = TimeSeries(
                name, clock=clock, kind=kind, max_points=max_points,
                initial=initial)
        return s

    def histogram(self, name: str, alpha: float = DEFAULT_ALPHA,
                  max_buckets: int = DEFAULT_MAX_BUCKETS) -> LogHistogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = LogHistogram(alpha=alpha,
                                                max_buckets=max_buckets)
        return h

    def snapshot(self) -> dict:
        """JSON-serializable doc of every series and histogram."""
        return {
            "series": {n: s.to_dict()
                       for n, s in sorted(self._series.items())},
            "histograms": {n: h.to_dict()
                           for n, h in sorted(self._hists.items())},
        }


def snapshot_series(prefix: Optional[str] = None) -> dict:
    """Archive doc over every live ``SeriesSet``: versioned, with flat
    ``namespace/name`` keys (the run-archive ``series.json`` payload)."""
    with _REGISTRY_LOCK:
        sets = list(_REGISTRY)
    series: dict[str, dict] = {}
    hists: dict[str, dict] = {}
    for ss in sorted(sets, key=lambda s: s.namespace):
        if prefix is not None and not ss.namespace.startswith(prefix):
            continue
        snap = ss.snapshot()
        for name, doc in snap["series"].items():
            series[f"{ss.namespace}/{name}"] = doc
        for name, doc in snap["histograms"].items():
            hists[f"{ss.namespace}/{name}"] = doc
    return {"seriesSchemaVersion": SERIES_SCHEMA_VERSION,
            "series": series, "histograms": hists}
