"""Fleet-health rollups and threshold events (obs layer 2).

Turns the span/counter contract the engines already emit (PR 7) into the
aggregates an operator actually watches: which node is busiest and by how
much, which clients straggle, which links retransmit, how stale the SSP
waits run, whether the store is hitting, and whether measured sparsity is
tracking the anneal schedule.  Everything here is *derived* — rollups are
pure functions of spans + counters, so they compute identically from a
live ``Tracer``, a list of ``Span`` objects, or a trace document loaded
back from disk (``repro.obs.export.spans_from_trace_doc``), which is what
lets ``launch/dash.py`` render from a run archive and lets tests
reconcile rollups against ``LinkStats`` exactly.

Exactness contract: the sim engine's ``_trace_xfer`` mirrors each
``LinkStats.record`` with the same floats in the same order, so
``comm_rollup`` over a *complete* span buffer (``mode="full"``, or ring
with zero drops) reproduces ``LinkStats``' per-node byte accumulators
bit-for-bit — the additions happen in the same sequence.  A ring buffer
that dropped spans under-counts; ``fleet_health`` surfaces that as a
``trace.dropped`` health event rather than silently reconciling wrong.

``HealthThresholds`` + ``fleet_health`` produce ``HealthEvent`` rows
(severity ``warning | serious | critical``, one per tripped rule) which
``emit_health`` streams as ``{"event": "health", ...}`` records through
``sim.report.MetricsStream`` — the same live JSONL protocol round metrics
use.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.obs.series import LogHistogram, TimeSeries
from repro.obs.trace import VIRTUAL, Span, Tracer

MB = 1e-6   # decimal MB, matching repro.sim.links / the paper's tables

SEVERITIES = ("warning", "serious", "critical")


def _spans_of(source) -> list[Span]:
    """Normalize a rollup source: Tracer | Sequence[Span] | trace doc."""
    if isinstance(source, Tracer):
        return source.spans()
    if isinstance(source, dict):
        from repro.obs.export import spans_from_trace_doc
        return spans_from_trace_doc(source)
    return list(source)


# ---------------------------------------------------------------------------
# rollups (pure functions of spans/counters)
# ---------------------------------------------------------------------------

def comm_rollup(source, top_k: int = 5) -> dict:
    """Per-node traffic and per-link retransmit rates from the virtual
    ``transfer``/``retransmit`` spans.

    Byte sums reconcile exactly with ``LinkStats`` (same floats, same
    addition order) when the span buffer is complete.  ``per_node_mb``
    follows the paper's busiest-direction convention ``max(up, down)``.
    """
    up: dict[int, float] = {}
    down: dict[int, float] = {}
    up_wire: dict[int, float] = {}
    down_wire: dict[int, float] = {}
    link_attempts: dict[tuple[int, int], int] = {}
    link_retrans: dict[tuple[int, int], int] = {}
    retrans_bytes = 0.0
    n_transfers = 0
    xfer_s = LogHistogram()
    for s in _spans_of(source):
        if s.name not in ("transfer", "retransmit") or s.clock != VIRTUAL:
            continue
        src, dst = int(s.attrs["src"]), int(s.attrs["dst"])
        bv = float(s.attrs["bytes_values"])
        bw = float(s.attrs["bytes_wire"])
        up[src] = up.get(src, 0.0) + bv
        down[dst] = down.get(dst, 0.0) + bv
        up_wire[src] = up_wire.get(src, 0.0) + bw
        down_wire[dst] = down_wire.get(dst, 0.0) + bw
        link_attempts[(src, dst)] = link_attempts.get((src, dst), 0) + 1
        if int(s.attrs.get("attempt", 0)) > 0:
            link_retrans[(src, dst)] = link_retrans.get((src, dst), 0) + 1
            retrans_bytes += bv
        n_transfers += 1
        xfer_s.add(max(s.dur, 0.0))
    nodes = sorted(set(up) | set(down))
    per_node_mb = {k: max(up.get(k, 0.0), down.get(k, 0.0)) * MB
                   for k in nodes}
    busiest = max(per_node_mb, key=per_node_mb.get) if per_node_mb else None
    total_retrans = sum(link_retrans.values())
    link_rates = {f"{s}->{d}": link_retrans.get((s, d), 0) / n
                  for (s, d), n in sorted(link_attempts.items())}
    return {
        "n_transfers": n_transfers,
        "nodes": nodes,
        "up_bytes": {k: up.get(k, 0.0) for k in nodes},
        "down_bytes": {k: down.get(k, 0.0) for k in nodes},
        "up_wire_bytes": {k: up_wire.get(k, 0.0) for k in nodes},
        "down_wire_bytes": {k: down_wire.get(k, 0.0) for k in nodes},
        "per_node_mb": per_node_mb,
        "busiest_node": busiest,
        "busiest_node_mb": per_node_mb.get(busiest, 0.0) if nodes else 0.0,
        "mean_node_mb": (sum(per_node_mb.values()) / len(nodes)
                         if nodes else 0.0),
        "top_nodes": sorted(per_node_mb.items(), key=lambda kv: -kv[1])[:top_k],
        "total_mb": sum(up.values()) * MB,
        "retrans_mb": retrans_bytes * MB,
        "n_retransmits": total_retrans,
        "retransmit_rate": (total_retrans / n_transfers
                            if n_transfers else 0.0),
        "link_retransmit_rate": link_rates,
        "worst_links": sorted(link_rates.items(),
                              key=lambda kv: -kv[1])[:top_k],
        "transfer_s": xfer_s,
    }


def straggler_rollup(source, top_k: int = 5) -> dict:
    """Per-client compute totals from the virtual ``compute`` spans on
    ``client/*`` tracks; ``top_stragglers`` are the largest totals."""
    totals: dict[int, float] = {}
    counts: dict[int, int] = {}
    hist = LogHistogram()
    for s in _spans_of(source):
        if s.name != "compute" or not s.track.startswith("client/"):
            continue
        k = int(s.track.split("/", 1)[1])
        d = max(s.dur, 0.0)
        totals[k] = totals.get(k, 0.0) + d
        counts[k] = counts.get(k, 0) + 1
        hist.add(d)
    mean = (sum(totals.values()) / len(totals)) if totals else 0.0
    top = sorted(totals.items(), key=lambda kv: -kv[1])[:top_k]
    return {
        "n_clients": len(totals),
        "compute_s": totals,
        "spans_per_client": counts,
        "mean_compute_s": mean,
        "top_stragglers": top,
        "straggler_ratio": (top[0][1] / mean if top and mean > 0 else 0.0),
        "compute_span_s": hist,
    }


def staleness_rollup(source) -> dict:
    """SSP wait distribution from the virtual ``ssp.wait`` spans."""
    hist = LogHistogram()
    per_client: dict[int, float] = {}
    for s in _spans_of(source):
        if s.name != "ssp.wait":
            continue
        k = int(s.track.split("/", 1)[1]) if "/" in s.track else -1
        d = max(s.dur, 0.0)
        hist.add(d)
        per_client[k] = per_client.get(k, 0.0) + d
    return {"n_waits": hist.count, "total_wait_s": hist.sum,
            "wait_s": hist, "per_client_wait_s": per_client,
            "p99_wait_s": hist.quantile(0.99)}


def uplink_rollup(source, top_k: int = 5) -> dict:
    """Per-sender uplink busy seconds from the ``uplink.busy`` spans.

    Approximation caveat (documented in ``docs/observability.md``): under
    the ``fair`` discipline sharing is exact *within* one push batch, but
    batches queue FIFO behind a busy uplink, so busy seconds here are the
    serialized occupancy of that hybrid schedule — not an idealized
    processor-sharing fluid limit across batches.
    """
    busy: dict[int, float] = {}
    t_max = 0.0
    for s in _spans_of(source):
        if s.name != "uplink.busy":
            continue
        src = int(s.track.split("/", 1)[1])
        busy[src] = busy.get(src, 0.0) + max(s.dur, 0.0)
        t_max = max(t_max, s.t1)
    util = {k: (v / t_max if t_max > 0 else 0.0) for k, v in busy.items()}
    return {"busy_s": busy, "span_s": t_max, "utilization": util,
            "top_uplinks": sorted(busy.items(), key=lambda kv: -kv[1])[:top_k]}


def store_rollup(counters: dict) -> dict:
    """Hit ratio and occupancy from a ``snapshot_counters()`` dict."""
    hits = float(counters.get("serve.store/hits", 0))
    misses = float(counters.get("serve.store/misses", 0))
    return {
        "hits": hits,
        "misses": misses,
        "evictions": float(counters.get("serve.store/evictions", 0)),
        "resident": float(counters.get("serve.store/resident", 0)),
        "bytes_at_rest": float(counters.get("serve.store/bytes_at_rest", 0)),
        "hit_ratio": hits / max(hits + misses, 1.0),
    }


def density_drift(measured: TimeSeries, target: TimeSeries) -> dict:
    """Measured-density-vs-anneal-schedule drift: pair the two gauge
    series positionally (both are sampled once per round by the engine)
    and report the largest and final absolute drift."""
    pts_m, pts_t = measured.points(), target.points()
    n = min(len(pts_m), len(pts_t))
    drifts = [abs(pts_m[i][1] - pts_t[i][1]) for i in range(n)]
    return {
        "n": n,
        "max_drift": max(drifts) if drifts else 0.0,
        "final_drift": drifts[-1] if drifts else 0.0,
        "final_measured": pts_m[n - 1][1] if n else None,
        "final_target": pts_t[n - 1][1] if n else None,
    }


# ---------------------------------------------------------------------------
# threshold events
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HealthEvent:
    kind: str            # e.g. "link.retransmit_rate"
    severity: str        # warning | serious | critical
    message: str
    value: float
    threshold: float

    def to_dict(self) -> dict:
        return {"event": "health", "kind": self.kind,
                "severity": self.severity, "message": self.message,
                "value": self.value, "threshold": self.threshold}


@dataclasses.dataclass
class HealthThresholds:
    """Tripwires for ``fleet_health``; ``None`` disables a rule."""
    max_retransmit_rate: Optional[float] = 0.05
    max_busiest_imbalance: Optional[float] = 3.0   # busiest / mean node MB
    max_straggler_ratio: Optional[float] = 3.0     # slowest / mean compute
    max_p99_staleness_s: Optional[float] = None    # run-scale dependent
    min_store_hit_ratio: Optional[float] = 0.5
    max_density_drift: Optional[float] = 0.05      # absolute density units


def _event(events: list, kind: str, severity: str, msg: str,
           value: float, threshold: float) -> None:
    if severity not in SEVERITIES:
        raise ValueError(f"severity must be one of {SEVERITIES}")
    events.append(HealthEvent(kind, severity, msg, float(value),
                              float(threshold)))


def fleet_health(source, counters: Optional[dict] = None,
                 thresholds: Optional[HealthThresholds] = None,
                 density: Optional[tuple[TimeSeries, TimeSeries]] = None,
                 dropped_spans: int = 0) -> tuple[dict, list[HealthEvent]]:
    """Compute every rollup and evaluate the thresholds.

    Returns ``(rollups, events)``; ``rollups`` maps
    ``comm | stragglers | staleness | uplinks | store | density`` to the
    corresponding rollup dict (``store`` only when ``counters`` given,
    ``density`` only when the series pair is given).
    """
    th = thresholds or HealthThresholds()
    spans = _spans_of(source)
    roll = {
        "comm": comm_rollup(spans),
        "stragglers": straggler_rollup(spans),
        "staleness": staleness_rollup(spans),
        "uplinks": uplink_rollup(spans),
    }
    if counters is not None:
        roll["store"] = store_rollup(counters)
    if density is not None:
        roll["density"] = density_drift(*density)

    events: list[HealthEvent] = []
    if dropped_spans:
        _event(events, "trace.dropped", "warning",
               f"{dropped_spans} spans dropped by the ring buffer; "
               "rollups under-count (use --trace-mode full to reconcile)",
               dropped_spans, 0)

    comm = roll["comm"]
    if (th.max_retransmit_rate is not None and comm["n_transfers"]
            and comm["retransmit_rate"] > th.max_retransmit_rate):
        worst = comm["worst_links"][0] if comm["worst_links"] else ("-", 0.0)
        _event(events, "link.retransmit_rate",
               "critical" if comm["retransmit_rate"]
               > 2 * th.max_retransmit_rate else "serious",
               f"fleet retransmit rate {comm['retransmit_rate']:.1%} "
               f"(worst link {worst[0]} at {worst[1]:.1%})",
               comm["retransmit_rate"], th.max_retransmit_rate)
    if (th.max_busiest_imbalance is not None and comm["mean_node_mb"] > 0):
        imb = comm["busiest_node_mb"] / comm["mean_node_mb"]
        if imb > th.max_busiest_imbalance:
            _event(events, "comm.busiest_imbalance", "warning",
                   f"node {comm['busiest_node']} carries {imb:.1f}x the "
                   f"mean per-node traffic "
                   f"({comm['busiest_node_mb']:.2f} MB)",
                   imb, th.max_busiest_imbalance)

    strag = roll["stragglers"]
    if (th.max_straggler_ratio is not None
            and strag["straggler_ratio"] > th.max_straggler_ratio):
        k, total = strag["top_stragglers"][0]
        _event(events, "compute.straggler", "warning",
               f"client {k} spent {total:.2f}s computing, "
               f"{strag['straggler_ratio']:.1f}x the fleet mean",
               strag["straggler_ratio"], th.max_straggler_ratio)

    stale = roll["staleness"]
    if (th.max_p99_staleness_s is not None and stale["n_waits"]
            and stale["p99_wait_s"] > th.max_p99_staleness_s):
        _event(events, "ssp.staleness", "serious",
               f"p99 SSP wait {stale['p99_wait_s']:.2f}s exceeds "
               f"{th.max_p99_staleness_s:.2f}s",
               stale["p99_wait_s"], th.max_p99_staleness_s)

    store = roll.get("store")
    if (store is not None and th.min_store_hit_ratio is not None
            and store["hits"] + store["misses"] > 0
            and store["hit_ratio"] < th.min_store_hit_ratio):
        _event(events, "store.hit_ratio", "warning",
               f"store hit ratio {store['hit_ratio']:.1%} below "
               f"{th.min_store_hit_ratio:.0%}",
               store["hit_ratio"], th.min_store_hit_ratio)

    dens = roll.get("density")
    if (dens is not None and th.max_density_drift is not None
            and dens["max_drift"] > th.max_density_drift):
        _event(events, "density.drift", "serious",
               f"measured density drifted {dens['max_drift']:.3f} from the "
               f"anneal schedule (final measured "
               f"{dens['final_measured']:.3f} vs target "
               f"{dens['final_target']:.3f})",
               dens["max_drift"], th.max_density_drift)

    return roll, events


def emit_health(stream, events: Sequence[HealthEvent]) -> None:
    """Stream health events as JSONL records through a ``MetricsStream``
    (or anything with ``emit(dict)``)."""
    for ev in events:
        stream.emit(ev.to_dict())
