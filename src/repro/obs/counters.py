"""Typed monotonic counters and gauges with a process-wide registry.

A ``CounterSet`` is a named bundle an engine/store owns (``serve.store``,
``scale.engine``, ``sparse.codec``, ...).  Sets register themselves in a
weak registry, so ``snapshot_counters()`` can collect every live metric in
the process as flat ``namespace/name -> value`` rows — this is what the
trace exporter stamps into a run's ``otherData`` (and what tests use to
reconcile trace spans against ``LinkStats`` / ``ModelStore`` exactly).

Two metric types:

* ``Counter`` — monotonic (``inc`` rejects negative deltas).  The existing
  engine counters (`ModelStore.hits`, codec byte totals) are backed by
  these instead of private ints/dicts, keeping their attribute APIs.
* ``Gauge`` — a point-in-time value, either set explicitly or computed by
  a callback at read time (used to mirror stateful accumulators such as
  ``LinkStats`` totals without duplicating their checkpointed state).

``install_jax_hooks`` bridges ``jax.monitoring``: every backend compile
event increments ``jax/backend_compiles`` (and accumulates compile
seconds), which is what makes "the stacked round compiles exactly once"
an assertable counter (``ScaleEngine``; ``tests/test_obs.py``).
"""
from __future__ import annotations

import threading
import weakref
from typing import Callable, Optional

#: the jax.monitoring event fired once per XLA backend compile
JAX_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class Counter:
    """Monotonic counter (int or float increments)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name!r} is monotonic; cannot inc by {n}")
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def __int__(self) -> int:
        return int(self.value)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Point-in-time value: explicit ``set`` or a read-time callback."""

    __slots__ = ("name", "_fn", "_value")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._fn = fn
        self._value = 0

    def set(self, v) -> None:
        if self._fn is not None:
            raise ValueError(f"gauge {self.name!r} is callback-backed")
        self._value = v

    @property
    def value(self):
        return self._fn() if self._fn is not None else self._value

    def reset(self) -> None:
        if self._fn is None:
            self._value = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Gauge({self.name}={self.value})"


_REGISTRY: "weakref.WeakSet[CounterSet]" = weakref.WeakSet()
_REGISTRY_LOCK = threading.Lock()


class CounterSet:
    """A namespaced bundle of counters/gauges, weakly registered process-wide.

    The owner (engine, store, codec module) holds the only strong
    reference, so a set disappears from snapshots when its owner does.
    """

    def __init__(self, namespace: str):
        self.namespace = namespace
        self._metrics: dict[str, Counter | Gauge] = {}
        with _REGISTRY_LOCK:
            _REGISTRY.add(self)

    def counter(self, name: str) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Counter(name)
        elif not isinstance(m, Counter):
            raise TypeError(f"{self.namespace}/{name} is a {type(m).__name__}")
        return m

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Gauge(name, fn)
        elif not isinstance(m, Gauge):
            raise TypeError(f"{self.namespace}/{name} is a {type(m).__name__}")
        return m

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> dict:
        return {name: m.value for name, m in sorted(self._metrics.items())}


def snapshot_counters(prefix: Optional[str] = None) -> dict:
    """Flat ``namespace/name -> value`` over every live ``CounterSet``;
    same-key metrics from multiple sets (several engines in one process)
    sum."""
    with _REGISTRY_LOCK:
        sets = list(_REGISTRY)
    out: dict[str, float] = {}
    for cs in sorted(sets, key=lambda s: s.namespace):
        if prefix is not None and not cs.namespace.startswith(prefix):
            continue
        for name, value in cs.snapshot().items():
            key = f"{cs.namespace}/{name}"
            out[key] = out.get(key, 0) + value
    return out


# ---------------------------------------------------------------------------
# jax.monitoring bridge (lazy: importing repro.obs never imports jax)
# ---------------------------------------------------------------------------

_JAX_SET: Optional[CounterSet] = None   # strong ref: hooks live forever

#: attribute stashed on the ``jax.monitoring`` module itself.  The module
#: object outlives a reload of *this* module (which resets ``_JAX_SET``),
#: so the guard cannot be defeated by ``importlib.reload(repro.obs.counters)``
#: or by two copies of this package installing independently — either of
#: which would register a second listener and double-count
#: ``jax/backend_compiles`` (and, through it, ``ScaleEngine.step_compiles``)
#: whenever train + benchmarks share one process.
_JAX_HOOK_ATTR = "_repro_obs_compile_counter_set"


def install_jax_hooks() -> CounterSet:
    """Idempotently register a ``jax.monitoring`` listener counting backend
    compiles into the ``jax`` namespace.  Returns the namespace's set.

    Idempotent across repeated calls *and* across reloads of this module:
    the installed ``CounterSet`` is stashed on ``jax.monitoring`` itself,
    so at most one listener ever exists per process."""
    global _JAX_SET
    if _JAX_SET is not None:
        return _JAX_SET
    import jax.monitoring

    existing = getattr(jax.monitoring, _JAX_HOOK_ATTR, None)
    if existing is not None:
        _JAX_SET = existing
        return existing

    cs = CounterSet("jax")
    compiles = cs.counter("backend_compiles")
    compile_s = cs.counter("backend_compile_s")

    def _on_duration(event: str, secs: float, **kw) -> None:
        if event == JAX_COMPILE_EVENT:
            compiles.inc()
            compile_s.inc(float(secs))

    jax.monitoring.register_event_duration_secs_listener(_on_duration)
    setattr(jax.monitoring, _JAX_HOOK_ATTR, cs)
    _JAX_SET = cs
    return cs


def jax_compile_count() -> int:
    """Backend compiles observed since ``install_jax_hooks`` (installing
    on first use) — snapshot before/after a jit call to detect recompiles."""
    return int(install_jax_hooks().counter("backend_compiles").value)
