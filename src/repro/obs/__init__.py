"""repro.obs — unified tracing, counters, and profile export.

One observability layer shared by all four engines (``repro.fl.engine``,
``repro.sim``, ``repro.scale``, ``repro.serve``) and the sparse codec:

* ``trace``    — nestable ``span("phase", **attrs)`` on named tracks,
  wall- and virtual-clock, ring-buffered, near-zero cost when disabled;
* ``counters`` — monotonic counters / gauges in namespaced ``CounterSet``
  bundles with a process-wide snapshot, plus the ``jax.monitoring``
  compile-event bridge;
* ``export``   — Chrome/Perfetto ``trace_event`` JSON export and the
  single place the streaming JSONL schema is versioned.

Importing this package never imports jax (hot paths stay light); see
``docs/observability.md`` for schema, counter names and trace tracks.
"""
from repro.obs.counters import (
    Counter,
    CounterSet,
    Gauge,
    install_jax_hooks,
    jax_compile_count,
    snapshot_counters,
)
from repro.obs.export import (
    JSONL_SCHEMA_VERSION,
    TRACE_SCHEMA_VERSION,
    phase_summary,
    to_trace_events,
    validate_trace,
    write_trace,
)
from repro.obs.trace import (
    VIRTUAL,
    WALL,
    Span,
    Tracer,
    get_tracer,
    set_tracer,
    span,
    traced,
)

__all__ = [
    "Counter",
    "CounterSet",
    "Gauge",
    "JSONL_SCHEMA_VERSION",
    "Span",
    "TRACE_SCHEMA_VERSION",
    "Tracer",
    "VIRTUAL",
    "WALL",
    "get_tracer",
    "install_jax_hooks",
    "jax_compile_count",
    "phase_summary",
    "set_tracer",
    "snapshot_counters",
    "span",
    "to_trace_events",
    "traced",
    "validate_trace",
    "write_trace",
]
