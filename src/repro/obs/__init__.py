"""repro.obs — unified tracing, counters, and profile export.

One observability layer shared by all four engines (``repro.fl.engine``,
``repro.sim``, ``repro.scale``, ``repro.serve``) and the sparse codec:

* ``trace``    — nestable ``span("phase", **attrs)`` on named tracks,
  wall- and virtual-clock, ring-buffered, near-zero cost when disabled;
* ``counters`` — monotonic counters / gauges in namespaced ``CounterSet``
  bundles with a process-wide snapshot, plus the ``jax.monitoring``
  compile-event bridge;
* ``export``   — Chrome/Perfetto ``trace_event`` JSON export and the
  single place the streaming JSONL schema is versioned.

Importing this package never imports jax (hot paths stay light); see
``docs/observability.md`` for schema, counter names and trace tracks.
"""
from repro.obs.counters import (
    Counter,
    CounterSet,
    Gauge,
    install_jax_hooks,
    jax_compile_count,
    snapshot_counters,
)
from repro.obs.export import (
    JSONL_SCHEMA_VERSION,
    TRACE_SCHEMA_VERSION,
    phase_summary,
    spans_from_trace_doc,
    to_trace_events,
    validate_trace,
    write_trace,
)
from repro.obs.health import (
    HealthEvent,
    HealthThresholds,
    comm_rollup,
    density_drift,
    emit_health,
    fleet_health,
    staleness_rollup,
    store_rollup,
    straggler_rollup,
    uplink_rollup,
)
from repro.obs.runs import (
    RunArchive,
    RunManifest,
    RunRegistry,
    append_history,
    diff_runs,
    git_sha,
    metric_history,
    read_history,
    save_run,
)
from repro.obs.series import (
    SERIES_SCHEMA_VERSION,
    LogHistogram,
    SeriesSet,
    TimeSeries,
    snapshot_series,
)
from repro.obs.trace import (
    VIRTUAL,
    WALL,
    Span,
    Tracer,
    get_tracer,
    set_tracer,
    span,
    traced,
)

__all__ = [
    "Counter",
    "CounterSet",
    "Gauge",
    "HealthEvent",
    "HealthThresholds",
    "JSONL_SCHEMA_VERSION",
    "LogHistogram",
    "RunArchive",
    "RunManifest",
    "RunRegistry",
    "SERIES_SCHEMA_VERSION",
    "SeriesSet",
    "Span",
    "TRACE_SCHEMA_VERSION",
    "TimeSeries",
    "Tracer",
    "VIRTUAL",
    "WALL",
    "append_history",
    "comm_rollup",
    "density_drift",
    "diff_runs",
    "emit_health",
    "fleet_health",
    "get_tracer",
    "git_sha",
    "install_jax_hooks",
    "jax_compile_count",
    "metric_history",
    "phase_summary",
    "read_history",
    "save_run",
    "set_tracer",
    "snapshot_counters",
    "snapshot_series",
    "span",
    "spans_from_trace_doc",
    "staleness_rollup",
    "store_rollup",
    "straggler_rollup",
    "to_trace_events",
    "traced",
    "uplink_rollup",
    "validate_trace",
    "write_trace",
]
