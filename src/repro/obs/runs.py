"""Run manifests, on-disk run archives, and the benchmark history log.

A *run* is one invocation of a launcher or the benchmark gate.  This
module gives every run an identity and a durable artifact:

* ``RunManifest`` — what produced the numbers: run id, kind, creation
  time, git sha, seed, the config/argv that launched it, and the
  python/numpy/jax + schema versions that interpret it.
* ``save_run`` / ``RunArchive`` — a run directory holding
  ``manifest.json``, ``counters.json`` (``snapshot_counters()``),
  ``series.json`` (``snapshot_series()``), and optionally ``trace.json``
  (the Perfetto export) and ``report.json``.  ``launch/dash.py`` renders
  a dashboard from exactly this layout, and ``RunRegistry`` lists/loads
  archives under a root directory.
* ``append_history`` / ``read_history`` — the append-only
  ``BENCH_history.jsonl`` that fixes the perf-trajectory loss:
  ``BENCH_latest.json`` is overwritten every gate run, so before this
  file the repo had *no* performance history at all.  Each gate run
  appends one timestamped, git-sha-stamped line per benchmark module
  plus one ``run`` line carrying the run's ``phase_summary`` and counter
  snapshot — which is what ``check_regression --attribute`` diffs to
  name the phase/counter responsible for a rule failure (``diff_runs``).

Importing this module never imports jax; the jax version is recorded
only when jax is already loaded in the process.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import subprocess
import sys
import time
from typing import Optional

from repro.obs.counters import snapshot_counters
from repro.obs.export import (
    JSONL_SCHEMA_VERSION,
    TRACE_SCHEMA_VERSION,
    phase_summary,
    spans_from_trace_doc,
    write_trace,
)
from repro.obs.series import SERIES_SCHEMA_VERSION, snapshot_series

MANIFEST_NAME = "manifest.json"
COUNTERS_NAME = "counters.json"
SERIES_NAME = "series.json"
TRACE_NAME = "trace.json"
REPORT_NAME = "report.json"

#: version of the run-archive directory layout
RUN_SCHEMA_VERSION = 1


def git_sha(cwd: Optional[str] = None) -> str:
    """Current git commit (short), or ``"unknown"`` outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=cwd or os.getcwd())
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _versions() -> dict:
    v = {
        "python": sys.version.split()[0],
        "runSchemaVersion": RUN_SCHEMA_VERSION,
        "traceSchemaVersion": TRACE_SCHEMA_VERSION,
        "jsonlSchemaVersion": JSONL_SCHEMA_VERSION,
        "seriesSchemaVersion": SERIES_SCHEMA_VERSION,
    }
    np = sys.modules.get("numpy")
    if np is not None:
        v["numpy"] = getattr(np, "__version__", "unknown")
    # only record jax if the run already imported it — never import it here
    jax = sys.modules.get("jax")
    if jax is not None:
        v["jax"] = getattr(jax, "__version__", "unknown")
    return v


@dataclasses.dataclass
class RunManifest:
    run_id: str
    kind: str                      # train | sim | serve | bench | ...
    created: float                 # unix seconds
    git_sha: str
    seed: Optional[int] = None
    config: dict = dataclasses.field(default_factory=dict)
    argv: list = dataclasses.field(default_factory=list)
    versions: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def build(cls, kind: str, run_id: Optional[str] = None,
              seed: Optional[int] = None,
              config: Optional[dict] = None,
              argv: Optional[list] = None) -> "RunManifest":
        created = time.time()
        if run_id is None:
            stamp = datetime.datetime.fromtimestamp(
                created, datetime.timezone.utc).strftime("%Y%m%d-%H%M%S")
            run_id = f"{kind}-{stamp}-{os.getpid()}"
        return cls(run_id=run_id, kind=kind, created=created,
                   git_sha=git_sha(), seed=seed, config=dict(config or {}),
                   argv=list(sys.argv if argv is None else argv),
                   versions=_versions())

    @property
    def created_iso(self) -> str:
        return datetime.datetime.fromtimestamp(
            self.created, datetime.timezone.utc).isoformat(
                timespec="seconds")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["created_iso"] = self.created_iso
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunManifest":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def save_run(run_dir: str, manifest: RunManifest, tracer=None,
             report: Optional[dict] = None,
             counters: Optional[dict] = None,
             series: Optional[dict] = None) -> "RunArchive":
    """Write a run archive: manifest + counter snapshot + series snapshot,
    plus the tracer's Perfetto export and an optional report doc.

    ``counters``/``series`` override the process-wide snapshots — pass
    per-instance snapshots when other live metric sets in the process
    (e.g. a shared test run) would pollute the same keys.
    """
    os.makedirs(run_dir, exist_ok=True)

    def _dump(name: str, obj) -> None:
        with open(os.path.join(run_dir, name), "w") as f:
            json.dump(obj, f, indent=1, default=str)
            f.write("\n")

    _dump(MANIFEST_NAME, manifest.to_dict())
    _dump(COUNTERS_NAME, snapshot_counters() if counters is None else counters)
    _dump(SERIES_NAME, snapshot_series() if series is None else series)
    if tracer is not None:
        write_trace(os.path.join(run_dir, TRACE_NAME), tracer)
    if report is not None:
        _dump(REPORT_NAME, report)
    return RunArchive(run_dir)


class RunArchive:
    """Lazy reader over one run directory (the ``save_run`` layout)."""

    def __init__(self, run_dir: str):
        self.run_dir = run_dir
        self._cache: dict[str, object] = {}

    def _load(self, name: str):
        if name not in self._cache:
            path = os.path.join(self.run_dir, name)
            if not os.path.exists(path):
                self._cache[name] = None
            else:
                with open(path) as f:
                    self._cache[name] = json.load(f)
        return self._cache[name]

    @property
    def exists(self) -> bool:
        return os.path.exists(os.path.join(self.run_dir, MANIFEST_NAME))

    def manifest(self) -> Optional[RunManifest]:
        d = self._load(MANIFEST_NAME)
        return None if d is None else RunManifest.from_dict(d)

    def counters(self) -> dict:
        return self._load(COUNTERS_NAME) or {}

    def series(self) -> dict:
        return self._load(SERIES_NAME) or {"series": {}, "histograms": {}}

    def trace(self) -> Optional[dict]:
        return self._load(TRACE_NAME)

    def report(self) -> Optional[dict]:
        return self._load(REPORT_NAME)

    def spans(self) -> list:
        doc = self.trace()
        return [] if doc is None else spans_from_trace_doc(doc)

    def phase_summary(self, clock: Optional[str] = None) -> dict:
        return phase_summary(self.spans(), clock=clock)


class RunRegistry:
    """Archives under one root directory, newest last."""

    def __init__(self, root: str):
        self.root = root

    def run_ids(self) -> list[str]:
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in os.listdir(self.root):
            ar = RunArchive(os.path.join(self.root, name))
            if ar.exists:
                m = ar.manifest()
                out.append((m.created, name))
        return [name for _, name in sorted(out)]

    def archive(self, run_id: str) -> RunArchive:
        return RunArchive(os.path.join(self.root, run_id))

    def latest(self, n: int = 1) -> list[RunArchive]:
        ids = self.run_ids()
        return [self.archive(r) for r in ids[-n:]]


# ---------------------------------------------------------------------------
# BENCH_history.jsonl — the append-only perf trajectory
# ---------------------------------------------------------------------------

def append_history(path: str, modules: dict[str, list],
                   phase_summary_doc: Optional[dict] = None,
                   counters: Optional[dict] = None,
                   sha: Optional[str] = None,
                   ts: Optional[float] = None,
                   note: str = "") -> int:
    """Append one line per benchmark module plus one ``run`` line; returns
    the number of lines written.  Existing history is never rewritten."""
    ts = time.time() if ts is None else float(ts)
    sha = git_sha() if sha is None else sha
    iso = datetime.datetime.fromtimestamp(
        ts, datetime.timezone.utc).isoformat(timespec="seconds")
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    n = 0
    with open(path, "a") as f:
        for module, rows in sorted(modules.items()):
            f.write(json.dumps({"event": "module", "ts": ts, "iso": iso,
                                "git_sha": sha, "module": module,
                                "rows": rows}, default=str) + "\n")
            n += 1
        run_line = {"event": "run", "ts": ts, "iso": iso, "git_sha": sha,
                    "modules": sorted(modules)}
        if note:
            run_line["note"] = note
        if phase_summary_doc is not None:
            run_line["phase_summary"] = phase_summary_doc
        if counters is not None:
            run_line["counters"] = counters
        f.write(json.dumps(run_line, default=str) + "\n")
        n += 1
    return n


def read_history(path: str, event: Optional[str] = None) -> list[dict]:
    """All history lines (optionally filtered by event kind), oldest
    first; malformed lines are skipped rather than fatal."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if event is None or rec.get("event") == event:
                out.append(rec)
    return out


def metric_history(path: str, module: str, row_name: str,
                   metric: str) -> list[tuple[float, float]]:
    """``(ts, value)`` trajectory of one benchmark metric — the series
    the dashboard's diff sparklines plot."""
    out = []
    for rec in read_history(path, event="module"):
        if rec.get("module") != module:
            continue
        for row in rec.get("rows", []):
            if row.get("name") == row_name and metric in row:
                try:
                    out.append((float(rec["ts"]), float(row[metric])))
                except (TypeError, ValueError):
                    pass
    return out


def diff_runs(old: dict, new: dict, top_k: int = 5) -> dict:
    """Rank what changed between two run-level docs, each shaped
    ``{"phase_summary": {...}, "counters": {...}}`` (a history ``run``
    line or a ``RunArchive``'s derived docs).

    Phases rank by absolute ``total_s`` delta, counters by relative
    change — the ``--attribute`` output that names the dominant cause of
    a regression instead of just the failing metric.
    """
    old_ph = old.get("phase_summary") or {}
    new_ph = new.get("phase_summary") or {}
    phases = []
    for name in sorted(set(old_ph) | set(new_ph)):
        o = float((old_ph.get(name) or {}).get("total_s", 0.0))
        nw = float((new_ph.get(name) or {}).get("total_s", 0.0))
        if o == 0.0 and nw == 0.0:
            continue
        phases.append({
            "phase": name, "old_s": o, "new_s": nw, "delta_s": nw - o,
            "ratio": (nw / o) if o > 0 else float("inf"),
        })
    phases.sort(key=lambda p: -abs(p["delta_s"]))

    old_c = old.get("counters") or {}
    new_c = new.get("counters") or {}
    counters = []
    for key in sorted(set(old_c) | set(new_c)):
        try:
            o = float(old_c.get(key, 0.0))
            nw = float(new_c.get(key, 0.0))
        except (TypeError, ValueError):
            continue
        if o == nw:
            continue
        rel = abs(nw - o) / max(abs(o), 1e-12)
        counters.append({"counter": key, "old": o, "new": nw,
                         "delta": nw - o, "rel": rel})
    counters.sort(key=lambda c: -c["rel"])
    return {"phases": phases[:top_k], "counters": counters[:top_k]}
