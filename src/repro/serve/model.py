"""Model adapters the serving engine is generic over.

The engine only needs three things from a model: a base ``init``, a
per-request input builder (seed-derived, so runs are reproducible), and a
*batched* forward that scores U user models against U inputs in one device
launch.  Three adapters cover the repo's model families:

* ``MLPModel`` — a bias-free relu MLP whose whole forward is a chain of
  masked matmuls.  This is the one model the block-sparse kernels can run
  end to end, so it supports all three backends:

  - ``vmap``   — ``jax.vmap`` over the per-user dense-masked params.  The
    store's params are already ``w ⊙ m``, so this is bit-exact (fp32)
    against the per-user loop — the property the engine's exactness tests
    pin down.
  - ``ref``    — per-layer ``kernels.ref.batched_masked_matmul_ref``
    (pure jnp, one fused launch per layer).
  - ``pallas`` — per-layer ``kernels.ops.batched_masked_matmul``: the
    user-major grid kernel with scalar-prefetched per-user block masks and
    ``@pl.when`` tile skipping.

* ``TaskModel`` — wraps an FL ``Task`` (the CNN backbones training
  checkpoints come from).  Conv models have no masked-matmul pipeline, so
  only the ``vmap`` backend applies.

* ``ArchModel`` — wraps a registered smoke arch (``configs.SMOKE_ARCHS``)
  as a one-step scorer: prefill a short prompt, return last-position
  logits.  ``vmap`` backend only, same stacked-params pattern the old
  serving demo used.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

BACKENDS = ("vmap", "ref", "pallas")


def _relu(x):
    return jnp.maximum(x, 0.0)


class MLPModel:
    """Bias-free relu MLP: every layer is ``h @ (w ⊙ m)`` — the matmul
    pipeline the batched kernel serves.  ``rows`` is the number of input
    rows per request (M of the matmul)."""

    def __init__(self, d_in: int = 64, widths: tuple[int, ...] = (128, 128),
                 n_out: int = 32, rows: int = 4):
        self.d_in = int(d_in)
        self.dims = (self.d_in, *[int(w) for w in widths], int(n_out))
        self.rows = int(rows)
        self._keys = [f"layer{i}" for i in range(len(self.dims) - 1)]
        self._jfwd: dict = {}

    def init(self, key: jax.Array) -> PyTree:
        ks = jax.random.split(key, len(self._keys))
        params = {}
        for i, name in enumerate(self._keys):
            fan_in = self.dims[i]
            params[name] = {"w": (jax.random.normal(
                ks[i], (self.dims[i], self.dims[i + 1]), jnp.float32)
                / np.sqrt(fan_in))}
        return params

    def make_input(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x1]))
        return rng.standard_normal((self.rows, self.d_in)).astype(np.float32)

    def forward(self, params: PyTree, x: jax.Array) -> jax.Array:
        """Single-user forward over dense(-masked) params — the oracle the
        batched backends are checked against."""
        h = x
        for i, name in enumerate(self._keys):
            h = h @ params[name]["w"]
            if i < len(self._keys) - 1:
                h = _relu(h)
        return h

    def _build(self, backend: str, interpret: bool):
        if backend == "vmap":
            def fwd(ps, ms, xs):
                del ms  # params are already w ⊙ m
                return jax.vmap(self.forward)(ps, xs)
            return jax.jit(fwd)
        if backend == "ref":
            from repro.kernels.ref import batched_masked_matmul_ref as bmm
        elif backend == "pallas":
            from repro.kernels.ops import batched_masked_matmul as _pallas_bmm
            import functools
            bmm = functools.partial(_pallas_bmm, interpret=interpret)
        else:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend}")

        def fwd(ps, ms, xs):
            h = xs
            for i, name in enumerate(self._keys):
                h = bmm(h, ps[name]["w"], ms[name]["w"])
                if i < len(self._keys) - 1:
                    h = _relu(h)
            return h
        return jax.jit(fwd)

    def batched_forward(self, params_stack: PyTree, masks_stack: PyTree,
                        xs: jax.Array, backend: str = "vmap",
                        interpret: bool = True) -> jax.Array:
        """xs: (U, rows, d_in) -> (U, rows, n_out); one launch per layer."""
        key = (backend, interpret)
        if key not in self._jfwd:
            self._jfwd[key] = self._build(backend, interpret)
        return self._jfwd[key](params_stack, masks_stack, xs)

    def backends(self) -> tuple[str, ...]:
        return BACKENDS


class TaskModel:
    """Serve an FL ``Task``'s model family (conv CNNs): request = one image
    batch, response = class logits.  vmap backend only."""

    def __init__(self, task, hw: int = 16, in_ch: int = 3, rows: int = 1):
        self.task = task
        self.hw = int(hw)
        self.in_ch = int(in_ch)
        self.rows = int(rows)
        self._jfwd = None

    def init(self, key: jax.Array) -> PyTree:
        return self.task.init_fn(key)

    def make_input(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x2]))
        return rng.standard_normal(
            (self.rows, self.hw, self.hw, self.in_ch)).astype(np.float32)

    def forward(self, params: PyTree, x: jax.Array) -> jax.Array:
        return self.task.apply_fn(params, x)

    def batched_forward(self, params_stack: PyTree, masks_stack: PyTree,
                        xs: jax.Array, backend: str = "vmap",
                        interpret: bool = True) -> jax.Array:
        del masks_stack, interpret
        if backend != "vmap":
            raise ValueError(
                f"TaskModel ({self.task.name}) has no masked-matmul "
                f"pipeline; only the vmap backend applies, got {backend}")
        if self._jfwd is None:
            self._jfwd = jax.jit(jax.vmap(self.forward))
        return self._jfwd(params_stack, xs)

    def backends(self) -> tuple[str, ...]:
        return ("vmap",)


class ArchModel:
    """Serve a registered smoke arch as a one-step scorer: prefill
    ``prompt_len`` tokens, return the last position's logits."""

    def __init__(self, cfg, prompt_len: int = 8, rows: int = 1):
        from repro.models import bind
        self.cfg = cfg
        self.api = bind(cfg, remat=False)
        self.prompt_len = int(prompt_len)
        self.rows = int(rows)
        self._jfwd = None

    def init(self, key: jax.Array) -> PyTree:
        return self.api.init(key)

    def make_input(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x3]))
        return rng.integers(0, self.cfg.vocab,
                            size=(self.rows, self.prompt_len),
                            dtype=np.int32)

    def forward(self, params: PyTree, tokens: jax.Array) -> jax.Array:
        b, s = tokens.shape
        batch = {"tokens": tokens}
        kw = {}
        max_len = s + self.cfg.prefix_len    # prefix rides in the kv cache
        if self.cfg.prefix_len:
            batch["prefix"] = jnp.zeros(
                (b, self.cfg.prefix_len, self.cfg.d_model))
        if self.cfg.enc_layers:
            batch["frames"] = jnp.zeros((b, 8, self.cfg.d_model))
            kw["enc_len"] = 8
        cache = self.api.init_cache(b, max_len, **kw)
        logits, _ = self.api.prefill(params, batch, cache)
        return logits[:, -1, :]

    def batched_forward(self, params_stack: PyTree, masks_stack: PyTree,
                        xs: jax.Array, backend: str = "vmap",
                        interpret: bool = True) -> jax.Array:
        del masks_stack, interpret
        if backend != "vmap":
            raise ValueError(
                f"ArchModel ({self.cfg.name}) has no masked-matmul "
                f"pipeline; only the vmap backend applies, got {backend}")
        if self._jfwd is None:
            self._jfwd = jax.jit(jax.vmap(self.forward))
        return self._jfwd(params_stack, xs)

    def backends(self) -> tuple[str, ...]:
        return ("vmap",)
