"""Deterministic request stream + cache-aware micro-batcher.

Serving experiments must be reproducible: two runs with the same seed and
knobs must form the *same* batches, touch the cache in the same order and
report the same hit/miss/eviction counts.  So arrivals are synthetic and
fully seed-derived (``np.random.SeedSequence([seed, ...])`` streams, the
same discipline as the round engine's per-``(seed, round, client)`` rng):
user ids from a Zipf-tilted popularity (hot users exist, which is what
makes an LRU cache worth having), exponential inter-arrival gaps at
``rate`` requests per virtual second, and a per-request input seed the
model adapter turns into the request payload.

``MicroBatcher`` groups pending requests into one device launch each.  Two
knobs bound the grouping:

* ``max_batch`` — at most this many requests per launch;
* ``max_wait`` — a pending request is never held longer than this many
  *virtual* seconds past its arrival before a flush.

A flush takes at most one request per user: a launch scores each user's
pool slot once, so a second same-user request in the window stays pending
for the next flush (its ``max_wait`` deadline still holds — the overdue
check runs before every arrival).  Within a flush, requests whose user
models are already resident in the unpack cache go first (``resident``
predicate — grouping by resident models keeps the launch from paying
unpack misses for users it could have deferred); ties keep arrival order,
so the whole schedule is a pure function of (stream, knobs, cache state)
and therefore of the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int                 # arrival index (0-based, dense)
    user: int                # which personalized model serves it
    t_arrival: float         # virtual seconds since stream start
    input_seed: int          # per-request payload seed (model adapter rng)


class RequestStream:
    """Seed-derived arrivals over ``n_users`` personalized models."""

    def __init__(self, n_users: int, n_requests: int, seed: int = 0,
                 rate: float = 1000.0, zipf_a: float = 1.1,
                 popularity: str = "zipf"):
        if popularity not in ("zipf", "uniform"):
            raise ValueError(f"popularity must be zipf|uniform, got {popularity}")
        self.n_users = int(n_users)
        self.n_requests = int(n_requests)
        self.seed = int(seed)
        self.rate = float(rate)
        self.zipf_a = float(zipf_a)
        self.popularity = popularity

    def requests(self) -> list[Request]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, 0xA11]))
        if self.popularity == "zipf":
            # Zipf-tilted popularity over a seed-shuffled user order, so
            # "hot" users are not always the low ids
            ranks = np.arange(1, self.n_users + 1, dtype=np.float64)
            probs = ranks ** (-self.zipf_a)
            probs /= probs.sum()
            order = rng.permutation(self.n_users)
            users = order[rng.choice(self.n_users, size=self.n_requests,
                                     p=probs)]
        else:
            users = rng.integers(0, self.n_users, size=self.n_requests)
        gaps = rng.exponential(1.0 / self.rate, size=self.n_requests)
        times = np.cumsum(gaps)
        seeds = rng.integers(0, 2**31 - 1, size=self.n_requests)
        return [Request(rid=i, user=int(users[i]), t_arrival=float(times[i]),
                        input_seed=int(seeds[i]))
                for i in range(self.n_requests)]

    def __iter__(self) -> Iterator[Request]:
        return iter(self.requests())


@dataclasses.dataclass(frozen=True)
class Batch:
    t_flush: float                  # virtual time the flush decision fired
    requests: tuple[Request, ...]   # launch order: resident users first

    @property
    def users(self) -> tuple[int, ...]:
        return tuple(r.user for r in self.requests)

    def queue_waits(self) -> list[float]:
        """Virtual seconds each request spent pending before its launch."""
        return [self.t_flush - r.t_arrival for r in self.requests]


class MicroBatcher:
    """Greedy deterministic micro-batching over an arrival sequence.

    ``resident`` is the cache predicate (``ModelStore.resident``); pass
    None to disable cache-aware ordering (pure arrival order).
    """

    def __init__(self, requests: Sequence[Request] | RequestStream,
                 max_batch: int = 8, max_wait: float = 0.005,
                 resident: Optional[Callable[[int], bool]] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.requests = list(requests)
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.resident = resident

    def _flush(self, pending: list[Request], t_flush: float) -> Batch:
        # one request per user per launch (a pool slot serves one model);
        # same-user duplicates keep their place in line for the next flush
        take: list[Request] = []
        seen: set[int] = set()
        rest: list[Request] = []
        for r in pending:
            if len(take) < self.max_batch and r.user not in seen:
                take.append(r)
                seen.add(r.user)
            else:
                rest.append(r)
        pending[:] = rest
        if self.resident is not None:
            # stable partition: resident-model requests first, arrival
            # order preserved inside each group
            take = ([r for r in take if self.resident(r.user)]
                    + [r for r in take if not self.resident(r.user)])
        return Batch(t_flush=t_flush, requests=tuple(take))

    def batches(self) -> Iterator[Batch]:
        pending: list[Request] = []
        for req in self.requests:
            # a pending request's max_wait deadline may expire before this
            # arrival: flush the overdue prefix first, at its deadline
            while pending and req.t_arrival > pending[0].t_arrival + self.max_wait:
                yield self._flush(pending, pending[0].t_arrival + self.max_wait)
            pending.append(req)
            if len(pending) >= self.max_batch:
                yield self._flush(pending, req.t_arrival)
        while pending:
            yield self._flush(pending, pending[0].t_arrival + self.max_wait)
