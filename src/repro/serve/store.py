"""``ModelStore`` — millions of personalized models at wire density.

DisPFL's end product is one personalized sparse model per user: a mask plus
the weights it keeps.  The store holds each user's model *exactly as it
travels on the wire*: a codec-encoded ``PackedSparse`` frame
(``sparse/codec.py`` — 8-byte header + bitmap + nnz values) against a
shared dense base model.  The frame IS the at-rest format, so

    store.bytes_at_rest(user) == codec.encoded_nbytes(user's packed delta)

byte for byte, and storage scales with mask density, not with K dense
replicas (``tests/test_serve.py`` pins this down; ``benchmarks/
serve_bench.py`` tracks the bytes-vs-density curve).

Delta semantics, stated honestly: the frame's bitmap is the personalization
*support* (the user's mask) and its values are the user's trained weights
at that support — a sparse *replacement* delta over the base, not a
residual ``w - base``.  At fp32 a residual delta saves zero bytes (same
nnz, same itemsize) and breaks the store's bit-exactness contract
(``(w - b) + b != w`` in floating point); replacement reconstruction
``scatter(values at bitmap)`` returns the training-side ``w ⊙ m``
bit-exactly.  The dense base serves two roles: the cold-start model for
users with no stored delta, and the dense baseline serving cost that the
benchmarks compare against.

The LRU cache is a *slot pool*: one device-resident stacked buffer per
leaf, shape ``(cache_size, ...)``, holding the unpacked dense-masked
models of the ``cache_size`` most recently served users.  The pool IS the
batched launch operand — the engine's vmapped/kernel forward runs straight
over it, so serving a cache hit moves zero parameter bytes (no per-launch
gather, no host restacking; that restacking cost is exactly what made
naive stacked serving lose to a per-user loop).  A miss decodes the
user's frame and writes one slot in place (``.at[slot].set`` under a
buffer-donating jit).  ``hits`` / ``misses`` / ``evictions`` counters
stream into the serve metrics; the access pattern is deterministic given
the request stream, so cache behaviour is reproducible (tested).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

import time

from repro.obs import CounterSet, SeriesSet, get_tracer, span
from repro.sparse import (
    TreeSpec,
    decode_dense,
    encode,
    encoded_nbytes,
    pack_tree,
    tree_packed_nnz,
)
from repro.utils.tree import tree_index, tree_ones_like

PyTree = Any


class ModelStore:
    """Per-user packed personalized models + slot-pool LRU cache.

    ``base_params`` is the shared dense base: served (with an all-ones
    mask) to users without a stored delta, and the template the message
    schema (``TreeSpec``) is derived from — every user's delta must share
    its tree structure and leaf shapes.
    """

    def __init__(self, base_params: PyTree, cache_size: int = 32,
                 payload_dtype=np.float32):
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.base = base_params
        self.cache_size = int(cache_size)
        self.payload_dtype = np.dtype(payload_dtype)
        self.spec = TreeSpec.from_tree(base_params, dtype=self.payload_dtype)
        self._frames: dict[int, bytes] = {}
        self._nnz: dict[int, int] = {}
        # slot pool: stacked device buffers; _slot_of is the LRU map
        c = self.cache_size
        self._pool = {
            "params": jax.tree.map(
                lambda x: jnp.zeros((c,) + np.shape(x), np.asarray(x).dtype),
                base_params),
            "masks": jax.tree.map(
                lambda x: jnp.zeros((c,) + np.shape(x), jnp.float32),
                base_params),
        }
        self._slot_of: "OrderedDict[int, int]" = OrderedDict()
        self._free = list(range(c - 1, -1, -1))     # pop() hands out 0,1,...
        self._write = jax.jit(
            lambda pool, slot, new: jax.tree.map(
                lambda buf, x: buf.at[slot].set(x), pool, new),
            donate_argnums=(0,))
        # hit/miss/eviction counters live in the process-wide registry so
        # an exported trace reconciles against them; the attribute API
        # (`store.hits` etc.) is preserved via properties below
        self.obs = CounterSet("serve.store")
        self._c_hits = self.obs.counter("hits")
        self._c_misses = self.obs.counter("misses")
        self._c_evictions = self.obs.counter("evictions")
        self.obs.gauge("resident", fn=lambda: len(self._slot_of))
        self.obs.gauge("bytes_at_rest", fn=self.total_bytes_at_rest)
        # miss-path latency sketch: decode+unpack+slot-write seconds, the
        # cost a cache hit avoids entirely (bounded-memory LogHistogram)
        self.series = SeriesSet("serve.store")
        self._h_miss_s = self.series.histogram("miss_decode_s")
        # per-slot residency: an open wall-clock span per occupied slot
        self._slot_handles: dict[int, Any] = {}

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def put(self, user: int, params: PyTree, mask: Optional[PyTree]) -> int:
        """Encode ``params ⊙ mask`` as the user's at-rest frame; returns its
        size in bytes.  ``mask=None`` stores a dense (all-ones) delta."""
        packed = pack_tree(params, mask, dtype=self.payload_dtype)
        frame = encode(packed)
        assert len(frame) == encoded_nbytes(packed)
        self._frames[user] = frame
        self._nnz[user] = tree_packed_nnz(packed)
        slot = self._slot_of.pop(user, None)        # stale unpacked copy
        if slot is not None:
            self._free.append(slot)
            self._end_residency(slot)
        return len(frame)

    # ------------------------------------------------------------------
    # read path (through the slot-pool LRU cache)
    # ------------------------------------------------------------------
    def _end_residency(self, slot: int) -> None:
        get_tracer().end(self._slot_handles.pop(slot, None))

    def _begin_residency(self, slot: int, user: int) -> None:
        tr = get_tracer()
        if tr.enabled:
            self._slot_handles[slot] = tr.begin(
                f"user:{user}", track=f"slot/{slot}", user=user)

    def acquire(self, user: int) -> int:
        """Slot index of the user's unpacked model, loading it into the
        pool on a miss (evicting the least recently served user if full).
        The returned slot stays valid until ``cache_size - 1`` further
        distinct-user acquires."""
        slot = self._slot_of.get(user)
        if slot is not None:
            self._c_hits.inc()
            self._slot_of.move_to_end(user)
            return slot
        self._c_misses.inc()
        t0 = time.perf_counter()
        with span("store.miss_decode", track="store", user=user) as sp:
            frame = self._frames.get(user)
            if frame is None:
                entry = {"params": self.base,
                         "masks": tree_ones_like(self.base)}
            else:
                # fused single-pass host decode: the serving hot path
                params, masks = decode_dense(frame, self.spec)
                entry = {"params": params, "masks": masks}
                sp.attrs["nbytes"] = len(frame)
            if self._free:
                slot = self._free.pop()
            else:
                _, slot = self._slot_of.popitem(last=False)
                self._c_evictions.inc()
            self._end_residency(slot)
            self._pool = self._write(self._pool, slot, entry)
            self._slot_of[user] = slot
            self._begin_residency(slot, user)
        self._h_miss_s.add(time.perf_counter() - t0)
        return slot

    def get(self, user: int) -> tuple[PyTree, PyTree]:
        """The user's unpacked (dense-masked params, mask) — bit-exact vs
        the training-side ``w ⊙ m``.  Unknown users get the shared base
        with an all-ones mask (cold start)."""
        slot = self.acquire(user)
        return (tree_index(self._pool["params"], slot),
                tree_index(self._pool["masks"], slot))

    @property
    def pool_params(self) -> PyTree:
        """(cache_size, ...) stacked params — the batched launch operand."""
        return self._pool["params"]

    @property
    def pool_masks(self) -> PyTree:
        """(cache_size, ...) stacked masks, aligned with ``pool_params``."""
        return self._pool["masks"]

    def resident(self, user: int) -> bool:
        """True iff the user's unpacked model holds a pool slot right now
        (no counter side effects — the batcher's grouping predicate)."""
        return user in self._slot_of

    def __contains__(self, user: int) -> bool:
        return user in self._frames

    def users(self) -> list[int]:
        return sorted(self._frames)

    # cache counters (registry-backed; attribute API preserved)
    @property
    def hits(self) -> int:
        return int(self._c_hits.value)

    @property
    def misses(self) -> int:
        return int(self._c_misses.value)

    @property
    def evictions(self) -> int:
        return int(self._c_evictions.value)

    def reset_counters(self) -> None:
        self._c_hits.reset()
        self._c_misses.reset()
        self._c_evictions.reset()

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def bytes_at_rest(self, user: int) -> int:
        """Exact at-rest size of the user's frame — equals
        ``codec.encoded_nbytes`` of their packed delta by construction."""
        return len(self._frames[user])

    def total_bytes_at_rest(self) -> int:
        return sum(len(f) for f in self._frames.values())

    def nnz(self, user: int) -> int:
        return self._nnz[user]

    def stats(self) -> dict:
        return {
            "users": len(self._frames),
            "cache_size": self.cache_size,
            "resident": len(self._slot_of),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "bytes_at_rest": self.total_bytes_at_rest(),
        }

    # ------------------------------------------------------------------
    # construction from training artifacts
    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, path: str, cache_size: int = 32,
                        payload_dtype=np.float32,
                        base_params: Optional[PyTree] = None) -> "ModelStore":
        """Load a trained ``RoundEngine``/``ScaleEngine`` archive (written
        by ``engine.save``) into a store: client k's personalized params
        (⊙ mask, when the strategy keeps masks) become user k's delta.

        ``base_params`` defaults to the dense mean of the client models —
        the natural shared base the deltas personalize.
        """
        from repro.checkpoint import load_pytree
        from repro.fl.engine import _unpack

        payload = load_pytree(path, as_jnp=False)
        if "state" not in payload or "params" not in payload["state"]:
            raise ValueError(
                f"{path} is not an engine archive (no state/params)")
        state = _unpack(payload["state"])
        params = state["params"]
        masks = state.get("masks")
        if base_params is None:
            stacked = [np.stack([np.asarray(x) for x in leaves])
                       for leaves in zip(*(jax.tree.leaves(p)
                                           for p in params))]
            treedef = jax.tree.structure(params[0])
            base_params = jax.tree.unflatten(
                treedef, [s.mean(axis=0) for s in stacked])
        store = cls(base_params, cache_size=cache_size,
                    payload_dtype=payload_dtype)
        for k, p in enumerate(params):
            # dispfl-style params are already w ⊙ m; pack gathers at the
            # mask's support, so the stored values are the trained weights
            store.put(k, p, masks[k] if masks is not None else None)
        return store
