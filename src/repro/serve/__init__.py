"""repro.serve — multi-tenant personalized sparse serving plane.

DisPFL trains one personalized sparse model per client; this package is
where those models get *served*.  Three pieces, three contracts:

**Store** (``store.ModelStore``).  Each user's personalized model lives
at rest as a codec-encoded ``PackedSparse`` frame against a shared dense
base — the ``sparse/codec.py`` wire frame IS the at-rest format, so
``store.bytes_at_rest(user) == codec.encoded_nbytes(packed delta)`` byte
for byte, and storage scales with mask density instead of K dense
replicas.  Frame values are the user's trained weights at the mask
support (a replacement delta, not a fp32-lossy residual), so
``store.get(user)`` returns the training-side ``w ⊙ m`` bit-exactly.  The
capacity-bounded LRU cache is a device-resident *slot pool*: stacked
``(cache_size, ...)`` leaves holding the unpacked models of the most
recently served users, with hit/miss/eviction counters.  A miss is one
fused host decode (``sparse.codec.decode_dense``) plus one in-place slot
write; a hit moves zero parameter bytes.  ``resident(user)`` is a
side-effect-free probe for the batcher.

**Batcher** (``batcher.RequestStream``, ``batcher.MicroBatcher``).
Arrivals are fully seed-derived (Zipf-tilted users, exponential gaps on a
virtual clock), so the batch schedule — and therefore the cache's
hit/miss/eviction sequence — is a pure function of (seed, knobs).
Flushes happen when ``max_batch`` requests are pending or the oldest has
waited ``max_wait`` virtual seconds; a flush takes at most one request
per user (a pool slot serves one model per launch), and requests whose
models are already resident in the slot pool launch first.

**Engine** (``engine.ServeEngine``).  One device launch per batch:
request inputs scatter into their models' pool slots and the whole pool
is scored by a backend — ``pallas`` (user-major
``kernels.masked_matmul.batched_masked_matmul`` grid with
scalar-prefetched per-user block masks), ``ref`` (its jnp oracle), or
``vmap`` (any model; bit-exact fp32 vs the per-user loop).  The launch
operand is the pool itself, so shapes are constant, jit compiles once,
and no per-launch parameter restacking happens; p50/p99 latency and
requests/s stream as JSON lines via ``sim.report.MetricsStream``.

CLI: ``python -m repro.launch.serve --users 64 --cache-size 16
--max-batch 8 --requests 256 --backend ref``.
"""
from repro.serve.batcher import Batch, MicroBatcher, Request, RequestStream
from repro.serve.engine import ServeEngine, ServeResult
from repro.serve.model import ArchModel, MLPModel, TaskModel
from repro.serve.store import ModelStore

__all__ = [
    "ArchModel",
    "Batch",
    "MLPModel",
    "MicroBatcher",
    "ModelStore",
    "Request",
    "RequestStream",
    "ServeEngine",
    "ServeResult",
    "TaskModel",
]
