"""``ServeEngine`` — K personalized models answered in one launch.

The serving loop is: micro-batch pending requests (``batcher.py``, one
request per user per launch), acquire each request's personalized model
in the store's slot pool (``store.py`` — misses decode into a slot,
hits move zero parameter bytes), scatter the request inputs to their
models' slots, and score the whole pool with ONE batched forward — for
matmul-pipeline models that is the user-major
``kernels.masked_matmul.batched_masked_matmul`` grid (or its jnp ``ref``
oracle); for arbitrary models it is ``jax.vmap`` over the pool.  The
launch operand IS the device-resident pool, so every launch has the same
(cache_size, ...) shapes and jit compiles exactly once; per-launch host
work is an input scatter, never a parameter restack (restacking K models
per launch is what makes naive batched serving lose to a per-user loop).

Latency accounting, stated plainly: arrivals are *virtual* (seed-derived,
``batcher.RequestStream``) while the launch is *wall-clock* measured end
to end — slot acquisition (including miss decode+unpack), input build and
scatter, and the batched forward.  A request's reported latency is its
virtual queue wait plus the wall service time of its launch — the blend
makes the queueing component reproducible across machines while still
charging real compute.  p50/p99
latency and requests/s stream as JSON lines through
``sim.report.MetricsStream``, the same live-metrics protocol the round
engine and network simulator use.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.obs import VIRTUAL, LogHistogram, SeriesSet, get_tracer, span
from repro.serve.batcher import Batch, MicroBatcher, Request, RequestStream
from repro.serve.store import ModelStore

PyTree = Any


@dataclasses.dataclass
class ServeResult:
    """Latency distributions are DDSketch-style ``LogHistogram`` sketches
    (``repro.obs.series``): bounded memory regardless of request count,
    quantiles within 1% relative error, and the three sketches share one
    bucket grid — so the pointwise ordering latency >= wait survives into
    the reported quantiles exactly."""
    outputs: dict[int, np.ndarray]       # rid -> model output
    latency_ms: LogHistogram             # wait + service, per request
    wait_ms: LogHistogram                # virtual queue wait component
    service_ms: LogHistogram             # wall launch-service component
    summary: dict

    @property
    def p50_ms(self) -> float:
        return self.latency_ms.quantile(0.5)

    @property
    def p99_ms(self) -> float:
        return self.latency_ms.quantile(0.99)


class ServeEngine:
    """Batched multi-tenant serving over a ``ModelStore``.

    ``backend`` picks the batched forward: ``vmap`` (any model; bit-exact
    vs the per-user loop), ``ref`` (jnp batched masked matmul) or
    ``pallas`` (the user-major kernel grid) — the latter two only for
    models exposing a masked-matmul pipeline (``model.backends()``).

    A launch scores the whole slot pool, so a batch can hold at most one
    request per user and at most ``store.cache_size`` requests;
    ``max_batch`` is clamped to the pool size.
    """

    def __init__(self, store: ModelStore, model, backend: str = "vmap",
                 max_batch: int = 8, max_wait: float = 0.005,
                 interpret: bool = True, metrics=None,
                 metrics_every: int = 8):
        if backend not in model.backends():
            raise ValueError(
                f"backend {backend!r} not supported by this model "
                f"(supports {model.backends()})")
        self.store = store
        self.model = model
        self.backend = backend
        self.max_batch = min(int(max_batch), store.cache_size)
        self.max_wait = float(max_wait)
        self.interpret = bool(interpret)
        self.metrics = metrics
        self.metrics_every = int(metrics_every)
        # obs layer 2: engine-lifetime latency sketches + throughput series
        # (each serve() call merges its own sketches in, so the archived
        # snapshot covers every call this engine served)
        self.series = SeriesSet("serve.engine")
        self._epoch = time.perf_counter()

    # ------------------------------------------------------------------
    def _launch(self, reqs: Sequence[Request],
                xs: Optional[list] = None) -> tuple[np.ndarray, float]:
        """Acquire slots, scatter inputs, one pool-wide batched forward.
        Returns (outputs for the requests, wall service seconds — the
        whole launch including miss decodes and the input scatter).
        ``xs`` are the request payloads (built from each request's input
        seed when not given — payload arrival is not serving work, so
        ``serve`` pre-builds them outside the service clock)."""
        if xs is None:
            xs = [self.model.make_input(r.input_seed) for r in reqs]
        t0 = time.perf_counter()
        with span("serve.launch", track="serve", batch=len(reqs)):
            with span("serve.acquire", track="serve"):
                slots = [self.store.acquire(r.user) for r in reqs]
            assert len(set(slots)) == len(slots), \
                "batch holds two requests for one pool slot (same user?)"
            with span("serve.scatter", track="serve"):
                x_pool = np.zeros((self.store.cache_size,) + xs[0].shape,
                                  dtype=xs[0].dtype)
                for s, x in zip(slots, xs):
                    x_pool[s] = x
            with span("serve.forward", track="serve"):
                y = self.model.batched_forward(self.store.pool_params,
                                               self.store.pool_masks, x_pool,
                                               backend=self.backend,
                                               interpret=self.interpret)
                y = np.asarray(jax.block_until_ready(y))
        service_s = time.perf_counter() - t0
        return y[np.asarray(slots)], service_s

    def warmup(self) -> float:
        """One throwaway pool-wide launch (zero inputs, current pool) so
        jit compile time never lands in a request's latency.  Touches no
        slots and no counters.  Returns compile+run seconds."""
        x0 = self.model.make_input(0)
        x_pool = np.zeros((self.store.cache_size,) + x0.shape,
                          dtype=x0.dtype)
        t0 = time.perf_counter()
        y = self.model.batched_forward(self.store.pool_params,
                                       self.store.pool_masks, x_pool,
                                       backend=self.backend,
                                       interpret=self.interpret)
        jax.block_until_ready(y)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request] | RequestStream,
              warmup: bool = True) -> ServeResult:
        warm_s = self.warmup() if warmup else 0.0

        batcher = MicroBatcher(requests, max_batch=self.max_batch,
                               max_wait=self.max_wait,
                               resident=self.store.resident)
        outputs: dict[int, np.ndarray] = {}
        # per-call sketches (bounded memory however many requests stream
        # through); merged into the engine-lifetime set after the loop
        lat_h = LogHistogram()
        wait_h = LogHistogram()
        service_h = LogHistogram()
        service_total = 0.0
        n_batches = 0
        n_served = 0
        t_wall0 = time.perf_counter()
        tr = get_tracer()
        for batch in batcher.batches():
            xs = [self.model.make_input(r.input_seed)
                  for r in batch.requests]
            y, service_s = self._launch(batch.requests, xs)
            service_total += service_s
            n_batches += 1
            n_served += len(batch.requests)
            for i, (req, wait) in enumerate(
                    zip(batch.requests, batch.queue_waits())):
                outputs[req.rid] = y[i]
                lat_h.add(wait * 1e3 + service_s * 1e3)
                wait_h.add(wait * 1e3)
                service_h.add(service_s * 1e3)
                if tr.enabled:
                    # batcher-wait on the request's virtual timeline — the
                    # queueing component of its reported latency
                    tr.add_span("request.wait", req.t_arrival, batch.t_flush,
                                track=f"user/{req.user}", clock=VIRTUAL,
                                rid=req.rid)
            if self.metrics and n_batches % self.metrics_every == 0:
                self.metrics.emit({
                    "event": "serve", "batches": n_batches,
                    "served": n_served,
                    "p50_ms": round(lat_h.quantile(0.5), 3),
                    "p99_ms": round(lat_h.quantile(0.99), 3),
                    "cache_hits": self.store.hits,
                    "cache_misses": self.store.misses,
                })
        wall_s = time.perf_counter() - t_wall0

        st = self.store.stats()
        summary = {
            "event": "summary",
            "backend": self.backend,
            "requests": n_served,
            "batches": n_batches,
            "mean_batch": round(n_served / max(n_batches, 1), 2),
            "p50_ms": round(lat_h.quantile(0.5), 3),
            "p99_ms": round(lat_h.quantile(0.99), 3),
            # honest latency components: queue wait vs launch service
            "p50_wait_ms": round(wait_h.quantile(0.5), 3),
            "p99_wait_ms": round(wait_h.quantile(0.99), 3),
            "p50_service_ms": round(service_h.quantile(0.5), 3),
            "p99_service_ms": round(service_h.quantile(0.99), 3),
            "requests_per_s": round(n_served / max(service_total, 1e-9), 1),
            "service_s": round(service_total, 4),
            "wall_s": round(wall_s, 4),
            "warmup_s": round(warm_s, 4),
            "cache_hit_rate": round(
                st["hits"] / max(st["hits"] + st["misses"], 1), 4),
            **{f"store_{k}": v for k, v in st.items()},
        }
        # fold this call into the engine-lifetime observability surface
        self.series.histogram("latency_ms").merge(lat_h)
        self.series.histogram("wait_ms").merge(wait_h)
        self.series.histogram("service_ms").merge(service_h)
        tw = time.perf_counter() - self._epoch
        self.series.series("requests", kind="counter").observe(
            tw, self.series.histogram("latency_ms").count)
        self.series.series("requests_per_s").observe(
            tw, summary["requests_per_s"])
        if self.metrics:
            self.metrics.emit(summary)
        return ServeResult(outputs=outputs, latency_ms=lat_h,
                           wait_ms=wait_h, service_ms=service_h,
                           summary=summary)
