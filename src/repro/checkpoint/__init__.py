from repro.checkpoint.npz import load_pytree, save_pytree, save_clients, load_clients  # noqa: F401
from repro.checkpoint.packed import decode_packed, encode_packed  # noqa: F401
