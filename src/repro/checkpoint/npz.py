"""Checkpointing: pytrees <-> .npz archives (no external deps).

Leaves are stored flat under their '/'-joined key paths; structure is
reconstructed on load from the paths, so any nested-dict pytree round-trips.
Per-client personalized models (params + masks) are stored one file per
client under a directory.
"""
from __future__ import annotations

import os
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_leaves_with_path

PyTree = Any


def save_pytree(path: str, tree: PyTree) -> None:
    flat = {p: np.asarray(x) for p, x in tree_leaves_with_path(tree)}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def _insert(root: dict, keys: list[str], value) -> None:
    cur = root
    for k in keys[:-1]:
        cur = cur.setdefault(k, {})
    cur[keys[-1]] = value


def load_pytree(path: str, as_jnp: bool = True) -> PyTree:
    with np.load(path) as z:
        root: dict = {}
        for key in z.files:
            val = z[key]
            if as_jnp:
                val = jnp.asarray(val)
            _insert(root, key.split("/"), val)
    return root


def save_clients(dirpath: str, states: list[dict]) -> None:
    os.makedirs(dirpath, exist_ok=True)
    for k, st in enumerate(states):
        save_pytree(os.path.join(dirpath, f"client_{k:04d}.npz"), st)


def load_clients(dirpath: str) -> list[PyTree]:
    files = sorted(f for f in os.listdir(dirpath) if f.endswith(".npz"))
    return [load_pytree(os.path.join(dirpath, f)) for f in files]
