"""Checkpointing sparse message payloads: PackedSparse <-> plain arrays.

``repro.checkpoint.npz`` stores pytrees of *arrays*; an in-flight simulator
message, however, is a tree of ``PackedSparse`` leaves (uint32 bitmap + nnz
values + a static dense shape).  ``encode_packed`` rewrites every
``PackedSparse`` into a marked plain-dict so the tree survives the
flat-path .npz round trip; ``decode_packed`` is the exact inverse.  The
bitmap and value arrays are stored verbatim — no re-quantization, no
re-packing — so a resumed simulation mixes bit-identical payloads.

This is what lets ``SimEngine.save`` persist a *mid-run* asynchronous
simulation: the pending event queue and per-client inboxes hold exactly
these payload trees.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.packed import PackedSparse, _is_packed

PyTree = Any

_PACKED_KEY = "__packed_sparse__"


def encode_packed(tree: PyTree) -> PyTree:
    """Replace every ``PackedSparse`` leaf with a marked plain-array dict
    (checkpointable); non-packed leaves pass through untouched."""

    def enc(x):
        if _is_packed(x):
            return {_PACKED_KEY: {
                "bitmap": np.asarray(x.bitmap),
                "values": np.asarray(x.values),
                "shape": np.asarray(x.shape, dtype=np.int64),
            }}
        return x

    return jax.tree.map(enc, tree, is_leaf=_is_packed)


def _is_marker(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {_PACKED_KEY}


def decode_packed(tree: PyTree) -> PyTree:
    """Inverse of ``encode_packed`` (bitmap/values restored verbatim)."""

    def dec(x):
        if _is_marker(x):
            d = x[_PACKED_KEY]
            return PackedSparse(
                bitmap=jnp.asarray(np.asarray(d["bitmap"], dtype=np.uint32)),
                values=jnp.asarray(d["values"]),
                shape=tuple(int(s) for s in np.asarray(d["shape"])))
        return x

    return jax.tree.map(dec, tree, is_leaf=_is_marker)
