"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names
(``constrain(x, ("client", "batch", "seq", "embed"))``).  A context manager
installs a mesh + logical->mesh rules; outside any context the annotations
are no-ops, so the same model code runs in the CPU simulator and in the
multi-pod dry-run unchanged.

Default rules (see DESIGN.md §5):
  client -> ('pod','data')   stacked personalized models
  batch  -> 'data' (only when there is no client axis)
  expert -> 'model'
  heads/kv_heads/ffn/vocab -> 'model'
  kv_seq -> 'data' for long-context decode (context parallelism)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def axis_rules(mesh: Mesh, overrides: dict | None = None) -> dict:
    names = set(mesh.axis_names)
    has_pod = "pod" in names
    client = ("pod", "data") if has_pod else ("data",)
    rules = {
        "client": client,
        "batch": (),                 # per-client batch: sharded via inputs
        "batch_noshard": (),
        "seq": (),
        "kv_seq": (),                # ('data',) override for long-context K=1
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
        "ffn": ("model",),
        "expert": ("model",),
        "expert_cap": (),
        "vocab": ("model",),
        "conv": (),
        "fsdp": ("data",),           # 2-D weight sharding for K=1 giants
        "state": (),
        None: (),
    }
    if overrides:
        rules.update(overrides)
    return rules


def _spec_for(names: Sequence[Optional[str]], rules: dict) -> P:
    parts = []
    for n in names:
        mapped = rules.get(n, ())
        if not mapped:
            parts.append(None)
        elif len(mapped) == 1:
            parts.append(mapped[0])
        else:
            parts.append(tuple(mapped))
    return P(*parts)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, overrides: dict | None = None):
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", None)
    _state.mesh = mesh
    _state.rules = axis_rules(mesh, overrides)
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a context."""
    rules = _rules()
    mesh = current_mesh()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(names):
        raise ValueError(f"rank mismatch: {x.shape} vs {names}")
    spec = _spec_for(names, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def logical_sharding(mesh: Mesh, names: Sequence[Optional[str]],
                     overrides: dict | None = None) -> NamedSharding:
    """NamedSharding for input/output shardings outside a context."""
    return NamedSharding(mesh, _spec_for(names, axis_rules(mesh, overrides)))
