from repro.sharding.ctx import (  # noqa: F401
    axis_rules,
    constrain,
    current_mesh,
    logical_sharding,
    use_mesh_rules,
)
