"""Parameter / cache / batch sharding rules for the production meshes.

Layout summary (DESIGN.md §5):

* Stacked-client dim (K>1): sharded over ('pod','data') / ('data',).
* Tensor-parallel 'model' axis on: qkv out dim, o-proj in dim, ffn hidden,
  vocab, expert dim, ssm inner projections, cache head_dim.
* K==1 giants (jamba) additionally shard the non-'model' matrix dim over
  'data' (2-D FSDP+TP); the client dim (size 1, or 'pod' on the 2-pod mesh)
  still leads every leaf so the step function is uniform across archs.
* KV caches shard head_dim over 'model' (always divisible: 64/128/256);
  long-context K==1 decode additionally shards cache seq over 'data'
  (context parallelism).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import tree_map_with_path

PyTree = Any

# path fragments whose 2-D matrices are (sharded_in, out) rather than
# (in, sharded_out)
_ROW_SHARDED = ("wo/w", "w_down", "out_proj", "head/w")
_REPLICATED = ("norm", "gn", "A_log", "/D", "dt_bias", "enc_pos", "router",
               "conv_b")


def _is_replicated(path: str) -> bool:
    return any(k in path for k in _REPLICATED) or path.endswith("/b")


def _client_axes(mesh: Mesh, fsdp2d: bool = False,
                 k: Optional[int] = None) -> Optional[tuple]:
    """Mesh axes carrying the stacked client dim.  FSDP2D archs put clients
    on 'pod' only ('data' is the FSDP axis); on a single-pod mesh their
    client dim has size 1 and stays unsharded.  When ``k`` (the actual
    leading-dim size) is given, the axes are trimmed until they divide it
    (K=1 long-context decode on the multi-pod mesh stays unsharded)."""
    if fsdp2d:
        axes = ("pod",) if "pod" in mesh.axis_names else None
    else:
        axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if axes is None or k is None:
        return axes
    while axes:
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if k >= size and k % size == 0:
            return axes
        axes = axes[:-1]
    return None


def _fits(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh.shape[axis] == 0 and dim >= mesh.shape[axis]


def param_spec(path: str, shape: tuple, mesh: Mesh, fsdp2d: bool,
               stacked: bool = True) -> P:
    """PartitionSpec for one (client-stacked) parameter leaf."""
    client = _client_axes(mesh, fsdp2d, shape[0] if stacked else None)
    body = shape[1:] if stacked else shape
    lead = [client if stacked else None]
    fsdp = "data" if fsdp2d else None

    def dims() -> list:
        d = len(body)
        # vectors / norms / biases / routers / conv params stay replicated
        if _is_replicated(path) or d <= 1:
            return [None] * d
        # stacked scan-block leaves have a leading n_blocks dim
        if "/moe/" in path and "shared" not in path and d >= 3:
            # (blocks?, E, d1, d2): expert dim over model, d1 over fsdp
            pre = [None] * (d - 3)
            e_ok = _fits(body[d - 3], mesh, "model")
            return pre + ["model" if e_ok else None,
                          fsdp if fsdp and _fits(body[d - 2], mesh, "data") else None,
                          None]
        if "embed/table" in path:
            return [("model" if _fits(body[0], mesh, "model") else None),
                    (fsdp if fsdp and _fits(body[1], mesh, "data") else None)]
        pre = [None] * (d - 2)
        r, c = body[-2], body[-1]
        if any(k in path for k in _ROW_SHARDED):
            return pre + [("model" if _fits(r, mesh, "model") else None),
                          (fsdp if fsdp and _fits(c, mesh, "data") else None)]
        if "conv_w" in path:
            return pre + [None, ("model" if _fits(c, mesh, "model") else None)]
        return pre + [(fsdp if fsdp and _fits(r, mesh, "data") else None),
                      ("model" if _fits(c, mesh, "model") else None)]

    spec = (lead + dims()) if stacked else dims()
    return P(*spec)


def cache_spec(path: str, shape: tuple, mesh: Mesh, seq_data: bool,
               stacked: bool = True, fsdp2d: bool = False) -> P:
    """KV/SSM cache leaves.  shape (K, [blocks,] B, S, h, dh) for kv,
    (K, [blocks,] B, H, Pd, N) for ssm_state, (K, [blocks,] B, W, C) conv."""
    client = _client_axes(mesh, fsdp2d, shape[0] if stacked else None)
    body = list(shape[1:] if stacked else shape)
    d = len(body)
    lead = [client if stacked else None]

    def dims() -> list:
        if path.endswith("/k") or path.endswith("/v"):
            pre = [None] * (d - 4)
            seq = "data" if seq_data else None
            dh = "model" if _fits(body[-1], mesh, "model") else None
            return pre + [None, seq, None, dh]
        if "ssm_state" in path:
            pre = [None] * (d - 4)
            h = "model" if _fits(body[-3], mesh, "model") else None
            return pre + [None, h, None, None]
        if "conv_state" in path:
            pre = [None] * (d - 3)
            c = "model" if _fits(body[-1], mesh, "model") else None
            return pre + [None, None, c]
        return [None] * d

    spec = (lead + dims()) if stacked else dims()
    return P(*spec)


def batch_spec(path: str, shape: tuple, mesh: Mesh, fsdp2d: bool = False) -> P:
    """Stacked input leaves (K, B, ...): client dim over its axes; for
    FSDP2D archs the per-client batch dim rides 'data' when divisible."""
    client = _client_axes(mesh, fsdp2d, shape[0])
    rest = [None] * (len(shape) - 1)
    if fsdp2d and len(shape) >= 2 and shape[1] % mesh.shape["data"] == 0 \
            and shape[1] >= mesh.shape["data"]:
        rest[0] = "data"
    return P(*([client] + rest))


def stacked_spec(shape: tuple, mesh: Mesh, fsdp2d: bool = False) -> P:
    """Client-dim-only PartitionSpec for a stacked (K-leading) leaf.

    This is the layout of ``repro.scale`` state and batches: the leading K
    dim rides the client axes (trimmed until they divide K), every other
    dim stays unsharded — per-client tensors are small; it is the *count*
    of clients that scales.  Contrast ``param_spec``, which additionally
    TP/FSDP-shards the body dims for the giant-arch plans."""
    client = _client_axes(mesh, fsdp2d, shape[0] if shape else None)
    return P(*([client] + [None] * (len(shape) - 1)))


def stacked_sharding(shape: tuple, mesh: Mesh,
                     fsdp2d: bool = False) -> NamedSharding:
    return NamedSharding(mesh, stacked_spec(shape, mesh, fsdp2d))


def tree_stacked_shardings(tree: PyTree, mesh: Mesh,
                           fsdp2d: bool = False) -> PyTree:
    """Shardings for a whole stacked state pytree (params/masks/opt-state
    with a leading K dim) — the ``repro.scale`` engine's state layout."""
    return jax.tree.map(
        lambda x: stacked_sharding(tuple(x.shape), mesh, fsdp2d), tree)


def tree_param_shardings(tree: PyTree, mesh: Mesh, fsdp2d: bool,
                         stacked: bool = True) -> PyTree:
    return tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_spec(p, tuple(x.shape), mesh,
                                                    fsdp2d, stacked)), tree)


def tree_cache_shardings(tree: PyTree, mesh: Mesh, seq_data: bool,
                         stacked: bool = True, fsdp2d: bool = False) -> PyTree:
    return tree_map_with_path(
        lambda p, x: NamedSharding(mesh, cache_spec(p, tuple(x.shape), mesh,
                                                    seq_data, stacked, fsdp2d)),
        tree)


def tree_batch_shardings(tree: PyTree, mesh: Mesh, fsdp2d: bool = False) -> PyTree:
    return tree_map_with_path(
        lambda p, x: NamedSharding(
            mesh,
            batch_spec(p, tuple(x.shape), mesh, fsdp2d)
            if len(x.shape) > 0 else P()),
        tree)
