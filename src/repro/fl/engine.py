"""Composable round engine: Strategy lifecycle hooks + streaming rounds.

Every federated strategy in the zoo shares the same skeleton — sample a
topology, mix with neighbors, run local SGD, (maybe) evolve masks, evaluate
on a cadence, account comm/FLOPs.  The seed code repeated that skeleton in
seven monolithic ``run_*`` loops; here it lives once, in ``RoundEngine``,
and a strategy is just the five-ish hooks that differ:

    class MyStrategy(StrategyBase):
        def init_state(self, task, clients, cfg) -> dict: ...
        def mix(self, state, ctx): ...                 # communication phase
        def local_update(self, state, k, ctx): ...     # client k's local phase
        def evolve(self, state, k, ctx): ...           # optional mask search
        def finalize_eval_params(self, state): ...     # what to evaluate

plus per-round accounting (``round_comm`` / ``round_flops``) so the paper's
tables come from the *actual* per-round adjacency and mask nnz rather than a
round-0 snapshot.

The engine *streams*: ``engine.rounds()`` is an iterator of ``RoundMetrics``
(mean/std personalized acc, this round's busiest-node comm, cumulative
FLOPs, lr, prune rate), which makes live dashboards, early stopping and
mid-run checkpointing natural.  ``engine.run()`` drains the iterator and
returns the familiar ``FLResult``.

Determinism: all randomness is derived from ``(cfg.seed, round, client)``
via ``np.random.SeedSequence`` — no shared generator threads through the
loop — so results are independent of client iteration order and a resumed
run is bit-identical to an uninterrupted one.

Fast path: for homogeneous-density clients, the local phase is executed as
one jitted ``jax.vmap``-over-clients ``lax.scan`` instead of a Python loop
over K clients (``local_exec="vmap"`` or ``"auto"``); batch orders are
drawn from the same per-client generators, ragged step counts are padded
and masked, and momentum travels as stacked per-client optimizer state —
so the schedule and update rule match the per-client loop exactly.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import CommReport, FlopsReport, centralized_comm
from repro.core.evolve import cosine_prune_rate
from repro.core.topology import make_adjacency
from repro.fl.base import (
    FLConfig,
    FLResult,
    Task,
    _pad_order,
    evaluate_clients,
    local_sgd,
    rounds_to_targets,
)
from repro.models.common import softmax_xent
from repro.obs import CounterSet, SeriesSet, span
from repro.optim import SGDConfig, masked_sgd_step, sgd_step
from repro.sparse import pack_tree, unpack_mask_tree, unpack_tree
from repro.utils.tree import tree_index, tree_nnz, tree_size, tree_stack

PyTree = Any

# rng sub-streams (the last SeedSequence word); disjoint per use so adding a
# draw to one phase never perturbs another
STREAM_CLIENT = 0       # per-(round, client) training randomness
STREAM_ROUND = 1        # per-round strategy randomness (client selection)
STREAM_EVAL = 2         # per-(round, client) eval-time fine-tuning


def derive_rng(seed: int, round_idx: int, k: int = 0,
               stream: int = STREAM_CLIENT) -> np.random.Generator:
    """Order-independent generator for (seed, round, client, stream)."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, round_idx, k, stream]))


# ---------------------------------------------------------------------------
# Round context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundCtx:
    """Everything a hook may need about the current round.

    Generators returned by ``client_rng``/``round_rng``/``eval_rng`` are
    cached for the round, so successive hook calls for the same client
    continue one deterministic stream (mix draws, then local-phase draws,
    then evolve draws).
    """
    t: int
    cfg: FLConfig
    task: Task
    clients: Sequence[Any]
    lr: float
    prune_rate: float
    adjacency: np.ndarray
    _rngs: dict = dataclasses.field(default_factory=dict, repr=False)

    def _rng(self, k: int, stream: int) -> np.random.Generator:
        key = (k, stream)
        if key not in self._rngs:
            self._rngs[key] = derive_rng(self.cfg.seed, self.t, k, stream)
        return self._rngs[key]

    def client_rng(self, k: int) -> np.random.Generator:
        return self._rng(k, STREAM_CLIENT)

    def round_rng(self) -> np.random.Generator:
        return self._rng(0, STREAM_ROUND)

    def eval_rng(self, k: int) -> np.random.Generator:
        return self._rng(k, STREAM_EVAL)


# ---------------------------------------------------------------------------
# Strategy protocol
# ---------------------------------------------------------------------------


class StrategyBase:
    """Default hook implementations; subclass and override what differs.

    ``init_state`` must return the *mutable, checkpointable* state: a pytree
    of arrays (nested dicts / lists).  Static derived quantities (ERK
    budgets, fixed masks, client sizes) belong on ``self`` — they are
    re-derived by ``init_state`` on resume, so checkpoints stay small and
    list/dict round-tripping stays trivial.
    """

    name: str = "strategy"
    #: engine may execute the local phase as vmap-over-clients when True
    vmap_capable: bool = False
    #: True iff ``mix`` communicates peer-to-peer over ``ctx.adjacency`` —
    #: the contract the network simulator (repro.sim) measures; server-based
    #: and local-only strategies leave this False
    decentralized: bool = False

    # -- lifecycle ---------------------------------------------------------
    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        self.task, self.clients, self.cfg = task, clients, cfg
        self.opt = SGDConfig(momentum=cfg.momentum,
                             weight_decay=cfg.weight_decay)
        self.n_samples = int(np.mean([c.n_train for c in clients]))
        return {}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        """Communication phase: gossip / server aggregation / selection."""

    def active_clients(self, state: dict, ctx: RoundCtx) -> Sequence[int]:
        """Clients that run a local phase this round (default: all)."""
        return range(len(self.clients))

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        raise NotImplementedError

    def evolve(self, state: dict, k: int, ctx: RoundCtx) -> None:
        """Optional per-client mask search after the local phase."""

    def post_round(self, state: dict, ctx: RoundCtx) -> None:
        """Optional aggregation after all clients finished (e.g. FedAvg)."""

    # -- evaluation --------------------------------------------------------
    def eval_params(self, state: dict, ctx: RoundCtx) -> list[PyTree]:
        return state["params"]

    def finalize_eval_params(self, state: dict) -> list[PyTree]:
        return state["params"]

    # -- accounting --------------------------------------------------------
    def round_comm(self, state: dict, ctx: RoundCtx) -> CommReport:
        return centralized_comm(0, [0], 1)

    def round_flops(self, state: dict, ctx: RoundCtx) -> FlopsReport:
        raise NotImplementedError

    # -- density telemetry (obs layer 2: measured vs scheduled sparsity) ---
    def measured_density(self, state: dict) -> Optional[float]:
        """Fleet-mean *measured* mask density (nnz / size over every
        client's mask), or None for strategies without masks."""
        masks = state.get("masks") if isinstance(state, dict) else None
        if not masks or masks[0] is None:
            return None
        nnz = sum(tree_nnz(m) for m in masks)
        size = sum(tree_size(m) for m in masks)
        return float(nnz) / float(size) if size else None

    def target_density(self, t: int) -> Optional[float]:
        """Fleet-mean *scheduled* density at round ``t``: the anneal
        schedule when the strategy has one (``density_at``), the static
        per-client config densities otherwise.  The gap between this and
        ``measured_density`` is the drift ``repro.obs.health`` watches."""
        cfg = getattr(self, "cfg", None)
        if cfg is None:
            return None
        if hasattr(self, "density_at"):
            return float(np.mean([self.density_at(t, k)
                                  for k in range(cfg.n_clients)]))
        return float(np.mean([cfg.client_density(k)
                              for k in range(cfg.n_clients)]))

    # -- vmap fast-path adapters ------------------------------------------
    def local_epochs(self, state: dict, ctx: RoundCtx) -> int:
        return ctx.cfg.local_epochs

    def local_params(self, state: dict, k: int) -> PyTree:
        return state["params"][k]

    def local_mask(self, state: dict, k: int) -> Optional[PyTree]:
        return None

    def set_local(self, state: dict, k: int, params: PyTree) -> None:
        state["params"][k] = params

    def set_local_mask(self, state: dict, k: int, mask: PyTree) -> None:
        if mask is not None and "masks" in state:
            state["masks"][k] = mask

    # -- per-message payload (used by repro.sim for bytes-on-wire) ---------
    def message_nnz(self, state: dict, k: int) -> int:
        """Values client k actually puts on the wire: its mask's nnz, or the
        full coordinate count for dense strategies."""
        mask = self.local_mask(state, k)
        if mask is not None:
            return tree_nnz(mask)
        return tree_size(self.local_params(state, k))

    def message_coords(self, state: dict, k: int) -> int:
        return tree_size(self.local_params(state, k))

    def snapshot_message(self, state: dict, k: int) -> dict:
        """What k transmits right now: a ``repro.sparse`` packed tree —
        bitmap + nnz values, never the dense pytree.  Dense strategies pack
        against an all-ones bitmap, so one wire format serves the whole zoo
        (``sim.links.measure_payload`` sizes it via the codec)."""
        return {"packed": pack_tree(self.local_params(state, k),
                                    self.local_mask(state, k))}

    def install_message(self, state: dict, k: int, msg: dict) -> None:
        """Write a received message into slot k (the simulator swaps these in
        temporarily so ``mix`` sees arrived — possibly stale — models)."""
        if "packed" in msg:
            self.set_local(state, k, unpack_tree(msg["packed"]))
            self.set_local_mask(state, k, unpack_mask_tree(msg["packed"]))
        else:
            self.set_local(state, k, msg["params"])
            self.set_local_mask(state, k, msg["mask"])

    def mix_one(self, state: dict, k: int, senders: dict[int, dict],
                ctx: RoundCtx) -> None:
        """Mix client k against the payloads that have *arrived* (the async
        simulator's per-activation communication hook).

        Generic fallback: swap the payloads into their slots, run the full
        ``mix`` on an adjacency whose only non-identity row is k's, keep
        only k's mixed model — correct for any strategy, but O(K) tree work
        per activation.  Decentralized strategies override it with packed
        O(degree)-fold implementations (``repro.sparse.ops``) whose cost
        tracks node degree, never K."""
        if not senders:
            # gossip self-mix is the identity (dispfl: re-masking an
            # already-masked model; dpsgd: W[k,k]=1) — skip the O(K) mix
            return
        saved_params = list(state["params"])
        saved_masks = list(state["masks"]) if "masks" in state else None
        for j, payload in senders.items():
            self.install_message(state, j, payload)
        self.mix(state, ctx)
        mixed_k = state["params"][k]
        state["params"] = saved_params
        state["params"][k] = mixed_k
        if saved_masks is not None:
            saved_masks[k] = state["masks"][k]
            state["masks"] = saved_masks


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, tuple[type, dict]] = {}


def register(name: str, **defaults):
    """Class decorator: ``@register("dpsgd_ft", finetune=True)``.

    One class may be registered under several names with different
    constructor defaults (the ``*_ft`` variants).
    """

    def deco(cls):
        _REGISTRY[name] = (cls, dict(defaults))
        return cls

    return deco


def strategy_names() -> list[str]:
    _ensure_zoo()
    return sorted(_REGISTRY)


def make_strategy(name: str, **overrides) -> StrategyBase:
    _ensure_zoo()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown strategy '{name}'; available: {sorted(_REGISTRY)}")
    cls, defaults = _REGISTRY[name]
    strat = cls(**{**defaults, **overrides})
    strat.name = name
    return strat


def _ensure_zoo() -> None:
    """Import the built-in strategy modules so their @register calls run."""
    import repro.fl.centralized  # noqa: F401
    import repro.fl.decentralized  # noqa: F401
    import repro.fl.dispfl  # noqa: F401
    import repro.fl.partial  # noqa: F401


# ---------------------------------------------------------------------------
# Streaming metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundMetrics:
    round: int                       # 0-based round index
    lr: float
    prune_rate: float
    comm_busiest_mb: float           # this round, from the current adjacency
    comm_rows: dict
    flops_round: float               # per client, this round
    cum_flops: float                 # per client, cumulative
    acc_mean: Optional[float]        # None on non-eval rounds
    acc_std: Optional[float]
    wall_s: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------


class Callback:
    def on_round_end(self, engine: "RoundEngine", metrics: RoundMetrics) -> None:
        pass

    def on_run_end(self, engine: "RoundEngine") -> None:
        pass


class JsonlLogger(Callback):
    """Append one JSON object per round to ``path``.

    The file is truncated only when a run starts from round 0, so a resumed
    run keeps the rounds streamed before the checkpoint."""

    def __init__(self, path: str):
        self.path = path
        import os
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)

    def on_round_end(self, engine, metrics):
        mode = "w" if metrics.round == 0 else "a"
        with open(self.path, mode) as f:
            f.write(json.dumps(metrics.to_dict()) + "\n")


class Checkpointer(Callback):
    """Save the full engine state every ``every`` rounds (and at run end)."""

    def __init__(self, path: str, every: int = 1):
        self.path = path
        self.every = max(1, every)

    def on_round_end(self, engine, metrics):
        if (metrics.round + 1) % self.every == 0:
            engine.save(self.path)

    def on_run_end(self, engine):
        engine.save(self.path)


class EarlyStopAtTarget(Callback):
    """Stop the run once mean personalized accuracy reaches ``target``."""

    def __init__(self, target: float):
        self.target = target

    def on_round_end(self, engine, metrics):
        if metrics.acc_mean is not None and metrics.acc_mean >= self.target:
            engine.request_stop()


# ---------------------------------------------------------------------------
# Checkpoint packing: lists <-> marked dicts so np.savez paths round-trip
# ---------------------------------------------------------------------------

_LIST_KEY = "__list__"


def _pack(tree):
    if isinstance(tree, dict):
        return {k: _pack(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {_LIST_KEY: {f"{i:06d}": _pack(v) for i, v in enumerate(tree)}}
    return tree


def _unpack(tree):
    if isinstance(tree, dict):
        if set(tree.keys()) == {_LIST_KEY}:
            inner = tree[_LIST_KEY]
            return [_unpack(inner[k]) for k in sorted(inner)]
        return {k: _unpack(v) for k, v in tree.items()}
    return tree


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class RoundEngine:
    """Owns the round loop for any ``Strategy``.

    Usage::

        engine = RoundEngine(make_strategy("dispfl"), task, clients, cfg)
        for m in engine.rounds():         # streams RoundMetrics
            print(m.round, m.acc_mean)
        result = engine.result()          # FLResult (paper tables)

    or simply ``engine.run()``.  ``local_exec``:

    * ``"loop"`` — per-client Python loop (the reference semantics),
    * ``"vmap"`` — force the stacked jax.vmap local phase (errors if the
      strategy/config cannot take it),
    * ``"auto"`` — vmap when the strategy is vmap-capable, densities are
      homogeneous and all active clients agree on an effective batch size
      (momentum rides along as stacked optimizer state); loop otherwise.
    """

    def __init__(self, strategy: StrategyBase, task: Task, clients,
                 cfg: FLConfig, callbacks: Sequence[Callback] = (),
                 local_exec: str = "auto"):
        if local_exec not in ("auto", "loop", "vmap"):
            raise ValueError(f"local_exec must be auto|loop|vmap, got {local_exec}")
        self.strategy = strategy
        self.task = task
        self.clients = clients
        self.cfg = cfg
        self.callbacks = list(callbacks)
        self.local_exec = local_exec
        self.state = strategy.init_state(task, clients, cfg)
        # metric accumulators (restored by `restore`)
        self._next_round = 0
        self._stop = False
        self._acc_history: list[float] = []
        self._acc_stds: list[float] = []
        self._eval_rounds: list[int] = []
        self._comm: dict[str, list[float]] = {
            "busiest_mb": [], "avg_per_node_mb": [], "total_mb": [],
            "busiest_mb_with_bitmap": []}
        self._flops: dict[str, list[float]] = {
            "per_round_flops": [], "dense_per_round_flops": [],
            "fwd_flops_per_sample": []}
        self._vmap_fns: dict[bool, Callable] = {}
        self.obs = CounterSet("fl.engine")
        self.obs.gauge("rounds_completed", fn=lambda: self._next_round)
        self.obs.gauge("cum_flops", fn=lambda: float(
            np.sum(self._flops["per_round_flops"])))
        self.obs.gauge("comm_total_mb", fn=lambda: float(
            np.sum(self._comm["total_mb"])))
        # obs layer 2: per-round wall-clock time series (not checkpointed —
        # a resumed run restarts its series; the counters above stay the
        # reconciliation source of truth)
        self.series = SeriesSet("fl.engine")
        self._series_epoch = time.perf_counter()

    # -- control -----------------------------------------------------------
    def request_stop(self) -> None:
        self._stop = True

    # -- checkpointing -----------------------------------------------------
    def _checkpoint_payload(self) -> dict:
        """The full serializable engine state (subclasses extend it — the
        network simulator adds its virtual timeline under a "sim" key)."""
        return {
            "engine": {
                "next_round": np.asarray(self._next_round, np.int64),
                "acc_history": np.asarray(self._acc_history, np.float64),
                "acc_stds": np.asarray(self._acc_stds, np.float64),
                "eval_rounds": np.asarray(self._eval_rounds, np.int64),
                "comm": {k: np.asarray(v, np.float64)
                         for k, v in self._comm.items()},
                "flops": {k: np.asarray(v, np.float64)
                          for k, v in self._flops.items()},
            },
            "state": _pack(self.state),
        }

    def save(self, path: str) -> None:
        from repro.checkpoint import save_pytree
        save_pytree(path, self._checkpoint_payload())

    def _restore_payload(self, payload: dict) -> None:
        eng = payload["engine"]
        self._next_round = int(eng["next_round"])
        self._acc_history = [float(a) for a in np.asarray(eng["acc_history"])]
        self._acc_stds = [float(a) for a in np.asarray(eng["acc_stds"])]
        self._eval_rounds = [int(r) for r in np.asarray(eng["eval_rounds"])]
        self._comm = {k: [float(x) for x in np.asarray(v)]
                      for k, v in eng["comm"].items()}
        self._flops = {k: [float(x) for x in np.asarray(v)]
                       for k, v in eng["flops"].items()}
        self.state = jax.tree.map(jnp.asarray, _unpack(payload["state"]))

    def restore(self, path: str) -> "RoundEngine":
        """Load a checkpoint written by ``save``; resumes bit-identically
        (all rng is derived from (seed, round, client), never carried).

        The archive is loaded as numpy (float64 metric histories and the
        simulator's virtual timeline must round-trip exactly; a jnp detour
        would truncate them to float32 under the x32 default) and only the
        strategy state is moved to device arrays."""
        from repro.checkpoint import load_pytree
        self._restore_payload(load_pytree(path, as_jnp=False))
        return self

    # -- the round loop ----------------------------------------------------
    def _make_ctx(self, t: int, alive: Optional[np.ndarray] = None) -> RoundCtx:
        cfg = self.cfg
        return RoundCtx(
            t=t, cfg=cfg, task=self.task, clients=self.clients,
            lr=cfg.lr_at(t),
            prune_rate=cosine_prune_rate(cfg.alpha0, t, cfg.rounds),
            adjacency=make_adjacency(cfg.topology, len(self.clients), t,
                                     cfg.degree, cfg.seed, cfg.drop_prob,
                                     alive=alive))

    # hooks for subclasses (the event simulator times each round without
    # perturbing the reference semantics below)
    def _pre_round(self, ctx: RoundCtx) -> None:
        """Called after the ctx is built, before any hook runs."""

    def _finish_metrics(self, ctx: RoundCtx, metrics: RoundMetrics) -> RoundMetrics:
        """Last chance to decorate the round's metrics before callbacks."""
        return metrics

    def _sample_series(self, metrics: RoundMetrics) -> None:
        """Sample the wall-clock engine series after one round.  Counter-kind
        series record the *cumulative* accumulator values, so their
        telescoping delta sums reconcile exactly with the ``fl.engine``
        gauges in ``snapshot_counters()``."""
        tw = time.perf_counter() - self._series_epoch
        ss = self.series
        ss.series("round_wall_s").observe(tw, metrics.wall_s)
        ss.series("comm_total_mb", kind="counter").observe(
            tw, float(np.sum(self._comm["total_mb"])))
        ss.series("cum_flops", kind="counter").observe(tw, metrics.cum_flops)
        if metrics.acc_mean is not None:
            ss.series("acc_mean").observe(tw, metrics.acc_mean)
        dm = self.strategy.measured_density(self.state)
        if dm is not None:
            ss.series("density_measured").observe(tw, dm)
            dt_ = self.strategy.target_density(metrics.round)
            if dt_ is not None:
                ss.series("density_target").observe(tw, dt_)

    def run_local_phase(self, ctx: RoundCtx, active: Sequence[int]) -> None:
        """Execute the local phase for ``active`` clients — the reusable unit
        the simulator invokes per client (``active=[k]``) or per round."""
        active = list(active)
        if self._use_vmap(ctx, active):
            self._vmap_local_phase(ctx, active)
        else:
            for k in active:
                self.strategy.local_update(self.state, k, ctx)

    def _run_one_round(self, t: int) -> RoundMetrics:
        cfg = self.cfg
        strat = self.strategy
        t0 = time.perf_counter()
        ctx = self._make_ctx(t)
        self._pre_round(ctx)
        with span("round.mix", track="engine", round=t):
            strat.mix(self.state, ctx)
        active = list(strat.active_clients(self.state, ctx))
        with span("round.local", track="engine", round=t,
                  active=len(active)):
            self.run_local_phase(ctx, active)
        with span("round.evolve", track="engine", round=t):
            for k in active:
                strat.evolve(self.state, k, ctx)
            strat.post_round(self.state, ctx)

        comm = strat.round_comm(self.state, ctx)
        flops = strat.round_flops(self.state, ctx)
        for key in self._comm:
            self._comm[key].append(float(getattr(comm, key)))
        for key in self._flops:
            self._flops[key].append(float(getattr(flops, key)))

        acc_mean = acc_std = None
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            with span("round.eval", track="engine", round=t):
                accs = evaluate_clients(
                    self.task, strat.eval_params(self.state, ctx),
                    self.clients)
            acc_mean = float(np.mean(accs))
            acc_std = float(np.std(accs))
            self._acc_history.append(acc_mean)
            self._acc_stds.append(acc_std)
            self._eval_rounds.append(t)

        self._next_round = t + 1
        metrics = RoundMetrics(
            round=t, lr=ctx.lr, prune_rate=ctx.prune_rate,
            comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
            flops_round=flops.per_round_flops,
            cum_flops=float(np.sum(self._flops["per_round_flops"])),
            acc_mean=acc_mean, acc_std=acc_std,
            wall_s=time.perf_counter() - t0)
        metrics = self._finish_metrics(ctx, metrics)
        self._sample_series(metrics)
        return metrics

    def rounds(self) -> Iterator[RoundMetrics]:
        for t in range(self._next_round, self.cfg.rounds):
            metrics = self._run_one_round(t)
            for cb in self.callbacks:
                cb.on_round_end(self, metrics)
            yield metrics
            if self._stop:
                break
        for cb in self.callbacks:
            cb.on_run_end(self)

    # -- results -----------------------------------------------------------
    def result(self, targets: Sequence[float] = (0.5,)) -> FLResult:
        """Aggregate streamed metrics into the paper-table ``FLResult``.

        Comm / FLOP columns are the *mean over executed rounds* — the
        topology is time-varying and masks evolve, so a single-round
        snapshot (the seed behaviour) misreports both.
        """
        final = evaluate_clients(
            self.task, self.strategy.finalize_eval_params(self.state),
            self.clients)
        comm = CommReport(**{k: float(np.mean(v)) if v else 0.0
                             for k, v in self._comm.items()})
        flops = FlopsReport(**{k: float(np.mean(v)) if v else 0.0
                               for k, v in self._flops.items()})
        return FLResult(
            acc_history=list(self._acc_history),
            final_accs=final,
            comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
            flops_per_round=flops.per_round_flops, flops_rows=flops.row(),
            rounds_to=rounds_to_targets(self._acc_history, list(targets)))

    def run(self, targets: Sequence[float] = (0.5,)) -> FLResult:
        for _ in self.rounds():
            pass
        return self.result(targets)

    # -- vmap fast path ----------------------------------------------------
    def _use_vmap(self, ctx: RoundCtx, active: list[int]) -> bool:
        if self.local_exec == "loop" or not active:
            return False
        ok, why = self._vmap_supported(ctx, active)
        if self.local_exec == "vmap" and not ok:
            raise ValueError(f"local_exec='vmap' requested but {why}")
        return ok

    def _vmap_supported(self, ctx: RoundCtx, active: list[int]):
        cfg = self.cfg
        if not self.strategy.vmap_capable:
            return False, f"strategy '{self.strategy.name}' is not vmap-capable"
        if cfg.capacities is not None:
            return False, "heterogeneous capacities use the per-client loop"
        ns = [self.clients[k].n_train for k in active]
        bss = {min(cfg.batch_size, n) for n in ns}
        if len(bss) != 1:
            return False, "clients disagree on effective batch size"
        # ragged step counts are fine: the stacked phase pads every client to
        # the max step count and masks the padded updates (no-op steps)
        return True, ""

    def _vmapped_fn(self, use_mask: bool) -> Callable:
        if use_mask in self._vmap_fns:
            return self._vmap_fns[use_mask]
        task = self.task
        # same update rule as the per-client loop (repro.optim); momentum
        # rides along as stacked per-client optimizer state, zero-initialized
        # each local phase exactly like the loop's init_sgd
        opt = SGDConfig(momentum=self.cfg.momentum,
                        weight_decay=self.cfg.weight_decay)

        def loss(p, x, y):
            return softmax_xent(task.apply_fn(p, x), y)

        grad = jax.grad(loss)

        def per_client(p, m, bx, by, live, lr):
            def body(carry, xyl):
                w, st = carry
                x, y, lv = xyl
                g = grad(w, x, y)
                if use_mask:
                    w2, st2 = masked_sgd_step(w, g, m, st, opt, lr)
                else:
                    w2, st2 = sgd_step(w, g, st, opt, lr)
                # padded steps (ragged per-client schedules) are no-ops;
                # jnp.where keeps live steps bit-identical to the plain step
                w = jax.tree.map(lambda o, n: jnp.where(lv, n, o), w, w2)
                st = jax.tree.map(lambda o, n: jnp.where(lv, n, o), st, st2)
                return (w, st), None

            st0 = ({"mu": jax.tree.map(jnp.zeros_like, p)}
                   if opt.momentum != 0.0 else {})
            (p, _), _ = jax.lax.scan(body, (p, st0), (bx, by, live))
            return p

        if use_mask:
            fn = jax.jit(jax.vmap(per_client, in_axes=(0, 0, 0, 0, 0, None)))
        else:
            fn = jax.jit(jax.vmap(
                lambda p, bx, by, live, lr: per_client(p, None, bx, by, live, lr),
                in_axes=(0, 0, 0, 0, None)))
        self._vmap_fns[use_mask] = fn
        return fn

    def _vmap_local_phase(self, ctx: RoundCtx, active: list[int]) -> None:
        strat = self.strategy
        state = self.state
        epochs = strat.local_epochs(state, ctx)
        bs = min(self.cfg.batch_size,
                 min(self.clients[k].n_train for k in active))
        orders = []
        for k in active:
            # identical draws to the per-client loop: one permutation per
            # epoch from the client's (seed, round, k) generator
            rng = ctx.client_rng(k)
            orders.append(np.concatenate(
                [_pad_order(self.clients[k].n_train, bs, rng)
                 for _ in range(epochs)]))
        # ragged schedules: pad every client to the max step count with
        # recycled batches, masked out in the scan (live=False -> no-op step)
        s_max = max(len(o) // bs for o in orders)
        xb, yb, live = [], [], []
        for k, order in zip(active, orders):
            steps = len(order) // bs
            c = self.clients[k]
            padded = np.resize(order, s_max * bs)
            xb.append(c.train_x[padded].reshape(
                (s_max, bs) + c.train_x.shape[1:]))
            yb.append(c.train_y[padded].reshape(s_max, bs))
            live.append(np.arange(s_max) < steps)
        live = jnp.asarray(np.stack(live))
        stacked = tree_stack([strat.local_params(state, k) for k in active])
        masks = [strat.local_mask(state, k) for k in active]
        use_mask = masks[0] is not None
        lr = jnp.float32(ctx.lr)
        if use_mask:
            new = self._vmapped_fn(True)(
                stacked, tree_stack(masks),
                jnp.asarray(np.stack(xb)), jnp.asarray(np.stack(yb)), live, lr)
        else:
            new = self._vmapped_fn(False)(
                stacked, jnp.asarray(np.stack(xb)), jnp.asarray(np.stack(yb)),
                live, lr)
        for i, k in enumerate(active):
            strat.set_local(state, k, tree_index(new, i))


# ---------------------------------------------------------------------------
# Convenience entry point (back-compat with the seed `run_strategy`)
# ---------------------------------------------------------------------------


def run_strategy(name: str, task: Task, clients, cfg: FLConfig,
                 targets: Sequence[float] = (0.5,),
                 callbacks: Sequence[Callback] = (),
                 local_exec: str = "auto", **strategy_kw) -> FLResult:
    """Build the named strategy, run it through the engine, return FLResult."""
    strat = make_strategy(name, **strategy_kw)
    engine = RoundEngine(strat, task, clients, cfg, callbacks=callbacks,
                         local_exec=local_exec)
    return engine.run(targets)
