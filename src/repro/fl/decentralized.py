"""Decentralized baselines: D-PSGD and D-PSGD-FT (Lian et al. 2017;
FL-adapted with multi-epoch local phases per Sun et al. 2021), as engine
hooks.

Gossip uses Metropolis-Hastings weights on the symmetrized topology (doubly
stochastic), then each client runs E local epochs.  The -FT variant
evaluates after ``ft_epochs`` of local fine-tuning from the consensus model
(paper App. B.4), leaving the consensus trajectory untouched.

``param_fraction`` implements the hardware-constrained baseline of §4.3:
every client trains only a fixed random ``param_fraction`` subnetwork of the
dense model (same mask for all clients, as D-PSGD has no personalization).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
from repro.fl.base import FLConfig, FLResult, Task, finetune_clients, local_sgd
from repro.fl.engine import (
    STREAM_EVAL,
    RoundCtx,
    StrategyBase,
    derive_rng,
    register,
    run_strategy,
)
from repro.sparse import packed_axpy
from repro.utils.tree import tree_nnz, tree_size


def metropolis_weights(a: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings mixing matrix of the symmetrized topology.

    W[i,j] = 1/(1+max(deg_i, deg_j)) on edges, diagonal absorbs the rest;
    doubly stochastic and symmetric.  Vectorized (the seed used an O(K^2)
    Python double loop).
    """
    sym = ((a + a.T) > 0).astype(float)
    np.fill_diagonal(sym, 0.0)
    deg = sym.sum(1)
    w = sym / (1.0 + np.maximum(deg[:, None], deg[None, :]))
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(1))
    return w


@register("dpsgd", finetune=False)
@register("dpsgd_ft", finetune=True)
class DPSGDStrategy(StrategyBase):
    """State: ``{"params": [K trees]}``.  The optional shared
    ``param_fraction`` mask is static and re-derived on resume."""

    vmap_capable = True
    decentralized = True

    def __init__(self, finetune: bool = False, param_fraction: float = 1.0):
        self.finetune = finetune
        self.param_fraction = param_fraction

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        w0 = task.init_fn(jax.random.PRNGKey(cfg.seed))
        self.mask = None
        self.densities: dict[str, float] = {}
        if self.param_fraction < 1.0:
            self.densities = erk_densities_for_params(w0, self.param_fraction)
            self.mask = init_mask(jax.random.PRNGKey(cfg.seed + 1), w0,
                                  self.param_fraction)
            w0 = apply_mask(w0, self.mask)
        self.n_coords = tree_size(w0)
        params = [jax.tree.map(lambda x: x, w0) for _ in range(len(clients))]
        return {"params": params}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        w_mix = metropolis_weights(ctx.adjacency)
        params = state["params"]
        k_clients = len(params)
        mixed = []
        for k in range(k_clients):
            acc = None
            for j in range(k_clients):
                if w_mix[k, j] == 0.0:
                    continue
                contrib = jax.tree.map(lambda x: w_mix[k, j] * x, params[j])
                acc = contrib if acc is None else jax.tree.map(
                    lambda u, v: u + v, acc, contrib)
            mixed.append(acc)
        state["params"] = mixed

    def mix_one(self, state: dict, k: int, senders: dict[int, dict],
                ctx: RoundCtx) -> None:
        """O(degree · nnz) per-activation mixing: Metropolis weights on k's
        star neighborhood, neighbor models folded in packed (dense models
        ride an all-ones bitmap), no other client touched."""
        if not senders:
            return
        n = len(state["params"])
        a = np.eye(n)
        a[k, sorted(senders)] = 1.0
        w_mix = metropolis_weights(a)
        acc = jax.tree.map(lambda x: w_mix[k, k] * x, state["params"][k])
        for j in sorted(senders):
            acc = packed_axpy(acc, senders[j]["packed"], float(w_mix[k, j]))
        state["params"][k] = acc

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k), mask=self.mask)

    def local_mask(self, state: dict, k: int):
        return self.mask

    def eval_params(self, state: dict, ctx: RoundCtx):
        if not self.finetune:
            return state["params"]
        return finetune_clients(
            self.task, state["params"], self.clients, self.cfg.ft_epochs,
            self.cfg.batch_size, ctx.lr, self.opt, ctx.eval_rng,
            mask=self.mask)

    def finalize_eval_params(self, state: dict):
        if not self.finetune:
            return state["params"]
        cfg = self.cfg
        return finetune_clients(
            self.task, state["params"], self.clients, cfg.ft_epochs,
            cfg.batch_size, cfg.lr_at(cfg.rounds), self.opt,
            lambda k: derive_rng(cfg.seed, cfg.rounds, k, stream=STREAM_EVAL),
            mask=self.mask)

    def round_comm(self, state: dict, ctx: RoundCtx):
        per = (tree_nnz(self.mask) if self.mask is not None
               else self.n_coords)
        return decentralized_comm(ctx.adjacency,
                                  [per] * len(self.clients), self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        dens = self.densities or {k: 1.0 for k in self.task.fwd_flops}
        return sparse_training_flops(
            self.task.fwd_flops, dens, self.n_samples, ctx.cfg.local_epochs,
            mask_search_batches=0, batch_size=ctx.cfg.batch_size)


def run_dpsgd(task: Task, clients, cfg: FLConfig, finetune: bool = False,
              param_fraction: float = 1.0, targets=(0.5,),
              **engine_kw) -> FLResult:
    """Back-compat wrapper: engine run -> FLResult."""
    return run_strategy("dpsgd", task, clients, cfg, targets=targets,
                        finetune=finetune, param_fraction=param_fraction,
                        **engine_kw)
