"""Decentralized baselines: D-PSGD and D-PSGD-FT (Lian et al. 2017;
FL-adapted with multi-epoch local phases per Sun et al. 2021).

Gossip uses Metropolis-Hastings weights on the symmetrized topology (doubly
stochastic), then each client runs E local epochs.  The -FT variant
evaluates after ``ft_epochs`` of local fine-tuning from the consensus model
(paper App. B.4), leaving the consensus trajectory untouched.

``param_fraction`` implements the hardware-constrained baseline of §4.3:
every client trains only a fixed random ``param_fraction`` subnetwork of the
dense model (same mask for all clients, as D-PSGD has no personalization).
"""
from __future__ import annotations

import copy

import jax
import numpy as np

from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
from repro.core.topology import make_adjacency
from repro.fl.base import (
    FLConfig,
    FLResult,
    Task,
    evaluate_clients,
    local_sgd,
    rounds_to_targets,
)
from repro.optim import SGDConfig
from repro.utils.tree import tree_map_with_path, tree_nnz, tree_size


def metropolis_weights(a: np.ndarray) -> np.ndarray:
    sym = ((a + a.T) > 0).astype(float)
    np.fill_diagonal(sym, 0.0)
    deg = sym.sum(1)
    k = len(a)
    w = np.zeros_like(sym)
    for i in range(k):
        for j in range(k):
            if sym[i, j] > 0:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    for i in range(k):
        w[i, i] = 1.0 - w[i].sum()
    return w


def run_dpsgd(task: Task, clients, cfg: FLConfig, finetune: bool = False,
              param_fraction: float = 1.0, targets=(0.5,)) -> FLResult:
    k_clients = len(clients)
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)

    w0 = task.init_fn(key)
    mask = None
    densities: dict[str, float] = {}
    if param_fraction < 1.0:
        densities = erk_densities_for_params(w0, param_fraction)
        mask = init_mask(jax.random.PRNGKey(cfg.seed + 1), w0, param_fraction)
        w0 = apply_mask(w0, mask)
    params = [jax.tree.map(lambda x: x, w0) for _ in range(k_clients)]

    history: list[float] = []
    adjacency0 = None
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        a = make_adjacency(cfg.topology, k_clients, t, cfg.degree, cfg.seed,
                           cfg.drop_prob)
        if adjacency0 is None:
            adjacency0 = a
        w_mix = metropolis_weights(a)
        mixed = []
        for k in range(k_clients):
            acc = None
            for j in range(k_clients):
                if w_mix[k, j] == 0.0:
                    continue
                contrib = jax.tree.map(lambda x: w_mix[k, j] * x, params[j])
                acc = contrib if acc is None else jax.tree.map(
                    lambda u, v: u + v, acc, contrib)
            mixed.append(acc)
        new_params = []
        for k in range(k_clients):
            c = clients[k]
            w = local_sgd(task, mixed[k], c.train_x, c.train_y,
                          cfg.local_epochs, cfg.batch_size, lr, opt, rng,
                          mask=mask)
            new_params.append(w)
        params = new_params
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            eval_params = params
            if finetune:
                eval_params = _finetune_all(task, params, clients, cfg, lr, rng, mask)
            history.append(float(np.mean(evaluate_clients(task, eval_params, clients))))

    final_params = params
    if finetune:
        final_params = _finetune_all(task, params, clients, cfg,
                                     cfg.lr_at(cfg.rounds), rng, mask)
    n_coords = tree_size(params[0])
    nnz = [tree_nnz(mask) if mask is not None else n_coords] * k_clients
    comm = decentralized_comm(adjacency0, nnz, n_coords)
    n_samples = int(np.mean([c.n_train for c in clients]))
    flops = sparse_training_flops(task.fwd_flops, densities or {k: 1.0 for k in task.fwd_flops},
                                  n_samples, cfg.local_epochs,
                                  mask_search_batches=0, batch_size=cfg.batch_size)
    final = evaluate_clients(task, final_params, clients)
    return FLResult(
        acc_history=history, final_accs=final,
        comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
        flops_per_round=flops.per_round_flops, flops_rows=flops.row(),
        rounds_to=rounds_to_targets(history, list(targets)))


def _finetune_all(task, params, clients, cfg, lr, rng, mask=None):
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    out = []
    for k, c in enumerate(clients):
        w = local_sgd(task, params[k], c.train_x, c.train_y, cfg.ft_epochs,
                      cfg.batch_size, lr, opt, rng, mask=mask)
        out.append(w)
    return out
