"""Partial-training decentralized variants (Shi et al., 2023): DFedAlt and
DFedSam, as engine hooks.

Both are the ROADMAP's "drop-in strategies the engine was built for" —
small ``StrategyBase`` subclasses that reuse the whole machinery (derived
rng, packed payloads, simulator, accounting) and change only what the
papers change:

* ``dfedalt`` — the model splits into a *shared body* and a *personal
  head* (the classifier).  Local steps alternate: update the head with the
  body frozen, then the body with the head frozen.  Only the body crosses
  the wire (a **partial packed payload**: the message bitmap is zero on
  every head coordinate, so codec frames, accounting and the simulator's
  measured bytes all shrink by the head size automatically), and the mix
  averages bodies over the in-neighborhood while heads stay personal.

* ``dfedsam`` — D-PSGD's gossip with a SAM local phase: each step takes
  the gradient at the adversarially perturbed point
  ``w + rho * g / ||g||`` (sharpness-aware minimization), which flattens
  local minima and reduces the consensus/personalization gap.  Payloads
  are full dense models (all-ones bitmap), like dpsgd.

Both use momentum-free SGD locally (the paper's setting); the engine's
per-(seed, round, client) rng derivation keeps them resume-exact.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import FlopsReport, decentralized_comm, sparse_training_flops
from repro.fl.base import FLConfig, Task, _pad_order
from repro.fl.decentralized import DPSGDStrategy
from repro.fl.engine import RoundCtx, StrategyBase, register
from repro.utils.tree import tree_map_with_path, tree_nnz, tree_size

PyTree = Any


def head_selector(path: str) -> bool:
    """The personal part: classifier leaves (``fc/...`` across the CNN zoo,
    ``head/...`` on the LM substrate)."""
    return path.startswith("fc") or path.startswith("head")


def split_masks(params: PyTree, selector=head_selector):
    """(body_sel, head_sel): complementary {0,1} float trees."""
    head = tree_map_with_path(
        lambda p, x: jnp.full(x.shape, 1.0 if selector(p) else 0.0,
                              jnp.float32), params)
    body = jax.tree.map(lambda h: 1.0 - h, head)
    return body, head


def _partial_sgd_step(params: PyTree, grads: PyTree, sel: PyTree,
                      lr: float, weight_decay: float) -> PyTree:
    """SGD on the selected coordinates only; frozen coordinates are left
    untouched (contrast ``masked_sgd_step``, which zeroes them — correct
    for sparsity masks, wrong for a freeze)."""
    return jax.tree.map(
        lambda w, g, s: w - lr * (g + weight_decay * w) * s,
        params, grads, sel)


@register("dfedalt")
class DFedAltStrategy(StrategyBase):
    """State: ``{"params": [K trees]}``.  The body/head split is static
    given the architecture and lives on ``self`` (re-derived on resume)."""

    decentralized = True

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        if cfg.momentum != 0.0:
            raise ValueError("dfedalt implements momentum-free local SGD "
                             "(the paper's setting); set cfg.momentum=0")
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(clients))
        params = [task.init_fn(k) for k in keys]
        self.body_sel, self.head_sel = split_masks(params[0])
        self.n_coords = tree_size(params[0])
        self.body_nnz = tree_nnz(self.body_sel)
        return {"params": params}

    # -- communication: bodies only ---------------------------------------
    def mix(self, state: dict, ctx: RoundCtx) -> None:
        a = ctx.adjacency
        params = state["params"]
        n = len(params)
        mixed = []
        for k in range(n):
            group = [k] + [j for j in range(n) if a[k, j] > 0 and j != k]
            inv = 1.0 / len(group)
            body = jax.tree.map(lambda x: inv * x, params[group[0]])
            for j in group[1:]:
                body = jax.tree.map(lambda u, v: u + inv * v, body, params[j])
            # personal head survives; shared body is the neighborhood mean
            mixed.append(jax.tree.map(
                lambda w, b, s: w * s + b * (1.0 - s),
                params[k], body, self.head_sel))
        state["params"] = mixed

    def local_mask(self, state: dict, k: int):
        # the message support: what dfedalt actually ships is the body —
        # snapshot_message/codec/accounting all key off this partial mask
        return self.body_sel

    # -- alternating local phase ------------------------------------------
    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        cfg = ctx.cfg
        c = self.clients[k]
        rng = ctx.client_rng(k)
        params = state["params"][k]
        bs = min(cfg.batch_size, c.n_train)
        for _ in range(cfg.local_epochs):
            order = _pad_order(c.n_train, bs, rng)
            for i in range(0, len(order), bs):
                sel = order[i: i + bs]
                x, y = c.train_x[sel], c.train_y[sel]
                # personal part first, then the shared part at the updated
                # head (DFedAlt's alternating order)
                _, g = self.task.value_and_grad(params, x, y)
                params = _partial_sgd_step(params, g, self.head_sel,
                                           ctx.lr, cfg.weight_decay)
                _, g = self.task.value_and_grad(params, x, y)
                params = _partial_sgd_step(params, g, self.body_sel,
                                           ctx.lr, cfg.weight_decay)
        state["params"][k] = params

    # -- accounting --------------------------------------------------------
    def round_comm(self, state: dict, ctx: RoundCtx):
        n = len(self.clients)
        return decentralized_comm(ctx.adjacency, [self.body_nnz] * n,
                                  self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        # two alternating half-updates per batch, quoted as two full
        # forward+backward passes (a slight overcount of the halves)
        dense = sparse_training_flops(
            self.task.fwd_flops, {k: 1.0 for k in self.task.fwd_flops},
            self.n_samples, ctx.cfg.local_epochs, mask_search_batches=0,
            batch_size=ctx.cfg.batch_size)
        return FlopsReport(
            per_round_flops=2 * dense.per_round_flops,
            dense_per_round_flops=dense.dense_per_round_flops,
            fwd_flops_per_sample=dense.fwd_flops_per_sample)


def local_sam_sgd(task: Task, params: PyTree, x, y, epochs: int,
                  batch_size: int, lr: float, weight_decay: float,
                  rng: np.random.Generator, rho: float) -> PyTree:
    """SAM local phase: per batch, the update direction is the gradient at
    the adversarially perturbed point ``w + rho * g1 / ||g1||``.  Batch
    schedule identical to ``local_sgd`` (same ``_pad_order`` draws per
    epoch) so the derived-rng determinism contract holds."""
    bs = min(batch_size, len(y))
    for _ in range(epochs):
        order = _pad_order(len(y), bs, rng)
        for i in range(0, len(order), bs):
            sel = order[i: i + bs]
            xb, yb = x[sel], y[sel]
            _, g1 = task.value_and_grad(params, xb, yb)
            norm = jnp.sqrt(sum(jnp.sum(jnp.square(le))
                                for le in jax.tree.leaves(g1)))
            scale = rho / (norm + 1e-12)
            w_adv = jax.tree.map(lambda w, g: w + scale * g, params, g1)
            _, g2 = task.value_and_grad(w_adv, xb, yb)
            params = jax.tree.map(
                lambda w, g: w - lr * (g + weight_decay * w), params, g2)
    return params


@register("dfedsam")
class DFedSamStrategy(DPSGDStrategy):
    """D-PSGD gossip (Metropolis weights, full dense payloads) + SAM local
    steps.  Inherits dpsgd's mix/mix_one/payload machinery wholesale; only
    the local phase and the FLOPs accounting differ."""

    #: the SAM two-gradient step is not the engine's standard scan body
    vmap_capable = False

    def __init__(self, rho: float = 0.05):
        super().__init__(finetune=False, param_fraction=1.0)
        self.rho = float(rho)

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        if cfg.momentum != 0.0:
            raise ValueError("dfedsam implements momentum-free SAM-SGD; "
                             "set cfg.momentum=0")
        return super().init_state(task, clients, cfg)

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sam_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr,
            ctx.cfg.weight_decay, ctx.client_rng(k), self.rho)

    def round_flops(self, state: dict, ctx: RoundCtx):
        base = super().round_flops(state, ctx)
        # SAM doubles the per-batch gradient work (ascent + descent pass)
        return FlopsReport(
            per_round_flops=2 * base.per_round_flops,
            dense_per_round_flops=base.dense_per_round_flops,
            fwd_flops_per_sample=base.fwd_flops_per_sample)
