"""DisPFL (paper Alg. 1) — decentralized sparse personalized FL, as engine
hooks.

Per communication round, for every client k:
  1. ``mix``: receive neighbor models/masks per the (time-varying) topology
     and intersection-weighted gossip average, re-masked by m_k (Fig. 1b),
  2. ``local_update``: E epochs of local SGD with gradient masking (fixed
     mask) — or the engine's vmap fast path, which is schedule-identical,
  3. ``evolve``: local mask search — dense gradient on one batch,
     cosine-annealed magnitude prune + gradient regrow (Alg. 2, Fig. 1c).

Heterogeneous clients pass per-client ``capacities`` (densities) — the ERK
allocation gives each its own layer-density profile (paper §4.3).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.core.evolve import evolve_masks, layer_nnz_budgets
from repro.core.gossip import gossip_average_one
from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
from repro.fl.base import FLConfig, FLResult, Task, local_sgd
from repro.fl.engine import RoundCtx, StrategyBase, register, run_strategy
from repro.utils.tree import tree_nnz, tree_size


@register("dispfl")
class DisPFLStrategy(StrategyBase):
    """State: ``{"params": [K trees], "masks": [K trees]}``.  ERK budgets and
    densities are static given (cfg, model) and live on ``self``."""

    vmap_capable = True
    decentralized = True

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        k_clients = len(clients)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * k_clients)
        params = [task.init_fn(keys[k]) for k in range(k_clients)]
        self.densities = [
            erk_densities_for_params(params[k], cfg.client_density(k))
            for k in range(k_clients)
        ]
        masks = [
            init_mask(keys[k_clients + k], params[k], cfg.client_density(k))
            for k in range(k_clients)
        ]
        self.budgets = [layer_nnz_budgets(params[k], self.densities[k])
                        for k in range(k_clients)]
        self.n_coords = tree_size(params[0])
        params = [apply_mask(p, m) for p, m in zip(params, masks)]
        return {"params": params, "masks": masks}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        a = ctx.adjacency
        params, masks = state["params"], state["masks"]
        k_clients = len(params)
        mixed = []
        for k in range(k_clients):
            nbrs = [j for j in range(k_clients) if a[k, j] > 0 and j != k]
            mixed.append(gossip_average_one(
                params[k], masks[k],
                [params[j] for j in nbrs], [masks[j] for j in nbrs]))
        state["params"] = mixed

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k), mask=state["masks"][k])

    def local_mask(self, state: dict, k: int):
        return state["masks"][k]

    def evolve(self, state: dict, k: int, ctx: RoundCtx) -> None:
        xb, yb = self.clients[k].sample_batch(ctx.client_rng(k),
                                              ctx.cfg.batch_size)
        _, g = self.task.value_and_grad(state["params"][k], xb, yb)
        m_new, w_new = evolve_masks(state["params"][k], state["masks"][k], g,
                                    ctx.prune_rate, self.budgets[k])
        state["masks"][k], state["params"][k] = m_new, w_new

    def round_comm(self, state: dict, ctx: RoundCtx):
        nnz = [tree_nnz(m) for m in state["masks"]]
        return decentralized_comm(ctx.adjacency, nnz, self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        return sparse_training_flops(
            self.task.fwd_flops, _mean_density(self.densities),
            self.n_samples, ctx.cfg.local_epochs,
            mask_search_batches=1, batch_size=ctx.cfg.batch_size)


def _mean_density(densities: list[dict[str, float]]) -> dict[str, float]:
    keys = densities[0].keys()
    return {k: float(np.mean([d[k] for d in densities])) for k in keys}


def run_dispfl(task: Task, clients, cfg: FLConfig, targets=(0.5,),
               **engine_kw) -> FLResult:
    """Back-compat wrapper: engine run -> FLResult."""
    return run_strategy("dispfl", task, clients, cfg, targets=targets,
                        **engine_kw)


def dispfl_state(task: Task, cfg: FLConfig):
    """Expose (params, masks) init for tests/examples."""
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * cfg.n_clients)
    params = [task.init_fn(keys[k]) for k in range(cfg.n_clients)]
    masks = [init_mask(keys[cfg.n_clients + k], params[k], cfg.client_density(k))
             for k in range(cfg.n_clients)]
    return [apply_mask(p, m) for p, m in zip(params, masks)], masks
