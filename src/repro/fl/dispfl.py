"""DisPFL (paper Alg. 1) — decentralized sparse personalized FL, as engine
hooks.

Per communication round, for every client k:
  1. ``mix``: receive neighbor models/masks per the (time-varying) topology
     and intersection-weighted gossip average, re-masked by m_k (Fig. 1b),
  2. ``local_update``: E epochs of local SGD with gradient masking (fixed
     mask) — or the engine's vmap fast path, which is schedule-identical,
  3. ``evolve``: local mask search — dense gradient on one batch,
     cosine-annealed magnitude prune + gradient regrow (Alg. 2, Fig. 1c).

Heterogeneous clients pass per-client ``capacities`` (densities) — the ERK
allocation gives each its own layer-density profile (paper §4.3).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.core.evolve import evolve_masks, layer_nnz_budgets
from repro.core.gossip import gossip_average_one
from repro.core.masks import (
    annealed_density,
    apply_mask,
    erk_densities_for_params,
    init_mask,
)
from repro.fl.base import FLConfig, FLResult, Task, local_sgd
from repro.fl.engine import RoundCtx, StrategyBase, register, run_strategy
from repro.sparse import (
    pack_tree,
    packed_gossip_one,
    unpack_mask_tree,
    unpack_tree,
)
from repro.utils.tree import tree_nnz, tree_size


@register("dispfl")
class DisPFLStrategy(StrategyBase):
    """State: ``{"params": [K trees], "masks": [K trees]}``.  ERK budgets and
    densities are static given (cfg, model) and live on ``self``.

    ``packed=True`` (the default) runs the gossip phase on ``repro.sparse``
    packed payloads — each sender is packed once (bitmap + nnz values, the
    message that physically crosses a link) and decoded once per round; the
    async per-activation path (``mix_one``) folds the payloads directly
    into (num, den) accumulators.  Both are bit-identical to the dense
    ``packed=False`` reference path (golden-tested).

    ``payload_dtype="fp16"`` ships half-precision values on the wire: the
    bitmap (and therefore every mask) is unchanged, each held value is cast
    to fp16 at the message boundary, and the codec frame shrinks to
    header + bitmap + 2*nnz bytes — exactly half the fp32 value payload.
    Receivers mix the cast values in fp32, so the trajectory matches the
    fp32 run to fp16 tolerance with *identical masks* (golden-tested);
    the analytic ``round_comm`` keeps the paper's 4-bytes-per-value
    headline while the measured/codec side reports the real halved frame
    (the documented divergence in ``core.accounting``)."""

    vmap_capable = True
    decentralized = True

    def __init__(self, packed: bool = True, payload_dtype: str = "fp32"):
        if payload_dtype not in ("fp32", "fp16"):
            raise ValueError(
                f"payload_dtype must be fp32|fp16, got {payload_dtype!r}")
        if payload_dtype == "fp16" and not packed:
            raise ValueError("payload_dtype='fp16' requires packed=True "
                             "(the cast happens at the message boundary)")
        self.packed = packed
        self.payload_dtype = payload_dtype
        #: dtype handed to pack_tree; None keeps values bit-exact fp32
        self._wire_dtype = np.float16 if payload_dtype == "fp16" else None

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        k_clients = len(clients)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * k_clients)
        params = [task.init_fn(keys[k]) for k in range(k_clients)]
        self.densities = [
            erk_densities_for_params(params[k], cfg.client_density(k))
            for k in range(k_clients)
        ]
        masks = [
            init_mask(keys[k_clients + k], params[k], cfg.client_density(k))
            for k in range(k_clients)
        ]
        self.budgets = [layer_nnz_budgets(params[k], self.densities[k])
                        for k in range(k_clients)]
        self.n_coords = tree_size(params[0])
        params = [apply_mask(p, m) for p, m in zip(params, masks)]
        return {"params": params, "masks": masks}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        a = ctx.adjacency
        params, masks = state["params"], state["masks"]
        k_clients = len(params)
        nbrs_of = [[j for j in range(k_clients) if a[k, j] > 0 and j != k]
                   for k in range(k_clients)]
        if self.packed:
            # produce/consume the same O(nnz) packed messages the simulator
            # ships: pack each sender once, decode once (not once per
            # receiving edge — the barrier mix is a broadcast, so a shared
            # decode is the cheap shape here; the async per-activation path
            # is mix_one, which folds payloads without a shared decode)
            senders = sorted({j for nbrs in nbrs_of for j in nbrs})
            payloads = {j: pack_tree(params[j], masks[j],
                                     dtype=self._wire_dtype)
                        for j in senders}
            dec_w = {j: unpack_tree(p) for j, p in payloads.items()}
            dec_m = {j: unpack_mask_tree(p) for j, p in payloads.items()}
            state["params"] = [
                gossip_average_one(params[k], masks[k],
                                   [dec_w[j] for j in nbrs_of[k]],
                                   [dec_m[j] for j in nbrs_of[k]])
                for k in range(k_clients)]
            return
        state["params"] = [
            gossip_average_one(params[k], masks[k],
                               [params[j] for j in nbrs_of[k]],
                               [masks[j] for j in nbrs_of[k]])
            for k in range(k_clients)]

    def mix_one(self, state: dict, k: int, senders: dict[int, dict],
                ctx: RoundCtx) -> None:
        """Per-activation gossip that folds exactly the arrived packed
        payloads — O(degree) folds, no swap-in/restore of the other K-1
        clients (see repro.sparse.ops for the precise cost model)."""
        if not senders:
            return
        packs = [senders[j]["packed"] for j in sorted(senders)]
        state["params"][k] = packed_gossip_one(
            state["params"][k], state["masks"][k], packs)

    def snapshot_message(self, state: dict, k: int) -> dict:
        """What k transmits: its packed masked model, values cast to the
        wire dtype (fp16 halves the codec frame's value bytes; the bitmap
        is dtype-independent)."""
        return {"packed": pack_tree(state["params"][k], state["masks"][k],
                                    dtype=self._wire_dtype)}

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k), mask=state["masks"][k])

    def local_mask(self, state: dict, k: int):
        return state["masks"][k]

    def evolve(self, state: dict, k: int, ctx: RoundCtx) -> None:
        xb, yb = self.clients[k].sample_batch(ctx.client_rng(k),
                                              ctx.cfg.batch_size)
        _, g = self.task.value_and_grad(state["params"][k], xb, yb)
        m_new, w_new = evolve_masks(state["params"][k], state["masks"][k], g,
                                    ctx.prune_rate, self.budgets[k])
        state["masks"][k], state["params"][k] = m_new, w_new

    def round_comm(self, state: dict, ctx: RoundCtx):
        nnz = [tree_nnz(m) for m in state["masks"]]
        return decentralized_comm(ctx.adjacency, nnz, self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        return sparse_training_flops(
            self.task.fwd_flops, _mean_density(self.densities),
            self.n_samples, ctx.cfg.local_epochs,
            mask_search_batches=1, batch_size=ctx.cfg.batch_size)


def _mean_density(densities: list[dict[str, float]]) -> dict[str, float]:
    keys = densities[0].keys()
    return {k: float(np.mean([d[k] for d in densities])) for k in keys}


@register("dispfl_anneal")
class DisPFLAnnealStrategy(DisPFLStrategy):
    """DA-DPFL-style sparse-to-sparser training (Long et al., 2024).

    Same hooks as DisPFL, but the per-client mask budget follows a cosine
    density schedule from ``cfg.density`` down to ``density_final``
    (default ``cfg.density_final`` or a quarter of the start): each round's
    mask search prunes to the *annealed* ERK budgets and regrows within
    them, so payloads — packed bitmap + nnz values — physically shrink
    round over round (the variable-size regime the codec-measured
    simulator links exercise)."""

    def __init__(self, density_final: float | None = None,
                 packed: bool = True, payload_dtype: str = "fp32"):
        super().__init__(packed=packed, payload_dtype=payload_dtype)
        #: constructor override; None defers to cfg at init_state time
        self.density_final = density_final

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        state = super().init_state(task, clients, cfg)
        # resolved per init_state so re-initializing with a new cfg re-reads
        # it (the ctor override, if any, stays authoritative)
        self._d_final = (self.density_final if self.density_final is not None
                         else cfg.density_final or cfg.density / 4.0)
        self._template = state["params"][0]      # shapes only
        self._budget_cache: dict[tuple[int, float], dict[str, int]] = {}
        self._flops_density_cache: dict[int, dict[str, float]] = {}
        return state

    def density_at(self, t: int, k: int = 0) -> float:
        d0 = self.cfg.client_density(k)
        d_end = self._d_final * d0 / self.cfg.density
        return annealed_density(d0, d_end, t, self.cfg.rounds)

    def _budgets_at(self, t: int, k: int) -> dict[str, int]:
        key = (t, self.cfg.client_density(k))
        if key not in self._budget_cache:
            dens = erk_densities_for_params(self._template,
                                            self.density_at(t, k))
            self._budget_cache[key] = layer_nnz_budgets(self._template, dens)
        return self._budget_cache[key]

    def evolve(self, state: dict, k: int, ctx: RoundCtx) -> None:
        xb, yb = self.clients[k].sample_batch(ctx.client_rng(k),
                                              ctx.cfg.batch_size)
        _, g = self.task.value_and_grad(state["params"][k], xb, yb)
        # the annealed budget both prunes (down to the schedule) and regrows
        # (within it): nnz(mask) == budget exactly after each round
        m_new, w_new = evolve_masks(state["params"][k], state["masks"][k], g,
                                    ctx.prune_rate, self._budgets_at(ctx.t, k))
        state["masks"][k], state["params"][k] = m_new, w_new

    def round_flops(self, state: dict, ctx: RoundCtx):
        # mean over clients' annealed ERK allocations, matching the base
        # strategy's _mean_density convention under heterogeneous capacities
        if ctx.t not in self._flops_density_cache:
            self._flops_density_cache[ctx.t] = _mean_density([
                erk_densities_for_params(self._template,
                                         self.density_at(ctx.t, k))
                for k in range(len(self.clients))])
        return sparse_training_flops(
            self.task.fwd_flops, self._flops_density_cache[ctx.t],
            self.n_samples, ctx.cfg.local_epochs,
            mask_search_batches=1, batch_size=ctx.cfg.batch_size)


def run_dispfl(task: Task, clients, cfg: FLConfig, targets=(0.5,),
               **engine_kw) -> FLResult:
    """Back-compat wrapper: engine run -> FLResult."""
    return run_strategy("dispfl", task, clients, cfg, targets=targets,
                        **engine_kw)


def dispfl_state(task: Task, cfg: FLConfig):
    """Expose (params, masks) init for tests/examples."""
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * cfg.n_clients)
    params = [task.init_fn(keys[k]) for k in range(cfg.n_clients)]
    masks = [init_mask(keys[cfg.n_clients + k], params[k], cfg.client_density(k))
             for k in range(cfg.n_clients)]
    return [apply_mask(p, m) for p, m in zip(params, masks)], masks
