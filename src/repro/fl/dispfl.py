"""DisPFL (paper Alg. 1) — decentralized sparse personalized FL.

Per communication round, synchronously for every client k:
  1. receive neighbor models/masks per the (time-varying) topology,
  2. intersection-weighted gossip average, re-masked by m_k (Fig. 1b),
  3. E epochs of local SGD with gradient masking (fixed mask),
  4. local mask search: dense gradient on one batch, cosine-annealed
     magnitude prune + gradient regrow (Alg. 2, Fig. 1c).

Heterogeneous clients pass per-client ``capacities`` (densities) — the ERK
allocation gives each its own layer-density profile (paper §4.3).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.core.evolve import cosine_prune_rate, evolve_masks, layer_nnz_budgets
from repro.core.gossip import gossip_average_one
from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
from repro.core.topology import make_adjacency
from repro.fl.base import (
    FLConfig,
    FLResult,
    Task,
    evaluate_clients,
    local_sgd,
    rounds_to_targets,
)
from repro.optim import SGDConfig
from repro.utils.tree import tree_nnz, tree_size


def run_dispfl(task: Task, clients, cfg: FLConfig, targets=(0.5,)) -> FLResult:
    k_clients = len(clients)
    rng = np.random.default_rng(cfg.seed)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * k_clients)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)

    # --- per-client init: model + ERK mask at capacity c_k ---------------
    params = [task.init_fn(keys[k]) for k in range(k_clients)]
    densities = [
        erk_densities_for_params(params[k], cfg.client_density(k))
        for k in range(k_clients)
    ]
    masks = [
        init_mask(keys[k_clients + k], params[k], cfg.client_density(k))
        for k in range(k_clients)
    ]
    nnz_budgets = [layer_nnz_budgets(params[k], densities[k]) for k in range(k_clients)]
    params = [apply_mask(p, m) for p, m in zip(params, masks)]

    history: list[float] = []
    adjacency0 = None
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        alpha_t = cosine_prune_rate(cfg.alpha0, t, cfg.rounds)
        a = make_adjacency(cfg.topology, k_clients, t, cfg.degree, cfg.seed,
                           cfg.drop_prob)
        if adjacency0 is None:
            adjacency0 = a
        new_params, new_masks = [], []
        for k in range(k_clients):
            nbrs = [j for j in range(k_clients) if a[k, j] > 0 and j != k]
            # (1)+(2) intersection-weighted gossip
            w = gossip_average_one(
                params[k], masks[k],
                [params[j] for j in nbrs], [masks[j] for j in nbrs])
            # (3) local sparse training with fixed mask
            c = clients[k]
            w = local_sgd(task, w, c.train_x, c.train_y, cfg.local_epochs,
                          cfg.batch_size, lr, opt, rng, mask=masks[k])
            # (4) mask search with one dense-gradient batch
            xb, yb = c.sample_batch(rng, cfg.batch_size)
            _, g = task.value_and_grad(w, xb, yb)
            m_new, w = evolve_masks(w, masks[k], g, alpha_t, nnz_budgets[k])
            new_params.append(w)
            new_masks.append(m_new)
        params, masks = new_params, new_masks
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, params, clients))))

    # --- accounting -------------------------------------------------------
    n_coords = tree_size(params[0])
    nnz = [tree_nnz(m) for m in masks]
    comm = decentralized_comm(adjacency0, nnz, n_coords)
    n_samples = int(np.mean([c.n_train for c in clients]))
    flops = sparse_training_flops(
        task.fwd_flops, _mean_density(densities), n_samples, cfg.local_epochs,
        mask_search_batches=1, batch_size=cfg.batch_size)
    final = evaluate_clients(task, params, clients)
    return FLResult(
        acc_history=history, final_accs=final,
        comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
        flops_per_round=flops.per_round_flops, flops_rows=flops.row(),
        rounds_to=rounds_to_targets(history, list(targets)))


def _mean_density(densities: list[dict[str, float]]) -> dict[str, float]:
    keys = densities[0].keys()
    return {k: float(np.mean([d[k] for d in densities])) for k in keys}


def dispfl_state(task: Task, cfg: FLConfig):
    """Expose (params, masks) init for tests/examples."""
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * cfg.n_clients)
    params = [task.init_fn(keys[k]) for k in range(cfg.n_clients)]
    masks = [init_mask(keys[cfg.n_clients + k], params[k], cfg.client_density(k))
             for k in range(cfg.n_clients)]
    return [apply_mask(p, m) for p, m in zip(params, masks)], masks
