"""Federated strategy zoo, built on the composable round engine.

Architecture
------------
``repro.fl.engine`` owns the round loop: topology sampling, the local phase
(per-client loop or a jitted vmap-over-clients fast path), eval cadence and
per-round comm/FLOP accounting.  A *strategy* is a small class implementing
the ``Strategy`` lifecycle hooks (see ``engine.StrategyBase``):

    init_state(task, clients, cfg) -> state     # params/masks pytree
    mix(state, ctx)                             # communication phase
    local_update(state, k, ctx)                 # client k's local phase
    evolve(state, k, ctx)                       # optional mask search
    finalize_eval_params(state)                 # what to evaluate at the end

plus ``round_comm``/``round_flops`` for the paper-table accounting, computed
from the *current* round's adjacency and mask nnz.

Adding a strategy in <100 lines
-------------------------------
Subclass ``StrategyBase``, override the hooks that differ from the defaults,
and register a name::

    from repro.fl.engine import StrategyBase, register

    @register("my_strategy")
    class MyStrategy(StrategyBase):
        def init_state(self, task, clients, cfg):
            super().init_state(task, clients, cfg)
            ...
            return {"params": params}
        def mix(self, state, ctx): ...
        def local_update(self, state, k, ctx): ...

then ``run_strategy("my_strategy", task, clients, cfg)`` or the launcher's
``--strategy my_strategy`` just work.  ``examples/custom_strategy.py`` is a
worked end-to-end example.

Streaming / checkpointing
-------------------------
``RoundEngine`` streams ``RoundMetrics`` per round and takes callbacks
(``JsonlLogger``, ``Checkpointer``, ``EarlyStopAtTarget``); a checkpointed
run resumes bit-identically because all rng is derived per (seed, round,
client).  ``run_strategy`` and the ``run_*`` wrappers below drain the
stream into the familiar ``FLResult``.
"""
from repro.fl.base import (  # noqa: F401
    FLConfig,
    FLResult,
    Task,
    make_cnn_task,
)
from repro.fl.engine import (  # noqa: F401
    Callback,
    Checkpointer,
    EarlyStopAtTarget,
    JsonlLogger,
    RoundCtx,
    RoundEngine,
    RoundMetrics,
    StrategyBase,
    make_strategy,
    register,
    run_strategy,
    strategy_names,
)
from repro.fl.centralized import (  # noqa: F401
    run_ditto,
    run_fedavg,
    run_fomo,
    run_local,
    run_subfedavg,
)
from repro.fl.decentralized import run_dpsgd  # noqa: F401
from repro.fl.dispfl import run_dispfl  # noqa: F401


def _runner(name: str):
    def _run(task, clients, cfg, **kw):
        return run_strategy(name, task, clients, cfg, **kw)

    _run.__name__ = f"run_{name}"
    return _run


#: Back-compat view of the registry: name -> runner(task, clients, cfg, **kw).
#: New code should use ``run_strategy`` / ``make_strategy`` directly; new
#: strategies appear here automatically via ``@register``.
STRATEGIES = {name: _runner(name) for name in strategy_names()}
