from repro.fl.base import FLConfig, FLResult, Task, make_cnn_task  # noqa: F401
from repro.fl.centralized import (  # noqa: F401
    run_ditto,
    run_fedavg,
    run_fomo,
    run_local,
    run_subfedavg,
)
from repro.fl.decentralized import run_dpsgd  # noqa: F401
from repro.fl.dispfl import run_dispfl  # noqa: F401

STRATEGIES = {
    "local": run_local,
    "fedavg": lambda t, c, cfg, **kw: run_fedavg(t, c, cfg, finetune=False, **kw),
    "fedavg_ft": lambda t, c, cfg, **kw: run_fedavg(t, c, cfg, finetune=True, **kw),
    "dpsgd": lambda t, c, cfg, **kw: run_dpsgd(t, c, cfg, finetune=False, **kw),
    "dpsgd_ft": lambda t, c, cfg, **kw: run_dpsgd(t, c, cfg, finetune=True, **kw),
    "ditto": run_ditto,
    "fomo": run_fomo,
    "subfedavg": run_subfedavg,
    "dispfl": run_dispfl,
}


def run_strategy(name: str, task, clients, cfg, **kw) -> FLResult:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy '{name}'; available: {sorted(STRATEGIES)}")
    return STRATEGIES[name](task, clients, cfg, **kw)
