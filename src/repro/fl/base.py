"""Shared substrate for all federated strategies.

A ``Task`` bundles the model family used in the FL simulation (the paper's
backbones or the fast small CNN) with jitted loss/grad/eval functions and the
per-layer analytic FLOPs map used by the accounting.

``local_sgd`` runs the paper's local phase: E epochs of minibatch SGD with
fixed batch size (epochs are padded to whole batches so a single jitted step
serves all clients), optional DisPFL-style gradient masking.

Determinism: callers must pass a *per-client, per-round* generator (see
``repro.fl.engine.derive_rng``) — never one generator shared across clients,
which would make results depend on client iteration order and break the
engine's vmap/parallel execution paths.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cnn as cnn_mod
from repro.models.common import softmax_xent
from repro.optim import SGDConfig, init_sgd, masked_sgd_step, sgd_step

PyTree = Any


@dataclasses.dataclass
class Task:
    name: str
    init_fn: Callable[[jax.Array], PyTree]
    apply_fn: Callable[[PyTree, jax.Array], jax.Array]
    fwd_flops: dict[str, float]          # per-sample forward FLOPs per weight leaf
    n_classes: int

    def __post_init__(self):
        def loss(params, x, y):
            return softmax_xent(self.apply_fn(params, x), y)

        self._vg = jax.jit(jax.value_and_grad(loss))
        self._acc = jax.jit(
            lambda params, x, y: jnp.mean(
                (jnp.argmax(self.apply_fn(params, x), -1) == y)))

        def acc_one(params, x, y, live):
            correct = ((jnp.argmax(self.apply_fn(params, x), -1) == y)
                       & live).astype(jnp.float32)
            # sum * (1/n), not sum / n: XLA strength-reduces _acc's
            # divide-by-constant into a reciprocal multiply, and the
            # stacked eval must round identically to stay bit-equal to
            # the per-client loop
            n = jnp.sum(live.astype(jnp.float32))
            return jnp.sum(correct) * (jnp.float32(1.0) / n)

        self._acc_stacked = jax.jit(jax.vmap(acc_one))

    def value_and_grad(self, params, x, y):
        return self._vg(params, jnp.asarray(x), jnp.asarray(y))

    def accuracy(self, params, x, y) -> float:
        return float(self._acc(params, jnp.asarray(x), jnp.asarray(y)))


def make_cnn_task(kind: str = "smallcnn", n_classes: int = 10, hw: int = 16,
                  width: int = 16) -> Task:
    if kind == "smallcnn":
        return Task(
            name="smallcnn",
            init_fn=lambda key: cnn_mod.init_smallcnn(key, n_classes, width=width),
            apply_fn=cnn_mod.smallcnn_apply,
            fwd_flops=cnn_mod.smallcnn_fwd_flops(n_classes, hw, width),
            n_classes=n_classes)
    if kind == "resnet18":
        return Task(
            name="resnet18",
            init_fn=lambda key: cnn_mod.init_resnet18(key, n_classes),
            apply_fn=cnn_mod.resnet18_apply,
            fwd_flops=cnn_mod.resnet18_fwd_flops(n_classes, hw),
            n_classes=n_classes)
    if kind == "vgg11":
        return Task(
            name="vgg11",
            init_fn=lambda key: cnn_mod.init_vgg11(key, n_classes),
            apply_fn=cnn_mod.vgg11_apply,
            fwd_flops=cnn_mod.vgg11_fwd_flops(n_classes, hw),
            n_classes=n_classes)
    raise ValueError(kind)


@dataclasses.dataclass
class FLConfig:
    n_clients: int = 10
    rounds: int = 20
    local_epochs: int = 5
    batch_size: int = 32
    lr0: float = 0.1
    lr_decay: float = 0.998
    weight_decay: float = 5e-4
    momentum: float = 0.0
    topology: str = "random"            # random | ring | fc
    degree: int = 10
    seed: int = 0
    drop_prob: float = 0.0
    # sparsity (DisPFL / SubFedAvg)
    density: float = 0.5
    capacities: Optional[list[float]] = None   # per-client densities
    alpha0: float = 0.5                  # initial prune rate (cosine annealed)
    # dispfl_anneal: end-of-run density of the DA-DPFL-style cosine
    # sparse-to-sparser schedule (None -> density / 4)
    density_final: Optional[float] = None
    # Ditto / FOMO / fine-tuning
    prox_lambda: float = 0.75
    ft_epochs: int = 2
    eval_every: int = 1

    def lr_at(self, r: int) -> float:
        return self.lr0 * (self.lr_decay ** r)

    def client_density(self, k: int) -> float:
        if self.capacities is not None:
            return self.capacities[k]
        return self.density


@dataclasses.dataclass
class FLResult:
    acc_history: list[float]             # mean personalized test acc per eval
    final_accs: list[float]
    comm_busiest_mb: float               # per round
    comm_rows: dict
    flops_per_round: float               # per client
    flops_rows: dict
    rounds_to: dict[float, int] = dataclasses.field(default_factory=dict)

    @property
    def final_acc(self) -> float:
        return float(np.mean(self.final_accs))


def _pad_order(n: int, bs: int, rng: np.random.Generator) -> np.ndarray:
    order = rng.permutation(n)
    pad = (-len(order)) % bs
    if pad:
        order = np.concatenate([order, order[:pad]])
    return order


def local_sgd(
    task: Task,
    params: PyTree,
    x: np.ndarray,
    y: np.ndarray,
    epochs: int,
    batch_size: int,
    lr: float,
    opt: SGDConfig,
    rng: np.random.Generator,
    mask: Optional[PyTree] = None,
) -> PyTree:
    """The paper's local phase (Alg. 1 lines 9-13)."""
    state = init_sgd(params, opt)
    bs = min(batch_size, len(y))
    for _ in range(epochs):
        order = _pad_order(len(y), bs, rng)
        for i in range(0, len(order), bs):
            sel = order[i: i + bs]
            _, grads = task.value_and_grad(params, x[sel], y[sel])
            if mask is not None:
                params, state = masked_sgd_step(params, grads, mask, state, opt, lr)
            else:
                params, state = sgd_step(params, grads, state, opt, lr)
    return params


def finetune_clients(
    task: Task,
    params: list[PyTree],
    clients,
    epochs: int,
    batch_size: int,
    lr: float,
    opt: SGDConfig,
    rng_for: Callable[[int], np.random.Generator],
    mask=None,
) -> list[PyTree]:
    """Fine-tune every client from ``params[k]`` (the -FT eval variants).

    ``rng_for(k)`` supplies the per-client generator; ``mask`` may be a
    single shared mask tree, a per-client list, or None.
    """
    out = []
    for k, c in enumerate(clients):
        m = mask[k] if isinstance(mask, list) else mask
        out.append(local_sgd(task, params[k], c.train_x, c.train_y, epochs,
                             batch_size, lr, opt, rng_for(k), mask=m))
    return out


def evaluate_clients(task: Task, client_params: list[PyTree], clients) -> list[float]:
    return [
        task.accuracy(p, c.test_x, c.test_y)
        for p, c in zip(client_params, clients)
    ]


def stack_eval_arrays(clients) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pad the K ragged test sets to one (K, L, ...) batch for stacked eval.

    Padding wraps each client's own test set (so padded rows are valid
    inputs, never zeros) and a (K, L) ``live`` mask marks the real rows.
    Build once and reuse — these arrays are round-invariant.
    """
    L = max(len(c.test_y) for c in clients)
    xs, ys, lives = [], [], []
    for c in clients:
        n = len(c.test_y)
        idx = np.resize(np.arange(n), L)
        xs.append(c.test_x[idx])
        ys.append(c.test_y[idx])
        lives.append(np.arange(L) < n)
    return (jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
            jnp.asarray(np.stack(lives)))


def evaluate_clients_stacked(task: Task, stacked_params: PyTree, clients,
                             arrays=None) -> list[float]:
    """One vmapped launch replacing the per-client host eval loop.

    Per client this computes ``sum(correct ∧ live) / sum(live)`` — the live
    count is exactly ``len(test_y)`` and 0/1 sums are exact in fp32, so the
    result matches ``evaluate_clients`` bit for bit (golden-tested in
    tests/test_scale_engine.py).  ``arrays`` is an optional pre-built
    ``stack_eval_arrays(clients)`` to amortize the padding across rounds.
    """
    if arrays is None:
        arrays = stack_eval_arrays(clients)
    x, y, live = arrays
    accs = task._acc_stacked(stacked_params, x, y, live)
    return [float(a) for a in accs]


def rounds_to_targets(history: list[float], targets: list[float]) -> dict[float, int]:
    out = {}
    for t in targets:
        hit = next((i + 1 for i, a in enumerate(history) if a >= t), -1)
        out[t] = hit
    return out
