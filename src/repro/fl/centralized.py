"""Centralized baselines (paper §4.1 / App. B.4) as engine hooks: Local,
FedAvg, FedAvg-FT, Ditto, FOMO, SubFedAvg.

All share the busiest-node constraint: the server touches at most
``cfg.degree`` clients per round (matching the decentralized degree bound).
Client selection draws from the round-level rng stream, so it is
reproducible under resume and independent of client iteration order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import centralized_comm, sparse_training_flops
from repro.core.gossip import gossip_average_one
from repro.core.masks import default_sparsifiable
from repro.fl.base import (
    FLConfig,
    FLResult,
    Task,
    finetune_clients,
    local_sgd,
)
from repro.fl.engine import (
    STREAM_EVAL,
    RoundCtx,
    StrategyBase,
    derive_rng,
    register,
    run_strategy,
)
from repro.utils.tree import tree_map_with_path, tree_nnz, tree_size


def _mean_trees(trees, weights=None):
    n = len(trees)
    if weights is None:
        weights = [1.0 / n] * n
    acc = jax.tree.map(lambda x: weights[0] * x, trees[0])
    for w, t in zip(weights[1:], trees[1:]):
        acc = jax.tree.map(lambda a, x: a + w * x, acc, t)
    return acc


def _dense_flops(task: Task, n_samples: int, cfg: FLConfig):
    return sparse_training_flops(
        task.fwd_flops, {k: 1.0 for k in task.fwd_flops}, n_samples,
        cfg.local_epochs, mask_search_batches=0, batch_size=cfg.batch_size)


# ---------------------------------------------------------------------------
# Local-only
# ---------------------------------------------------------------------------


@register("local")
class LocalStrategy(StrategyBase):
    vmap_capable = True

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(clients))
        params = [task.init_fn(k) for k in keys]
        self.n_coords = tree_size(params[0])
        return {"params": params}

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k))

    def round_comm(self, state: dict, ctx: RoundCtx):
        return centralized_comm(0, [0], self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        return _dense_flops(self.task, self.n_samples, ctx.cfg)


# ---------------------------------------------------------------------------
# FedAvg / FedAvg-FT
# ---------------------------------------------------------------------------


@register("fedavg", finetune=False)
@register("fedavg_ft", finetune=True)
class FedAvgStrategy(StrategyBase):
    """State: ``{"w_global": tree}``.  Selected clients train from the
    global model; ``post_round`` re-aggregates by sample counts."""

    vmap_capable = True

    def __init__(self, finetune: bool = False):
        self.finetune = finetune

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        w0 = task.init_fn(jax.random.PRNGKey(cfg.seed))
        self.n_sel = min(cfg.degree, len(clients))
        self.n_coords = tree_size(w0)
        return {"w_global": w0}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        sel = ctx.round_rng().choice(len(self.clients), size=self.n_sel,
                                     replace=False)
        state["_sel"] = [int(k) for k in sel]
        state["_locals"] = {}

    def active_clients(self, state: dict, ctx: RoundCtx):
        return state["_sel"]

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["_locals"][k] = local_sgd(
            self.task, state["w_global"], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k))

    # vmap adapters: every selected client starts from the global model
    def local_params(self, state: dict, k: int):
        return state["w_global"]

    def set_local(self, state: dict, k: int, params) -> None:
        state["_locals"][k] = params

    def post_round(self, state: dict, ctx: RoundCtx) -> None:
        sel = state.pop("_sel")
        locals_ = state.pop("_locals")
        sizes = [self.clients[k].n_train for k in sel]
        weights = [s / sum(sizes) for s in sizes]
        state["w_global"] = _mean_trees([locals_[k] for k in sel], weights)

    def _broadcast(self, state: dict):
        return [state["w_global"]] * len(self.clients)

    def eval_params(self, state: dict, ctx: RoundCtx):
        params = self._broadcast(state)
        if not self.finetune:
            return params
        return finetune_clients(
            self.task, params, self.clients, self.cfg.ft_epochs,
            self.cfg.batch_size, ctx.lr, self.opt, ctx.eval_rng)

    def finalize_eval_params(self, state: dict):
        params = self._broadcast(state)
        if not self.finetune:
            return params
        cfg = self.cfg
        return finetune_clients(
            self.task, params, self.clients, cfg.ft_epochs, cfg.batch_size,
            cfg.lr_at(cfg.rounds), self.opt,
            lambda k: derive_rng(cfg.seed, cfg.rounds, k, stream=STREAM_EVAL))

    def round_comm(self, state: dict, ctx: RoundCtx):
        return centralized_comm(self.n_sel, [self.n_coords] * self.n_sel,
                                self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        return _dense_flops(self.task, self.n_samples, ctx.cfg)


# ---------------------------------------------------------------------------
# Ditto
# ---------------------------------------------------------------------------


@register("ditto")
class DittoStrategy(StrategyBase):
    """Global FedAvg trajectory + per-client personal model with a proximal
    pull toward the global model (Li et al. 2021b).  Per the paper's fair
    budget: 3 epochs on the global model, 2 on the personal one.  The
    interleaved prox loop keeps this on the per-client path (not vmap)."""

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        k_clients = len(clients)
        keyring = jax.random.split(jax.random.PRNGKey(cfg.seed), k_clients + 1)
        w_global = task.init_fn(keyring[0])
        personal = [task.init_fn(keyring[k + 1]) for k in range(k_clients)]
        self.n_sel = min(cfg.degree, k_clients)
        self.n_coords = tree_size(w_global)
        self.g_epochs = max(1, (cfg.local_epochs * 3) // 5)
        self.p_epochs = max(1, cfg.local_epochs - self.g_epochs)
        return {"w_global": w_global, "personal": personal}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        sel = ctx.round_rng().choice(len(self.clients), size=self.n_sel,
                                     replace=False)
        state["_sel"] = [int(k) for k in sel]
        state["_locals"] = {}

    def active_clients(self, state: dict, ctx: RoundCtx):
        return state["_sel"]

    def _prox_step(self, params, ref, x, y, lr):
        cfg = self.cfg
        _, grads = self.task.value_and_grad(params, x, y)
        grads = jax.tree.map(
            lambda g, w, r: g + cfg.prox_lambda * (w - r), grads, params, ref)
        return jax.tree.map(lambda w, g: w - lr * (g + cfg.weight_decay * w),
                            params, grads)

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        cfg = ctx.cfg
        rng = ctx.client_rng(k)
        w_global = state["w_global"]
        state["_locals"][k] = local_sgd(
            self.task, w_global, c.train_x, c.train_y, self.g_epochs,
            cfg.batch_size, ctx.lr, self.opt, rng)
        # personal model: prox-SGD toward the (old) global model
        v = state["personal"][k]
        bs = min(cfg.batch_size, c.n_train)
        for _ in range(self.p_epochs):
            order = rng.permutation(c.n_train)
            pad = (-len(order)) % bs
            if pad:
                order = np.concatenate([order, order[:pad]])
            for i in range(0, len(order), bs):
                s = order[i: i + bs]
                v = self._prox_step(v, w_global, c.train_x[s], c.train_y[s],
                                    ctx.lr)
        state["personal"][k] = v

    def post_round(self, state: dict, ctx: RoundCtx) -> None:
        sel = state.pop("_sel")
        locals_ = state.pop("_locals")
        sizes = [self.clients[k].n_train for k in sel]
        weights = [s / sum(sizes) for s in sizes]
        state["w_global"] = _mean_trees([locals_[k] for k in sel], weights)

    def local_params(self, state: dict, k: int):
        # what a Ditto client puts on the wire is its copy of the global
        # model (the personal model never leaves the device)
        return state["w_global"]

    def set_local(self, state: dict, k: int, params) -> None:
        state["w_global"] = params

    def eval_params(self, state: dict, ctx: RoundCtx):
        return state["personal"]

    def finalize_eval_params(self, state: dict):
        return state["personal"]

    def round_comm(self, state: dict, ctx: RoundCtx):
        return centralized_comm(self.n_sel, [self.n_coords] * self.n_sel,
                                self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        return _dense_flops(self.task, self.n_samples, ctx.cfg)


# ---------------------------------------------------------------------------
# FOMO
# ---------------------------------------------------------------------------


@register("fomo")
class FOMOStrategy(StrategyBase):
    """First-order model optimization (Zhang et al. 2020): clients weight
    the received models by the first-order utility
        u_j = max(L_k(w_k) - L_k(w_j), 0) / ||w_j - w_k||
    and move toward the useful ones before local training."""

    vmap_capable = True

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(clients))
        params = [task.init_fn(k) for k in keys]
        self.n_nbrs = min(cfg.degree, len(clients) - 1)
        self.n_coords = tree_size(params[0])
        return {"params": params}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        params = state["params"]
        k_clients = len(params)
        mixed_all = []
        for k in range(k_clients):
            rng = ctx.client_rng(k)
            c = self.clients[k]
            xb, yb = c.sample_batch(rng, ctx.cfg.batch_size)
            own_loss, _ = self.task.value_and_grad(params[k], xb, yb)
            nbrs = rng.choice([j for j in range(k_clients) if j != k],
                              size=self.n_nbrs, replace=False)
            mixed = params[k]
            weights, deltas = [], []
            for j in nbrs:
                lj, _ = self.task.value_and_grad(params[j], xb, yb)
                delta = jax.tree.map(jnp.subtract, params[j], params[k])
                norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(d))
                                          for d in jax.tree.leaves(delta)))) + 1e-8
                u = max(float(own_loss) - float(lj), 0.0) / norm
                weights.append(u)
                deltas.append(delta)
            tot = sum(weights)
            if tot > 0:
                for u, d in zip(weights, deltas):
                    mixed = jax.tree.map(lambda m, x: m + (u / tot) * x,
                                         mixed, d)
            mixed_all.append(mixed)
        state["params"] = mixed_all

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k))

    def round_comm(self, state: dict, ctx: RoundCtx):
        n = self.n_nbrs
        return centralized_comm(n, [self.n_coords] * n, self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        return _dense_flops(self.task, self.n_samples, ctx.cfg)


# ---------------------------------------------------------------------------
# SubFedAvg (dense-to-sparse personalized subnetworks)
# ---------------------------------------------------------------------------


@register("subfedavg")
class SubFedAvgStrategy(StrategyBase):
    """Vahidian et al. 2021: clients start dense and iteratively magnitude-
    prune toward ``cfg.density`` as rounds progress; the server averages on
    the unpruned intersections (same intersection math as DisPFL's gossip,
    but star topology and dense-to-sparse)."""

    vmap_capable = True

    def __init__(self, prune_per_round: float = 0.05):
        self.prune_per_round = prune_per_round

    def init_state(self, task: Task, clients, cfg: FLConfig) -> dict:
        super().init_state(task, clients, cfg)
        k_clients = len(clients)
        w0 = task.init_fn(jax.random.PRNGKey(cfg.seed))
        params = [jax.tree.map(lambda x: x, w0) for _ in range(k_clients)]
        masks = [jax.tree.map(lambda x: jnp.ones(x.shape, jnp.float32), w0)
                 for _ in range(k_clients)]
        self.n_sel = min(cfg.degree, k_clients)
        self.n_coords = tree_size(w0)
        return {"params": params, "masks": masks}

    def mix(self, state: dict, ctx: RoundCtx) -> None:
        sel = [int(k) for k in ctx.round_rng().choice(
            len(self.clients), size=self.n_sel, replace=False)]
        state["_sel"] = sel
        params, masks = state["params"], state["masks"]
        averaged = {}
        for k in sel:
            others = [j for j in sel if j != k]
            averaged[k] = gossip_average_one(
                params[k], masks[k],
                [params[j] for j in others], [masks[j] for j in others])
        for k in sel:
            state["params"][k] = averaged[k]

    def active_clients(self, state: dict, ctx: RoundCtx):
        return state["_sel"]

    def local_update(self, state: dict, k: int, ctx: RoundCtx) -> None:
        c = self.clients[k]
        state["params"][k] = local_sgd(
            self.task, state["params"][k], c.train_x, c.train_y,
            ctx.cfg.local_epochs, ctx.cfg.batch_size, ctx.lr, self.opt,
            ctx.client_rng(k), mask=state["masks"][k])

    def local_mask(self, state: dict, k: int):
        return state["masks"][k]

    def evolve(self, state: dict, k: int, ctx: RoundCtx) -> None:
        # dense-to-sparse: magnitude-prune a further slice per round
        if _tree_density(state["masks"][k]) > ctx.cfg.density:
            state["masks"][k], state["params"][k] = _magnitude_prune(
                state["params"][k], state["masks"][k], self.prune_per_round,
                ctx.cfg.density)

    def post_round(self, state: dict, ctx: RoundCtx) -> None:
        state.pop("_sel")

    def round_comm(self, state: dict, ctx: RoundCtx):
        # worst case: the server's n_sel connections carry the heaviest
        # current models (centralized_comm truncates to n_sel)
        nnz = sorted((tree_nnz(state["masks"][k]) for k in
                      range(len(self.clients))), reverse=True)
        return centralized_comm(self.n_sel, nnz, self.n_coords)

    def round_flops(self, state: dict, ctx: RoundCtx):
        mean_density = float(np.mean(
            [_tree_density(m) for m in state["masks"]]))
        densities = {k: mean_density for k in self.task.fwd_flops}
        return sparse_training_flops(
            self.task.fwd_flops, densities, self.n_samples,
            ctx.cfg.local_epochs, mask_search_batches=0,
            batch_size=ctx.cfg.batch_size)


def _tree_density(mask) -> float:
    tot = tree_size(mask)
    return tree_nnz(mask) / max(tot, 1)


def _magnitude_prune(params, mask, rate: float, floor: float):
    """Prune ``rate`` of remaining weights per sparsifiable layer (not below
    ``floor`` density)."""
    def one(path, w, m):
        if not default_sparsifiable(path, w):
            return m, w
        n = int(np.prod(w.shape))
        cur = int(jnp.sum(m > 0))
        target = max(int(n * floor), int(cur * (1.0 - rate)))
        if target >= cur:
            return m, w
        from repro.core.evolve import _exact_topk_mask
        scores = jnp.where(m.reshape(-1) > 0, jnp.abs(w.reshape(-1)), -jnp.inf)
        new_m = _exact_topk_mask(scores, target).reshape(w.shape)
        return new_m.astype(m.dtype), w * new_m.astype(w.dtype)

    paired = tree_map_with_path(one, params, mask)
    new_mask = jax.tree.map(lambda t: t[0], paired,
                            is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda t: t[1], paired,
                              is_leaf=lambda x: isinstance(x, tuple))
    return new_mask, new_params


# ---------------------------------------------------------------------------
# Back-compat wrappers (engine run -> FLResult)
# ---------------------------------------------------------------------------


def run_local(task: Task, clients, cfg: FLConfig, targets=(0.5,),
              **engine_kw) -> FLResult:
    return run_strategy("local", task, clients, cfg, targets=targets,
                        **engine_kw)


def run_fedavg(task: Task, clients, cfg: FLConfig, finetune: bool = False,
               targets=(0.5,), **engine_kw) -> FLResult:
    return run_strategy("fedavg", task, clients, cfg, targets=targets,
                        finetune=finetune, **engine_kw)


def run_ditto(task: Task, clients, cfg: FLConfig, targets=(0.5,),
              **engine_kw) -> FLResult:
    return run_strategy("ditto", task, clients, cfg, targets=targets,
                        **engine_kw)


def run_fomo(task: Task, clients, cfg: FLConfig, targets=(0.5,),
             **engine_kw) -> FLResult:
    return run_strategy("fomo", task, clients, cfg, targets=targets,
                        **engine_kw)


def run_subfedavg(task: Task, clients, cfg: FLConfig,
                  prune_per_round: float = 0.05, targets=(0.5,),
                  **engine_kw) -> FLResult:
    return run_strategy("subfedavg", task, clients, cfg, targets=targets,
                        prune_per_round=prune_per_round, **engine_kw)
