"""Centralized baselines (paper §4.1 / App. B.4): Local, FedAvg, FedAvg-FT,
Ditto, FOMO, SubFedAvg.

All share the busiest-node constraint: the server touches at most
``cfg.degree`` clients per round (matching the decentralized degree bound).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import centralized_comm, decentralized_comm, sparse_training_flops
from repro.core.evolve import evolve_mask_layer
from repro.core.gossip import gossip_average_one
from repro.core.masks import apply_mask, default_sparsifiable, erk_densities_for_params
from repro.fl.base import (
    FLConfig,
    FLResult,
    Task,
    evaluate_clients,
    local_sgd,
    rounds_to_targets,
)
from repro.fl.decentralized import _finetune_all
from repro.optim import SGDConfig, init_sgd, sgd_step
from repro.utils.tree import (
    tree_leaves_with_path,
    tree_map_with_path,
    tree_nnz,
    tree_size,
)


def _mean_trees(trees, weights=None):
    n = len(trees)
    if weights is None:
        weights = [1.0 / n] * n
    acc = jax.tree.map(lambda x: weights[0] * x, trees[0])
    for w, t in zip(weights[1:], trees[1:]):
        acc = jax.tree.map(lambda a, x: a + w * x, acc, t)
    return acc


def _result(task, clients, cfg, history, final, comm, densities=None,
            mask_batches=0, targets=(0.5,)):
    n_samples = int(np.mean([c.n_train for c in clients]))
    flops = sparse_training_flops(
        task.fwd_flops, densities or {k: 1.0 for k in task.fwd_flops},
        n_samples, cfg.local_epochs, mask_search_batches=mask_batches,
        batch_size=cfg.batch_size)
    return FLResult(
        acc_history=history, final_accs=final,
        comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
        flops_per_round=flops.per_round_flops, flops_rows=flops.row(),
        rounds_to=rounds_to_targets(history, list(targets)))


# ---------------------------------------------------------------------------
# Local-only
# ---------------------------------------------------------------------------


def run_local(task: Task, clients, cfg: FLConfig, targets=(0.5,)) -> FLResult:
    rng = np.random.default_rng(cfg.seed)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(clients))
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    params = [task.init_fn(k) for k in keys]
    history = []
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        params = [
            local_sgd(task, params[k], c.train_x, c.train_y, cfg.local_epochs,
                      cfg.batch_size, lr, opt, rng)
            for k, c in enumerate(clients)
        ]
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, params, clients))))
    final = evaluate_clients(task, params, clients)
    comm = centralized_comm(0, [0], tree_size(params[0]))
    return _result(task, clients, cfg, history, final, comm, targets=targets)


# ---------------------------------------------------------------------------
# FedAvg / FedAvg-FT
# ---------------------------------------------------------------------------


def run_fedavg(task: Task, clients, cfg: FLConfig, finetune: bool = False,
               targets=(0.5,)) -> FLResult:
    k_clients = len(clients)
    rng = np.random.default_rng(cfg.seed)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    w_global = task.init_fn(jax.random.PRNGKey(cfg.seed))
    n_sel = min(cfg.degree, k_clients)
    history = []
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        sel = rng.choice(k_clients, size=n_sel, replace=False)
        locals_, sizes = [], []
        for k in sel:
            c = clients[k]
            w = local_sgd(task, w_global, c.train_x, c.train_y,
                          cfg.local_epochs, cfg.batch_size, lr, opt, rng)
            locals_.append(w)
            sizes.append(c.n_train)
        weights = [s / sum(sizes) for s in sizes]
        w_global = _mean_trees(locals_, weights)
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            eval_params = [w_global] * k_clients
            if finetune:
                eval_params = _finetune_all(task, eval_params, clients, cfg, lr, rng)
            history.append(float(np.mean(evaluate_clients(task, eval_params, clients))))
    final_params = [w_global] * k_clients
    if finetune:
        final_params = _finetune_all(task, final_params, clients, cfg,
                                     cfg.lr_at(cfg.rounds), rng)
    final = evaluate_clients(task, final_params, clients)
    n_coords = tree_size(w_global)
    comm = centralized_comm(n_sel, [n_coords] * n_sel, n_coords)
    return _result(task, clients, cfg, history, final, comm, targets=targets)


# ---------------------------------------------------------------------------
# Ditto
# ---------------------------------------------------------------------------


def run_ditto(task: Task, clients, cfg: FLConfig, targets=(0.5,)) -> FLResult:
    """Global FedAvg trajectory + per-client personal model with a proximal
    pull toward the global model (Li et al. 2021b).  Per the paper's fair
    budget: 3 epochs on the global model, 2 on the personal one."""
    k_clients = len(clients)
    rng = np.random.default_rng(cfg.seed)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    keyring = jax.random.split(jax.random.PRNGKey(cfg.seed), k_clients + 1)
    w_global = task.init_fn(keyring[0])
    personal = [task.init_fn(keyring[k + 1]) for k in range(k_clients)]
    n_sel = min(cfg.degree, k_clients)
    g_epochs = max(1, (cfg.local_epochs * 3) // 5)
    p_epochs = max(1, cfg.local_epochs - g_epochs)
    history = []

    def prox_step(params, ref, x, y, lr):
        loss, grads = task.value_and_grad(params, x, y)
        grads = jax.tree.map(
            lambda g, w, r: g + cfg.prox_lambda * (w - r), grads, params, ref)
        return jax.tree.map(lambda w, g: w - lr * (g + cfg.weight_decay * w),
                            params, grads)

    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        sel = rng.choice(k_clients, size=n_sel, replace=False)
        locals_, sizes = [], []
        for k in sel:
            c = clients[k]
            w = local_sgd(task, w_global, c.train_x, c.train_y, g_epochs,
                          cfg.batch_size, lr, opt, rng)
            locals_.append(w)
            sizes.append(c.n_train)
            # personal model: prox-SGD toward the (old) global model
            v = personal[k]
            bs = min(cfg.batch_size, c.n_train)
            for _ in range(p_epochs):
                order = rng.permutation(c.n_train)
                pad = (-len(order)) % bs
                if pad:
                    order = np.concatenate([order, order[:pad]])
                for i in range(0, len(order), bs):
                    s = order[i: i + bs]
                    v = prox_step(v, w_global, c.train_x[s], c.train_y[s], lr)
            personal[k] = v
        weights = [s / sum(sizes) for s in sizes]
        w_global = _mean_trees(locals_, weights)
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, personal, clients))))
    final = evaluate_clients(task, personal, clients)
    n_coords = tree_size(w_global)
    comm = centralized_comm(n_sel, [n_coords] * n_sel, n_coords)
    return _result(task, clients, cfg, history, final, comm, targets=targets)


# ---------------------------------------------------------------------------
# FOMO
# ---------------------------------------------------------------------------


def run_fomo(task: Task, clients, cfg: FLConfig, targets=(0.5,)) -> FLResult:
    """First-order model optimization (Zhang et al. 2020): clients weight the
    received models by the first-order utility
        u_j = max(L_k(w_k) - L_k(w_j), 0) / ||w_j - w_k||
    and move toward the useful ones before local training."""
    k_clients = len(clients)
    rng = np.random.default_rng(cfg.seed)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), k_clients)
    params = [task.init_fn(k) for k in keys]
    n_nbrs = min(cfg.degree, k_clients - 1)
    history = []
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        new_params = []
        for k in range(k_clients):
            c = clients[k]
            xb, yb = c.sample_batch(rng, cfg.batch_size)
            own_loss, _ = task.value_and_grad(params[k], xb, yb)
            nbrs = rng.choice([j for j in range(k_clients) if j != k],
                              size=n_nbrs, replace=False)
            mixed = params[k]
            weights, deltas = [], []
            for j in nbrs:
                lj, _ = task.value_and_grad(params[j], xb, yb)
                delta = jax.tree.map(jnp.subtract, params[j], params[k])
                norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(d))
                                          for d in jax.tree.leaves(delta)))) + 1e-8
                u = max(float(own_loss) - float(lj), 0.0) / norm
                weights.append(u)
                deltas.append(delta)
            tot = sum(weights)
            if tot > 0:
                for u, d in zip(weights, deltas):
                    mixed = jax.tree.map(lambda m, x: m + (u / tot) * x, mixed, d)
            w = local_sgd(task, mixed, c.train_x, c.train_y, cfg.local_epochs,
                          cfg.batch_size, lr, opt, rng)
            new_params.append(w)
        params = new_params
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, params, clients))))
    final = evaluate_clients(task, params, clients)
    n_coords = tree_size(params[0])
    comm = centralized_comm(min(cfg.degree, k_clients),
                            [n_coords] * min(cfg.degree, k_clients), n_coords)
    return _result(task, clients, cfg, history, final, comm, targets=targets)


# ---------------------------------------------------------------------------
# SubFedAvg (dense-to-sparse personalized subnetworks)
# ---------------------------------------------------------------------------


def run_subfedavg(task: Task, clients, cfg: FLConfig, prune_per_round: float = 0.05,
                  targets=(0.5,)) -> FLResult:
    """Vahidian et al. 2021: clients start dense and iteratively magnitude-
    prune toward ``cfg.density`` as rounds progress; the server averages on
    the unpruned intersections (same intersection math as DisPFL's gossip,
    but star topology and dense-to-sparse)."""
    k_clients = len(clients)
    rng = np.random.default_rng(cfg.seed)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    w0 = task.init_fn(jax.random.PRNGKey(cfg.seed))
    params = [jax.tree.map(lambda x: x, w0) for _ in range(k_clients)]
    masks = [jax.tree.map(lambda x: jnp.ones(x.shape, jnp.float32), w0)
             for _ in range(k_clients)]
    n_sel = min(cfg.degree, k_clients)
    history = []
    density_track = []
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        sel = list(rng.choice(k_clients, size=n_sel, replace=False))
        # server-side intersection average for each selected client
        averaged = {}
        for k in sel:
            others = [j for j in sel if j != k]
            averaged[k] = gossip_average_one(
                params[k], masks[k],
                [params[j] for j in others], [masks[j] for j in others])
        for k in sel:
            c = clients[k]
            w = local_sgd(task, averaged[k], c.train_x, c.train_y,
                          cfg.local_epochs, cfg.batch_size, lr, opt, rng,
                          mask=masks[k])
            # dense-to-sparse: magnitude-prune a further slice per round
            cur_density = _tree_density(masks[k])
            if cur_density > cfg.density:
                masks[k], w = _magnitude_prune(w, masks[k], prune_per_round,
                                               cfg.density)
            params[k] = w
        density_track.append(float(np.mean([_tree_density(m) for m in masks])))
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, params, clients))))
    final = evaluate_clients(task, params, clients)
    n_coords = tree_size(w0)
    nnz = [tree_nnz(m) for m in masks]
    comm = centralized_comm(n_sel, sorted(nnz, reverse=True), n_coords)
    mean_density = float(np.mean(density_track))
    densities = {k: mean_density for k in task.fwd_flops}
    return _result(task, clients, cfg, history, final, comm,
                   densities=densities, targets=targets)


def _tree_density(mask) -> float:
    tot = tree_size(mask)
    return tree_nnz(mask) / max(tot, 1)


def _magnitude_prune(params, mask, rate: float, floor: float):
    """Prune ``rate`` of remaining weights per sparsifiable layer (not below
    ``floor`` density)."""
    def one(path, w, m):
        if not default_sparsifiable(path, w):
            return m, w
        n = int(np.prod(w.shape))
        cur = int(jnp.sum(m > 0))
        target = max(int(n * floor), int(cur * (1.0 - rate)))
        if target >= cur:
            return m, w
        from repro.core.evolve import _exact_topk_mask
        scores = jnp.where(m.reshape(-1) > 0, jnp.abs(w.reshape(-1)), -jnp.inf)
        new_m = _exact_topk_mask(scores, target).reshape(w.shape)
        return new_m.astype(m.dtype), w * new_m.astype(w.dtype)

    paired = tree_map_with_path(one, params, mask)
    new_mask = jax.tree.map(lambda t: t[0], paired,
                            is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda t: t[1], paired,
                              is_leaf=lambda x: isinstance(x, tuple))
    return new_mask, new_params
