"""Config module for --arch seamless-m4t-large-v2 (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import SEAMLESS_M4T_LARGE_V2 as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["seamless-m4t-large-v2"]
