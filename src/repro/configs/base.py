"""Architecture config schema + layer-pattern resolution.

Every assigned architecture is a ``ModelConfig``; ``layer_kinds(cfg)``
expands it into a per-layer sequence of sublayer descriptors consumed by the
decoder stack (models/lm.py).  Patterns are periodic so the stack can
``lax.scan`` over same-structure blocks (HLO stays small for 72-layer models).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    act: str = "silu"
    mlp_gated: bool = True
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float = 0.0

    # --- attention pattern: period of alternating local/global layers.
    # sliding_window > 0 with local_period p means layers i%p != p-1 are
    # local (windowed); the last layer in each period is global.
    sliding_window: int = 0
    local_period: int = 0

    # --- MoE: layers i with i % moe_period == moe_offset are MoE
    moe: Optional[MoESpec] = None
    moe_period: int = 1
    moe_offset: int = 0
    dense_ff_first: int = 0  # deepseek-moe: layer 0 uses a dense MLP this wide

    # --- SSM / hybrid: layers i with i % attn_period == attn_offset are
    # attention; the rest are SSM blocks (jamba 1:7 -> attn_period=8).
    ssm: Optional[SSMSpec] = None
    attn_period: int = 0     # 0 -> all attention; 1 -> all ssm handled below
    attn_offset: int = 0
    all_ssm: bool = False    # mamba2: no attention at all

    # --- encoder-decoder (audio) --------------------------------------
    enc_layers: int = 0      # >0 -> enc-dec; encoder consumes stub embeddings

    # --- multimodal stub prefix (vlm/audio frontends) ------------------
    prefix_len: int = 0      # patch/frame embeddings prepended to the text

    source: str = ""         # citation for the config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class SubLayer:
    kind: str                 # 'attn' | 'ssm'
    window: int = 0           # 0 = full causal attention
    ffn: str = "mlp"          # 'mlp' | 'moe' | 'none'
    d_ff_override: int = 0


def layer_kinds(cfg: ModelConfig) -> list[SubLayer]:
    """Expand the config into one SubLayer per decoder layer."""
    out = []
    for i in range(cfg.n_layers):
        # mixer
        if cfg.all_ssm:
            kind, window = "ssm", 0
        elif cfg.attn_period > 0 and cfg.ssm is not None:
            if i % cfg.attn_period == cfg.attn_offset:
                kind, window = "attn", 0
            else:
                kind, window = "ssm", 0
        else:
            kind = "attn"
            window = 0
            if cfg.local_period > 0 and cfg.sliding_window > 0:
                if i % cfg.local_period != cfg.local_period - 1:
                    window = cfg.sliding_window
            elif cfg.sliding_window > 0:
                window = cfg.sliding_window
        # ffn
        if cfg.all_ssm:
            ffn = "none"  # mamba2 blocks have no separate FFN
            d_over = 0
        elif cfg.moe is not None and i % cfg.moe_period == cfg.moe_offset:
            if i == 0 and cfg.dense_ff_first > 0:
                ffn, d_over = "mlp", cfg.dense_ff_first
            else:
                ffn, d_over = "moe", 0
        else:
            ffn, d_over = "mlp", 0
        if i == 0 and cfg.dense_ff_first > 0 and ffn != "mlp":
            ffn, d_over = "mlp", cfg.dense_ff_first
        out.append(SubLayer(kind=kind, window=window, ffn=ffn, d_ff_override=d_over))
    return out


def pattern_period(cfg: ModelConfig) -> int:
    """Smallest period P such that layers i and i+P have identical SubLayer
    structure for all i >= first_regular (layer 0 may be special)."""
    kinds = layer_kinds(cfg)
    # find smallest p dividing the tail (after any special first layer) into
    # identical repeating blocks
    start = 1 if (cfg.dense_ff_first > 0) else 0
    tail = kinds[start:]
    m = len(tail)
    for p in range(1, m + 1):
        if m % p == 0 and all(tail[i] == tail[i % p] for i in range(m)):
            return p
    return m


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
