"""Config module for --arch gemma-2b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import GEMMA_2B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["gemma-2b"]
