"""Config module for --arch llava-next-mistral-7b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import LLAVA_NEXT_MISTRAL_7B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["llava-next-mistral-7b"]
