"""Config module for --arch qwen3-8b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import QWEN3_8B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["qwen3-8b"]
