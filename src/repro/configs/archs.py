"""The 10 assigned architectures (exact configs, sources in brackets) plus
reduced smoke variants (2 layers, d_model<=512, <=4 experts) used by the
per-arch CPU smoke tests.  FULL configs are exercised only via the dry-run.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, MoESpec, SSMSpec

# ---------------------------------------------------------------------------
# Full configs
# ---------------------------------------------------------------------------

GEMMA3_1B = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144, act="gelu", mlp_gated=True,
    sliding_window=1024, local_period=6,       # 5 local : 1 global
    rope_theta=1_000_000.0, tie_embeddings=True,
    source="[hf:google/gemma-3-1b-pt]",
)

JAMBA_1_5_LARGE = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, act="silu",
    ssm=SSMSpec(d_state=128, expand=2, head_dim=128, conv_width=4, chunk=256),
    attn_period=8, attn_offset=4,              # 1 attn : 7 mamba
    moe=MoESpec(n_experts=16, top_k=2, d_expert=24576),
    moe_period=2, moe_offset=1,                # MoE every other layer
    tie_embeddings=False,
    source="[arXiv:2403.19887]",
)

MAMBA2_1_3B = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280,
    all_ssm=True,
    ssm=SSMSpec(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=256),
    tie_embeddings=True,
    source="[arXiv:2405.21060]",
)

DEEPSEEK_MOE_16B = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400, act="silu",
    moe=MoESpec(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    moe_period=1, dense_ff_first=10944,        # layer 0 is a dense MLP
    tie_embeddings=False,
    source="[arXiv:2401.06066]",
)

SEAMLESS_M4T_LARGE_V2 = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, act="gelu", mlp_gated=False,
    enc_layers=24,                             # speech encoder (stub frontend)
    tie_embeddings=True,
    source="[arXiv:2308.11596]",
)

GEMMA_2B = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="gelu", mlp_gated=True,  # GeGLU, MQA
    tie_embeddings=True,
    source="[arXiv:2403.08295]",
)

QWEN3_8B = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936, act="silu", qk_norm=True,
    rope_theta=1_000_000.0, tie_embeddings=False,
    source="[hf:Qwen/Qwen3-8B]",
)

STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab=49152, act="gelu", mlp_gated=False, use_bias=True,
    rope_theta=1_000_000.0, tie_embeddings=True,
    source="[arXiv:2402.19173]",
)

LLAVA_NEXT_MISTRAL_7B = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, act="silu",
    prefix_len=2880,                           # anyres: up to 5 tiles x 576
    tie_embeddings=False,
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf]",
)

QWEN3_MOE_30B_A3B = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, act="silu", qk_norm=True,
    moe=MoESpec(n_experts=128, top_k=8, d_expert=768),
    moe_period=1, tie_embeddings=False,
    source="[hf:Qwen/Qwen3-30B-A3B]",
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        GEMMA3_1B, JAMBA_1_5_LARGE, MAMBA2_1_3B, DEEPSEEK_MOE_16B,
        SEAMLESS_M4T_LARGE_V2, GEMMA_2B, QWEN3_8B, STARCODER2_7B,
        LLAVA_NEXT_MISTRAL_7B, QWEN3_MOE_30B_A3B,
    ]
}


# ---------------------------------------------------------------------------
# Reduced smoke variants: same family/pattern, tiny dims
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """2 layers (or one full period), d_model<=512, <=4 experts, small vocab."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        d_model=256, vocab=512,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        prefix_len=8 if cfg.prefix_len else 0,
    )
    if cfg.ssm is not None:
        kw["ssm"] = SSMSpec(d_state=16, expand=2, head_dim=32, conv_width=4, chunk=32)
    if cfg.moe is not None:
        kw["moe"] = MoESpec(n_experts=4, top_k=2,
                            d_expert=128, n_shared=min(cfg.moe.n_shared, 1))
    if cfg.attn_period > 0 and cfg.ssm is not None:
        kw["n_layers"] = cfg.attn_period          # one full hybrid period
        kw["attn_offset"] = cfg.attn_offset % cfg.attn_period
    elif cfg.local_period > 0:
        kw["n_layers"] = cfg.local_period
        kw["sliding_window"] = 16
    else:
        kw["n_layers"] = 2
    if cfg.dense_ff_first > 0:
        kw["dense_ff_first"] = 256
        kw["n_layers"] = 3                        # prelude + 2 moe layers
    if cfg.enc_layers > 0:
        kw["enc_layers"] = 2
        kw["n_layers"] = 2
    return cfg.replace(**kw)


SMOKE_ARCHS: dict[str, ModelConfig] = {name: reduced(c) for name, c in ARCHS.items()}
