"""Config module for --arch jamba-1.5-large-398b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import JAMBA_1_5_LARGE as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["jamba-1.5-large-398b"]
