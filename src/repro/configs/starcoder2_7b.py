"""Config module for --arch starcoder2-7b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import STARCODER2_7B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["starcoder2-7b"]
