"""Config module for --arch mamba2-1.3b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import MAMBA2_1_3B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["mamba2-1.3b"]
