"""Config module for --arch deepseek-moe-16b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import DEEPSEEK_MOE_16B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["deepseek-moe-16b"]
