from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoESpec,
    SSMSpec,
    SubLayer,
    layer_kinds,
)
from repro.configs.archs import ARCHS, SMOKE_ARCHS, reduced  # noqa: F401


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(table)}")
    return table[name]
