"""Config module for --arch qwen3-moe-30b-a3b (see archs.py for the full definition and
source citation; SMOKE is the reduced per-arch smoke-test variant)."""
from repro.configs.archs import QWEN3_MOE_30B_A3B as CONFIG
from repro.configs.archs import SMOKE_ARCHS

SMOKE = SMOKE_ARCHS["qwen3-moe-30b-a3b"]
