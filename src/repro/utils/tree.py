"""Pytree utilities used across the framework.

All model parameters, masks, gradients and optimizer states are plain nested
dicts of jnp arrays.  These helpers provide path-aware maps, counting, and
RNG splitting without any framework dependency.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def path_str(path) -> str:
    """Render a jax.tree_util key path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree, *rest: PyTree) -> PyTree:
    """Like jax.tree.map but fn receives the string path as first arg."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x, *xs: fn(path_str(kp), x, *xs), tree, *rest
    )


def tree_leaves_with_path(tree: PyTree) -> list[tuple[str, Any]]:
    return [(path_str(kp), leaf) for kp, leaf in jax.tree_util.tree_leaves_with_path(tree)]


def tree_size(tree: PyTree) -> int:
    """Total number of scalar elements."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_nnz(tree: PyTree) -> int:
    """Number of non-zero entries (for masks: active parameter count)."""
    return int(sum(int(jnp.sum(x != 0)) for x in jax.tree.leaves(tree)))


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_ones_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.ones_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_mul(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.multiply, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_dot(a: PyTree, b: PyTree):
    return sum(jnp.vdot(x, y) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def tree_l2(a: PyTree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(a)))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_stack(trees: Iterable[PyTree]) -> PyTree:
    """Stack a list of identically-structured pytrees along a new leading axis."""
    trees = list(trees)
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: PyTree, n: int) -> list[PyTree]:
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_index(tree: PyTree, i) -> PyTree:
    return jax.tree.map(lambda x: x[i], tree)


def split_like(key: jax.Array, tree: PyTree) -> PyTree:
    """One PRNG key per leaf, same structure as `tree`."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def select_by_path(tree: PyTree, pattern: str) -> PyTree:
    """Boolean pytree: True where path matches regex `pattern`."""
    rx = re.compile(pattern)
    return tree_map_with_path(lambda p, x: bool(rx.search(p)), tree)


def count_params(tree: PyTree) -> dict[str, int]:
    """Per-path parameter counts plus 'TOTAL'."""
    out = {p: int(np.prod(x.shape)) for p, x in tree_leaves_with_path(tree)}
    out["TOTAL"] = sum(out.values())
    return out


def check_finite(tree: PyTree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))
