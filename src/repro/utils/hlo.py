"""HLO text analysis: collective-bytes extraction for the roofline.

``compiled.cost_analysis()`` has no collective accounting, so we parse the
post-SPMD HLO (per-device program) and sum the bytes each collective moves.
Shapes in the partitioned module are per-device shard shapes.

Per-op byte conventions (ring algorithms, bytes per device):
  all-gather        : output bytes (each device receives ~full output)
  all-reduce        : 2 x input bytes (reduce-scatter + all-gather phases)
  reduce-scatter    : input bytes
  all-to-all        : input bytes
  collective-permute: input bytes (one neighbor send/recv)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = {
    "all-gather": ("out", 1.0),
    "all-reduce": ("in", 2.0),
    "reduce-scatter": ("in", 1.0),
    "all-to-all": ("in", 1.0),
    "collective-permute": ("in", 1.0),
    "ragged-all-to-all": ("in", 1.0),
}
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"ragged-all-to-all)(?:-start|-done)?\((.*?)\)",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def row(self) -> dict:
        return {
            "total_GB": round(self.total_bytes / 1e9, 4),
            **{k: round(v / 1e9, 4) for k, v in sorted(self.bytes_by_kind.items())},
            "counts": dict(sorted(self.count_by_kind.items())),
        }


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic of a partitioned HLO module.

    ``*-start`` ops are counted; their ``*-done`` halves are skipped to avoid
    double counting.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        out_part, kind, in_part = m.groups()
        side, factor = _COLLECTIVES[kind]
        nbytes = _shape_bytes(out_part if side == "out" else in_part) * factor
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def op_histogram(hlo_text: str, top: int = 20) -> dict[str, int]:
    """Crude opcode histogram (useful for spotting remat recompute and
    layout-change churn in §Perf)."""
    counts: dict[str, int] = {}
    rx = re.compile(r"=\s*[\w\[\]{},. ]*?\s([a-z][a-z0-9-]*)\(")
    for line in hlo_text.splitlines():
        m = rx.search(line)
        if m:
            op = m.group(1)
            counts[op] = counts.get(op, 0) + 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])
