# DisPFL's primary contribution: personalized sparse masks + decentralized
# sparse training (ERK init, intersection gossip, RigL-style mask search).
from repro.core import accounting, evolve, gossip, masks, topology  # noqa: F401
