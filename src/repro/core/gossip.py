"""Intersection-weighted gossip averaging (paper Alg. 1 line 7, Fig. 1b).

Given neighbor models w_j (stored densely but zero outside their masks) and
masks m_j, client k forms

    w_{k,t+1/2} = ( (w_k + sum_j w_j) / (m_k + sum_j m_j) ) ⊙ m_k

i.e. each coordinate is averaged over the subset of peers that actually hold
it.  Non-sparsifiable leaves (all-ones masks) reduce to the plain gossip
average.  Two implementations:

* ``gossip_average_stacked`` — all clients at once via adjacency einsum over a
  stacked client axis.  This is the form lowered onto the TPU mesh (the
  client axis is sharded over 'data'/'pod'; GSPMD emits the collectives) and
  is also what the CPU simulator uses.
* ``gossip_average_one`` — single-client form (list of neighbor trees), used
  by the per-client simulator paths and tests.

The fused elementwise core (num/den ⊙ m) has a Pallas TPU kernel in
``repro.kernels.gossip_avg``; the jnp fallback here is the oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _intersection_avg(num, den, mask):
    """num/den on held coordinates, zero elsewhere.  den>=1 wherever mask=1."""
    den = jnp.maximum(den, 1.0)
    return (num / den) * mask


@partial(jax.jit, static_argnames=())
def gossip_average_stacked(
    stacked_params: PyTree,
    stacked_masks: PyTree,
    adjacency: jax.Array,
) -> PyTree:
    """All-client intersection-weighted gossip.

    Args:
      stacked_params: pytree with leading client dim K on every leaf.
      stacked_masks:  same structure, {0,1} masks (all-ones where dense).
      adjacency: (K, K), A[k, j] = 1 iff k receives j (diag must be 1).

    Returns:
      stacked w_{·,t+1/2}, same structure/shapes.

    Delegates to ``repro.scale.stacked.masked_gossip_stacked`` — the single
    stacked gossip implementation (lazy import so ``core`` stays loadable
    on its own); this fp32-accumulating einsum form is bit-identical to
    the previous inline body for fp32 trees.
    """
    from repro.scale.stacked import masked_gossip_stacked

    return masked_gossip_stacked(stacked_params, stacked_masks, adjacency,
                                 reduction="einsum")


def gossip_average_one(
    own_params: PyTree,
    own_mask: PyTree,
    neighbor_params: list[PyTree],
    neighbor_masks: list[PyTree],
) -> PyTree:
    """Single-client intersection-weighted gossip (paper Alg. 1 line 7)."""

    def one(w, m, *rest):
        n = len(rest) // 2
        ws, ms = rest[:n], rest[n:]
        num = w * m.astype(w.dtype)
        den = m.astype(w.dtype)
        for wj, mj in zip(ws, ms):
            num = num + wj * mj.astype(w.dtype)
            den = den + mj.astype(w.dtype)
        return _intersection_avg(num, den, m.astype(w.dtype))

    return jax.tree.map(
        one, own_params, own_mask, *neighbor_params, *neighbor_masks
    )


@partial(jax.jit, static_argnames=())
def plain_gossip_stacked(stacked_params: PyTree, mixing: jax.Array) -> PyTree:
    """D-PSGD style gossip: w_k <- sum_j W[k,j] w_j with row-stochastic W.
    Delegates to the single stacked implementation in ``repro.scale``."""
    from repro.scale.stacked import plain_mix_stacked

    return plain_mix_stacked(stacked_params, mixing, reduction="einsum")
