"""Communication and computation accounting (paper Table 1/2/3 columns).

The paper reports, per communication round:
  * ``Comm (MB)`` — bytes moved through the *busiest* node.  Convention from
    the paper's released code: payload = 4 bytes per *transmitted value*
    (nnz of the sender's mask); the {0,1} mask bitmap itself is not counted
    in the headline number (we also expose it).  With ``with_bitmap=True``
    the quoted size is the *exact* wire frame of ``repro.sparse.codec``:
    8-byte header + word-aligned bitmap (4 bytes per 32 coordinates) +
    value bytes — analytic and measured reports agree bit for bit.
    Busiest node = max over
    nodes of (bytes uploaded + bytes downloaded)/2 matched to their table:
    for a server with C connections it is C * model_bytes (download == upload
    so a single direction is quoted); for decentralized nodes it is
    degree * payload.
  * ``FLOPS (1e12)`` — total training FLOPs per client per round, counting a
    multiply-add as 2 FLOPs, forward+backward = 3x forward, over
    (local_epochs * n_samples).  Sparse models scale each layer's forward
    FLOPs by its *layer density* (ERK is non-uniform, which is why the paper
    gets 7.0e12 rather than 4.15e12 at global density 0.5), plus one dense
    forward+backward batch per round for the mask-search gradient.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

PyTree = Any

BYTES_PER_VALUE = 4  # fp32 on the wire, per the paper
HEADER_NBYTES = 8    # repro.sparse.codec frame header (magic/version/dtype/nnz)
BITMAP_WORD_NBYTES = 4   # the bitmap packs 32 coordinates per uint32 word


def bitmap_nbytes(n_coords: int) -> int:
    """Exact word-aligned bitmap size over ``n_coords`` coordinates."""
    return BITMAP_WORD_NBYTES * ((n_coords + 31) // 32)


@dataclass
class CommReport:
    busiest_mb: float
    avg_per_node_mb: float
    total_mb: float
    busiest_mb_with_bitmap: float

    def row(self) -> dict:
        return {
            "busiest_MB": round(self.busiest_mb, 1),
            "avg_node_MB": round(self.avg_per_node_mb, 1),
            "total_MB": round(self.total_mb, 1),
            "busiest_MB_with_bitmap": round(self.busiest_mb_with_bitmap, 1),
        }


def payload_bytes(n_values: int, n_coords: int = 0, with_bitmap: bool = False,
                  value_nbytes: int = BYTES_PER_VALUE) -> float:
    b = n_values * value_nbytes
    if with_bitmap:
        b += bitmap_nbytes(n_coords) + HEADER_NBYTES
    return b


def message_bytes(nnz: int, n_coords: int = 0, with_bitmap: bool = False,
                  value_nbytes: int = BYTES_PER_VALUE) -> float:
    """On-wire size of one model message whose sender mask holds ``nnz``
    values.  ``with_bitmap=True`` is the exact codec frame size
    (``repro.sparse.codec.encoded_nbytes``); the simulator stamps every
    transfer with it so measured totals and analytic reports agree."""
    return payload_bytes(nnz, n_coords, with_bitmap, value_nbytes)


def edge_message_bytes(
    adjacency: np.ndarray,
    nnz_per_client: list[int],
    n_coords: int = 0,
    with_bitmap: bool = False,
) -> np.ndarray:
    """Per-edge message sizes: ``E[i, j]`` = bytes of j's model on the j->i
    edge (0 off-edge and on the diagonal).  ``decentralized_comm`` and the
    event simulator both derive their byte counts from this matrix, which is
    what makes "simulated bytes-on-wire == accounting totals" testable."""
    a = adjacency.astype(float).copy()
    np.fill_diagonal(a, 0.0)
    per_sender = np.asarray(
        [message_bytes(v, n_coords, with_bitmap) for v in nnz_per_client])
    return (a > 0) * per_sender[None, :]


def measured_comm(adjacency: np.ndarray, value_nbytes_per_client: list[float],
                  wire_nbytes_per_client: list[int]) -> CommReport:
    """Measured mode: a ``CommReport`` from *real encoded* message sizes.

    ``wire_nbytes_per_client[j]`` is ``codec.encoded_nbytes`` of j's actual
    packed payload (bitmap + header included); ``value_nbytes_per_client``
    carries the paper's headline value-bytes.  Busiest-node convention is
    identical to ``decentralized_comm`` — for fp32 payloads the two reports
    are equal bit for bit, and they diverge exactly when the payload does
    (fp16 values, annealed densities, partial payloads)."""
    a = (np.asarray(adjacency, dtype=float) > 0).astype(float)
    np.fill_diagonal(a, 0.0)
    e = a * np.asarray(value_nbytes_per_client, dtype=float)[None, :]
    e_w = a * np.asarray(wire_nbytes_per_client, dtype=float)[None, :]
    per_node = np.maximum(e.sum(axis=0), e.sum(axis=1))
    per_node_w = np.maximum(e_w.sum(axis=0), e_w.sum(axis=1))
    mb = 1.0 / 1e6
    return CommReport(
        busiest_mb=float(per_node.max()) * mb,
        avg_per_node_mb=float(per_node.mean()) * mb,
        total_mb=float(e.sum()) * mb,
        busiest_mb_with_bitmap=float(per_node_w.max()) * mb,
    )


def decentralized_comm(
    adjacency: np.ndarray,
    nnz_per_client: list[int],
    n_coords: int,
) -> CommReport:
    """Per-round communication for a decentralized topology.

    adjacency[k, j] = 1 iff k receives j's model; sender j uploads its own
    nnz_j values once per receiving edge.
    """
    e = edge_message_bytes(adjacency, nnz_per_client)
    e_bm = edge_message_bytes(adjacency, nnz_per_client, n_coords, True)
    up = e.sum(axis=0)
    down = e.sum(axis=1)
    up_bm = e_bm.sum(axis=0)
    down_bm = e_bm.sum(axis=1)
    per_node = np.maximum(up, down)  # busiest direction, matching the paper
    per_node_bm = np.maximum(up_bm, down_bm)
    total = up.sum()
    mb = 1.0 / 1e6  # decimal MB, matching the paper's tables
    return CommReport(
        busiest_mb=float(per_node.max()) * mb,
        avg_per_node_mb=float(per_node.mean()) * mb,
        total_mb=float(total) * mb,
        busiest_mb_with_bitmap=float(per_node_bm.max()) * mb,
    )


def centralized_comm(
    n_connected: int, nnz_per_client: list[int], n_coords: int
) -> CommReport:
    """Server-centric: the server is the busiest node; it downloads and
    uploads ``n_connected`` models per round (a single direction is quoted,
    per the paper's table)."""
    sel = nnz_per_client[:n_connected]
    b = sum(payload_bytes(v) for v in sel)
    b_bm = sum(payload_bytes(v, n_coords, True) for v in sel)
    mb = 1.0 / 1e6
    return CommReport(
        busiest_mb=b * mb,
        avg_per_node_mb=b * mb / max(n_connected, 1),
        total_mb=2 * b * mb,
        busiest_mb_with_bitmap=b_bm * mb,
    )


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------


@dataclass
class FlopsReport:
    per_round_flops: float          # per client, per communication round
    dense_per_round_flops: float
    fwd_flops_per_sample: float

    def row(self) -> dict:
        return {
            "FLOPS_1e12": round(self.per_round_flops / 1e12, 2),
            "dense_FLOPS_1e12": round(self.dense_per_round_flops / 1e12, 2),
        }


def sparse_training_flops(
    layer_fwd_flops: dict[str, float],
    layer_densities: dict[str, float],
    n_samples: int,
    local_epochs: int,
    mask_search_batches: int = 1,
    batch_size: int = 128,
    bwd_multiplier: float = 2.0,
) -> FlopsReport:
    """Per-round training FLOPs with layer-wise sparse scaling.

    fwd+bwd = (1 + bwd_multiplier) * fwd.  The mask search adds
    ``mask_search_batches`` dense forward+backward batches per round.
    """
    dense_fwd = sum(layer_fwd_flops.values())
    sparse_fwd = sum(
        f * layer_densities.get(k, 1.0) for k, f in layer_fwd_flops.items()
    )
    steps_samples = n_samples * local_epochs
    train = steps_samples * sparse_fwd * (1.0 + bwd_multiplier)
    mask_search = mask_search_batches * batch_size * dense_fwd * (1.0 + bwd_multiplier)
    dense_train = steps_samples * dense_fwd * (1.0 + bwd_multiplier)
    return FlopsReport(
        per_round_flops=train + mask_search,
        dense_per_round_flops=dense_train,
        fwd_flops_per_sample=dense_fwd,
    )
