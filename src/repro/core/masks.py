"""Personalized sparse masks — ERK initialization and capacity handling.

Implements the mask machinery of DisPFL (Dai et al., ICML 2022, §3.2):

* Erdos-Renyi-Kernel (ERK) layer-density allocation (Evci et al., 2020):
  layers with more parameters get *higher sparsity* (lower density); the raw
  per-layer score is (sum of dims)/(product of dims) and a global scale eps
  is solved so the overall density hits the client's capacity ``c_k``.
* Only leaves with ndim >= 2 are sparsified (weights); biases / norm scales
  stay dense — they are a negligible fraction of parameters and pruning them
  destabilizes training (standard DST practice, matches the paper's code).
* Each client k draws an i.i.d. Bernoulli(density_l) mask per layer from its
  own PRNG stream, yielding the personalized initial masks m_{k,0}.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_leaves_with_path, tree_map_with_path, split_like

PyTree = Any

# ---------------------------------------------------------------------------
# Which leaves are sparsifiable
# ---------------------------------------------------------------------------


def default_sparsifiable(path: str, leaf) -> bool:
    """Weights (ndim>=2) are sparsifiable; biases/norm scales are not.

    Embedding tables are sparsifiable too — the paper masks all conv/fc
    weights; we extend the same rule to matmul-shaped tensors.
    """
    del path
    return hasattr(leaf, "ndim") and leaf.ndim >= 2


# ---------------------------------------------------------------------------
# ERK density allocation
# ---------------------------------------------------------------------------


def erk_layer_densities(
    shapes: dict[str, tuple[int, ...]],
    density: float,
    erk_power_scale: float = 1.0,
) -> dict[str, float]:
    """Solve per-layer ERK densities so that total nnz ~= density * total.

    Mirrors RigL's ERK: raw_l = (sum(shape)/prod(shape))**power; density_l =
    min(1, eps*raw_l); eps solved by iteratively freezing saturated layers.
    """
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0,1], got {density}")
    numel = {k: int(np.prod(s)) for k, s in shapes.items()}
    total = sum(numel.values())
    target_nnz = density * total
    raw = {
        k: (float(np.sum(s)) / float(np.prod(s))) ** erk_power_scale
        for k, s in shapes.items()
    }
    dense_layers: set[str] = set()
    while True:
        # nnz contributed by saturated (fully dense) layers
        dense_nnz = sum(numel[k] for k in dense_layers)
        free = {k: v for k, v in raw.items() if k not in dense_layers}
        denom = sum(raw[k] * numel[k] for k in free)
        if denom <= 0:
            eps = 0.0
        else:
            eps = (target_nnz - dense_nnz) / denom
        newly_dense = [k for k in free if raw[k] * eps > 1.0]
        if not newly_dense:
            break
        dense_layers.update(newly_dense)
    out = {}
    for k in shapes:
        if k in dense_layers:
            out[k] = 1.0
        else:
            out[k] = float(np.clip(raw[k] * eps, 0.0, 1.0))
    return out


def annealed_density(d0: float, d_final: float, t: int, t_end: int) -> float:
    """Cosine sparse-to-sparser density schedule (DA-DPFL, Long et al. 2024).

    Decays from ``d0`` at t=0 to ``d_final`` at ``t_end``; the annealed
    value re-enters ``erk_layer_densities`` so every round's mask budget is
    a proper ERK allocation at the scheduled global density.
    """
    import math

    if not 0.0 < d_final <= d0:
        raise ValueError(
            f"need 0 < d_final <= d0, got d_final={d_final}, d0={d0}")
    frac = 0.5 * (1.0 + math.cos(min(t, t_end) * math.pi / max(t_end, 1)))
    return d_final + (d0 - d_final) * frac


def erk_densities_for_params(
    params: PyTree,
    density: float,
    sparsifiable: Callable[[str, Any], bool] = default_sparsifiable,
) -> dict[str, float]:
    """ERK densities for the sparsifiable leaves of a parameter pytree."""
    shapes = {
        p: tuple(x.shape)
        for p, x in tree_leaves_with_path(params)
        if sparsifiable(p, x)
    }
    if not shapes:
        return {}
    return erk_layer_densities(shapes, density)


# ---------------------------------------------------------------------------
# Mask initialization
# ---------------------------------------------------------------------------


def init_mask(
    key: jax.Array,
    params: PyTree,
    density: float,
    sparsifiable: Callable[[str, Any], bool] = default_sparsifiable,
    dtype=jnp.float32,
) -> PyTree:
    """Random ERK mask for one client: Bernoulli(density_l) per layer.

    Non-sparsifiable leaves get an all-ones mask so downstream code can treat
    the mask pytree uniformly (w ⊙ m is a no-op there).
    """
    densities = erk_densities_for_params(params, density, sparsifiable)
    keys = split_like(key, params)

    def one(path, x, k):
        if path in densities:
            d = densities[path]
            m = jax.random.bernoulli(k, p=d, shape=x.shape)
            return m.astype(dtype)
        return jnp.ones(x.shape, dtype=dtype)

    return tree_map_with_path(one, params, keys)


def init_client_masks(
    key: jax.Array,
    params: PyTree,
    capacities: list[float],
    sparsifiable: Callable[[str, Any], bool] = default_sparsifiable,
    dtype=jnp.float32,
) -> list[PyTree]:
    """Personalized masks m_{k,0}, one per client, density = capacity c_k."""
    keys = jax.random.split(key, len(capacities))
    return [
        init_mask(k, params, c, sparsifiable, dtype)
        for k, c in zip(keys, capacities)
    ]


def mask_density(mask: PyTree, params: PyTree | None = None,
                 sparsifiable: Callable[[str, Any], bool] = default_sparsifiable) -> float:
    """Achieved density over sparsifiable leaves."""
    ref = params if params is not None else mask
    flags = {p: sparsifiable(p, x) for p, x in tree_leaves_with_path(ref)}
    nnz = 0
    tot = 0
    for p, m in tree_leaves_with_path(mask):
        if flags.get(p, True):
            nnz += int(jnp.sum(m != 0))
            tot += int(np.prod(m.shape))
    return nnz / max(tot, 1)


def apply_mask(params: PyTree, mask: PyTree) -> PyTree:
    """w ⊙ m (Hadamard product over the pytree)."""
    return jax.tree.map(lambda w, m: w * m.astype(w.dtype), params, mask)
