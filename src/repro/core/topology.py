"""Communication topologies for the decentralized protocol (paper Fig. 2).

Adjacency matrices are (K, K) float arrays with A[k, j] = 1 iff client k
*receives* client j's model this round.  The diagonal is always 1 (a client
always keeps itself).  The paper's main setting is the *time-varying random*
topology where each client samples `degree` random neighbors per round and
the busiest node's fan-in is bounded by the centralized server's fan-in.
"""
from __future__ import annotations

import numpy as np


def ring(n_clients: int) -> np.ndarray:
    """Static ring: each client hears its two ring neighbors (Fig. 2b)."""
    a = np.eye(n_clients)
    for k in range(n_clients):
        a[k, (k - 1) % n_clients] = 1.0
        a[k, (k + 1) % n_clients] = 1.0
    return a


def fully_connected(n_clients: int) -> np.ndarray:
    """All-to-all (Fig. 2c)."""
    return np.ones((n_clients, n_clients))


def time_varying_random(
    n_clients: int,
    degree: int,
    round_idx: int,
    seed: int = 0,
    drop_prob: float = 0.0,
) -> np.ndarray:
    """Time-varying topology (Fig. 2d): a random ``degree``-regular directed
    graph per round, built from ``degree`` random cyclic permutations so that
    *both* in-degree and out-degree are bounded by ``degree`` — the paper's
    busiest-node constraint ("at most 10 neighbors") caps upload and download
    alike.  ``drop_prob`` models the client-dropping experiment (App. B.6):
    a dropped client neither sends nor receives this round.
    """
    if degree >= n_clients:
        return fully_connected(n_clients)
    rng = np.random.default_rng(np.random.SeedSequence([seed, round_idx]))
    a = np.eye(n_clients)
    for _ in range(degree):
        perm = rng.permutation(n_clients)
        # rotate the permutation cycle so no client maps to itself
        targets = perm[(np.argsort(perm) + 1) % n_clients]
        a[np.arange(n_clients), targets] = 1.0
    if drop_prob > 0.0:
        alive = rng.random(n_clients) >= drop_prob
        for k in range(n_clients):
            if not alive[k]:
                a[k, :] = 0.0
                a[:, k] = 0.0
                a[k, k] = 1.0
    return a


def busiest_node_degree(a: np.ndarray) -> int:
    """Max #models any single node must *upload* (out-degree excl. self).

    The paper's busiest-node communication metric counts the heaviest
    uploader/downloader; with symmetric random sampling the upload side
    (column sums) is the binding one.
    """
    out_deg = a.sum(axis=0) - np.diag(a)
    in_deg = a.sum(axis=1) - np.diag(a)
    return int(max(out_deg.max(), in_deg.max()))


def mixing_matrix(a: np.ndarray) -> np.ndarray:
    """Row-normalized adjacency (plain gossip average, used by D-PSGD)."""
    return a / a.sum(axis=1, keepdims=True)


def make_adjacency(
    kind: str,
    n_clients: int,
    round_idx: int = 0,
    degree: int = 10,
    seed: int = 0,
    drop_prob: float = 0.0,
) -> np.ndarray:
    if kind == "ring":
        return ring(n_clients)
    if kind in ("fc", "fully_connected"):
        return fully_connected(n_clients)
    if kind in ("random", "time_varying", "dynamic"):
        return time_varying_random(n_clients, degree, round_idx, seed, drop_prob)
    raise ValueError(f"unknown topology kind: {kind}")
