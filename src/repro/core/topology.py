"""Communication topologies for the decentralized protocol (paper Fig. 2).

Adjacency matrices are (K, K) float arrays with A[k, j] = 1 iff client k
*receives* client j's model this round.  The diagonal is always 1 (a client
always keeps itself).  The paper's main setting is the *time-varying random*
topology where each client samples `degree` random neighbors per round and
the busiest node's fan-in is bounded by the centralized server's fan-in.
"""
from __future__ import annotations

import numpy as np

# SeedSequence sub-stream tags, disjoint from the engine's rng streams so a
# draw here never perturbs training randomness.
AVAIL_STREAM = 104729   # per-round client up/down draws (shared failure model)
GOSSIP_STREAM = 7919    # per-(round, client) directed neighbor sampling


def bernoulli_alive(
    n_clients: int, round_idx: int, drop_prob: float, seed: int = 0
) -> np.ndarray:
    """Per-round i.i.d. Bernoulli up/down draws — THE client-failure model.

    Both the round engine (via ``drop_prob``) and ``repro.sim.availability``
    derive their alive sets from this one function, so the fig-6 dropping
    experiment and the event simulator see identical failures for identical
    (seed, round) pairs."""
    if drop_prob <= 0.0:
        return np.ones(n_clients, dtype=bool)
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, round_idx, AVAIL_STREAM]))
    return rng.random(n_clients) >= drop_prob


def apply_availability(a: np.ndarray, alive: np.ndarray) -> np.ndarray:
    """Zero a dropped client's row and column (it neither sends nor
    receives); every client always keeps itself (diagonal stays 1)."""
    m = np.asarray(alive, dtype=float)
    out = a * m[:, None] * m[None, :]
    np.fill_diagonal(out, 1.0)
    return out


def ring(n_clients: int) -> np.ndarray:
    """Static ring: each client hears its two ring neighbors (Fig. 2b)."""
    a = np.eye(n_clients)
    for k in range(n_clients):
        a[k, (k - 1) % n_clients] = 1.0
        a[k, (k + 1) % n_clients] = 1.0
    return a


def fully_connected(n_clients: int) -> np.ndarray:
    """All-to-all (Fig. 2c)."""
    return np.ones((n_clients, n_clients))


def time_varying_random(
    n_clients: int,
    degree: int,
    round_idx: int,
    seed: int = 0,
    drop_prob: float = 0.0,
) -> np.ndarray:
    """Time-varying topology (Fig. 2d): a random ``degree``-regular directed
    graph per round, built from ``degree`` random cyclic permutations so that
    *both* in-degree and out-degree are bounded by ``degree`` — the paper's
    busiest-node constraint ("at most 10 neighbors") caps upload and download
    alike.  ``drop_prob`` models the client-dropping experiment (App. B.6):
    a dropped client neither sends nor receives this round.
    """
    if degree >= n_clients:
        a = fully_connected(n_clients)
    else:
        rng = np.random.default_rng(np.random.SeedSequence([seed, round_idx]))
        a = np.eye(n_clients)
        for _ in range(degree):
            perm = rng.permutation(n_clients)
            # rotate the permutation cycle so no client maps to itself
            targets = perm[(np.argsort(perm) + 1) % n_clients]
            a[np.arange(n_clients), targets] = 1.0
    if drop_prob > 0.0:
        a = apply_availability(
            a, bernoulli_alive(n_clients, round_idx, drop_prob, seed))
    return a


def directed_out_neighbors(
    n_clients: int,
    k: int,
    round_idx: int,
    degree: int,
    seed: int = 0,
) -> np.ndarray:
    """Receivers of client k's push-gossip message at its local round
    ``round_idx`` — the asynchronous counterpart of the time-varying
    topology.  Sampled without replacement from a per-(seed, round, client)
    derived generator, so the draw is independent of event ordering and one
    client's schedule never perturbs another's."""
    if degree >= n_clients - 1:
        return np.array([j for j in range(n_clients) if j != k])
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, round_idx, k, GOSSIP_STREAM]))
    others = np.array([j for j in range(n_clients) if j != k])
    return np.sort(rng.choice(others, size=degree, replace=False))


def busiest_node_degree(a: np.ndarray) -> int:
    """Max #models any single node must *upload* (out-degree excl. self).

    The paper's busiest-node communication metric counts the heaviest
    uploader/downloader; with symmetric random sampling the upload side
    (column sums) is the binding one.
    """
    out_deg = a.sum(axis=0) - np.diag(a)
    in_deg = a.sum(axis=1) - np.diag(a)
    return int(max(out_deg.max(), in_deg.max()))


def mixing_matrix(a: np.ndarray) -> np.ndarray:
    """Row-normalized adjacency (plain gossip average, used by D-PSGD)."""
    return a / a.sum(axis=1, keepdims=True)


def make_adjacency(
    kind: str,
    n_clients: int,
    round_idx: int = 0,
    degree: int = 10,
    seed: int = 0,
    drop_prob: float = 0.0,
    alive: np.ndarray | None = None,
) -> np.ndarray:
    """Build the round's adjacency, then apply the client-failure model.

    ``alive`` (a boolean vector, e.g. from ``repro.sim.availability``)
    overrides the built-in ``drop_prob`` Bernoulli draws; with neither, the
    topology is failure-free.  Dropping now applies uniformly to every
    ``kind`` (the seed code silently ignored ``drop_prob`` for ring/fc).
    """
    if kind == "ring":
        a = ring(n_clients)
    elif kind in ("fc", "fully_connected"):
        a = fully_connected(n_clients)
    elif kind in ("random", "time_varying", "dynamic"):
        a = time_varying_random(n_clients, degree, round_idx, seed)
    else:
        raise ValueError(f"unknown topology kind: {kind}")
    if alive is None and drop_prob > 0.0:
        alive = bernoulli_alive(n_clients, round_idx, drop_prob, seed)
    if alive is not None:
        a = apply_availability(a, alive)
    return a
