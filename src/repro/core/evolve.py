"""Local mask search (paper Alg. 2, Fig. 1c) — RigL-style prune & regrow.

Once per communication round, each client:
  1. computes the *dense* gradient g(w_{k,t+1}) on one local batch
     (backward without the mask — this is the only dense computation),
  2. per layer, prunes the alpha_t-fraction of *active* weights with the
     smallest magnitude,
  3. regrows the same count among *inactive* coordinates, picking those with
     the largest dense-gradient magnitude.

alpha_t follows cosine annealing (Liu et al., 2021b):
    alpha_t = alpha_0 / 2 * (1 + cos(t * pi / T_end)).

Regrown coordinates re-enter at weight 0; the *next* intersection gossip
warm-starts them from peers that hold them (paper §3.2 point (iii)).

The layer counts (n_active) are static given the ERK densities, so the layer
update is shape-static and can be jitted; the simulator calls it eagerly.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.masks import default_sparsifiable
from repro.utils.tree import tree_map_with_path

PyTree = Any


def cosine_prune_rate(alpha0: float, round_idx: int, total_rounds: int) -> float:
    """alpha_t = alpha_0/2 * (1 + cos(t*pi/T))."""
    t = min(round_idx, total_rounds)
    return alpha0 / 2.0 * (1.0 + math.cos(t * math.pi / max(total_rounds, 1)))


def _exact_topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """{0,1} mask (flattened shape) selecting the k largest scores, exact
    count even under ties (argsort-based)."""
    flat = scores.reshape(-1)
    if k <= 0:
        return jnp.zeros_like(flat)
    order = jnp.argsort(-flat)
    sel = jnp.zeros_like(flat).at[order[:k]].set(1.0)
    return sel


def evolve_mask_layer(
    w: jax.Array,
    m: jax.Array,
    g: jax.Array,
    prune_rate: float,
    n_active: int,
) -> tuple[jax.Array, jax.Array]:
    """One layer of Alg. 2.  Returns (new_mask, new_weights).

    n_active is the (static) nnz budget of this layer's mask; it is preserved
    exactly: prune n_prune, regrow n_prune.
    """
    n_prune = int(math.ceil(prune_rate * n_active))
    n_keep = n_active - n_prune
    shape = w.shape
    mf = m.reshape(-1).astype(jnp.float32)
    wf = w.reshape(-1).astype(jnp.float32)
    gf = g.reshape(-1).astype(jnp.float32)

    neg_inf = jnp.float32(-jnp.inf)
    # -- magnitude pruning among active coords
    keep_scores = jnp.where(mf > 0, jnp.abs(wf), neg_inf)
    m_half = _exact_topk_mask(keep_scores, n_keep)
    # -- gradient regrow among inactive coords (of the pruned mask)
    grow_scores = jnp.where(m_half > 0, neg_inf, jnp.abs(gf))
    grown = _exact_topk_mask(grow_scores, n_prune)
    new_m = (m_half + grown).reshape(shape)
    # pruned coords are zeroed; regrown coords start at 0 (w was masked)
    new_w = w * new_m.astype(w.dtype)
    return new_m.astype(m.dtype), new_w


def evolve_masks(
    params: PyTree,
    mask: PyTree,
    dense_grads: PyTree,
    prune_rate: float,
    layer_nnz: dict[str, int],
    sparsifiable: Callable[[str, Any], bool] = default_sparsifiable,
) -> tuple[PyTree, PyTree]:
    """Apply Alg. 2 across the pytree.  ``layer_nnz`` maps sparsifiable leaf
    paths to their static active-count budgets (from the ERK allocation).
    Non-sparsifiable leaves pass through unchanged.
    """
    new_mask = {}
    new_params = {}

    def one(path, w, m, g):
        if path in layer_nnz and sparsifiable(path, w):
            nm, nw = evolve_mask_layer(w, m, g, prune_rate, layer_nnz[path])
            return nm, nw
        return m, w

    paired = tree_map_with_path(one, params, mask, dense_grads)
    # unzip the (mask, weight) tuples
    new_mask = jax.tree.map(lambda t: t[0], paired, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda t: t[1], paired, is_leaf=lambda x: isinstance(x, tuple))
    return new_mask, new_params


def layer_nnz_budgets(params: PyTree, densities: dict[str, float]) -> dict[str, int]:
    """Static per-layer active counts implied by ERK densities."""
    import numpy as np
    from repro.utils.tree import tree_leaves_with_path

    out = {}
    for p, x in tree_leaves_with_path(params):
        if p in densities:
            out[p] = int(round(densities[p] * int(np.prod(x.shape))))
    return out
