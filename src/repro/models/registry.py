"""Uniform model API over all families.

``bind(cfg)`` returns a ``ModelAPI`` whose methods take/return plain pytrees:

  init(key, dtype)                      -> params
  train_loss(params, batch)             -> (loss, aux_metrics)
  prefill(params, batch, cache)         -> (logits, cache)
  decode(params, tokens, pos, cache)    -> (logits, cache)
  init_cache(batch_size, max_len, dtype)-> cache
  input_specs(shape, dtype, batch)      -> batch pytree of ShapeDtypeStructs

Batch layout (per client, no client axis here — the launcher stacks):
  train  : {'tokens': (B,S_t) i32, 'labels': (B,S) i32, ['prefix'|'frames']}
  prefill: {'tokens': (B,S_t) i32, ['prefix'|'frames']}
  decode : tokens (B,1) i32 + pos scalar i32
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.common import softmax_xent

PyTree = Any


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    input_specs: Callable


def _enc_dec_split(seq_len: int) -> tuple[int, int]:
    """Audio enc-dec: half the token budget to frames, half to text."""
    return seq_len // 2, seq_len - seq_len // 2


def bind(cfg: ModelConfig, moe_dense: bool = False, remat: bool = True,
         unroll: bool = False, remat_policy: str = "full") -> ModelAPI:
    if cfg.enc_layers > 0:
        return _bind_encdec(cfg, remat, unroll)
    return _bind_lm(cfg, moe_dense, remat, unroll, remat_policy)


# ---------------------------------------------------------------------------
# Decoder-only families (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------


def _bind_lm(cfg: ModelConfig, moe_dense: bool, remat: bool,
             unroll: bool = False, remat_policy: str = "full") -> ModelAPI:
    def init(key, dtype=jnp.float32):
        return lm_mod.init_lm(key, cfg, dtype)

    def train_loss(params, batch):
        prefix = batch.get("prefix")
        logits, aux = lm_mod.forward_train(params, batch["tokens"], cfg,
                                           prefix=prefix, remat=remat,
                                           unroll=unroll,
                                           remat_policy=remat_policy,
                                           moe_dense=moe_dense)
        loss = softmax_xent(logits, batch["labels"])
        return loss + aux, {"xent": loss, "aux": aux}

    def prefill(params, batch, cache):
        return lm_mod.forward_prefill(params, batch["tokens"], cfg, cache,
                                      prefix=batch.get("prefix"),
                                      unroll=unroll, moe_dense=moe_dense)

    def decode(params, tokens, pos, cache):
        return lm_mod.forward_decode(params, tokens, pos, cfg, cache,
                                     unroll=unroll, moe_dense=moe_dense)

    def init_cache(batch_size, max_len, dtype=jnp.float32):
        return lm_mod.init_cache(cfg, batch_size, max_len, dtype)

    def input_specs(shape: InputShape, dtype=jnp.float32, batch: Optional[int] = None):
        b = batch if batch is not None else shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32
        if shape.mode == "train":
            spec = {
                "tokens": jax.ShapeDtypeStruct((b, s - cfg.prefix_len), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if cfg.prefix_len:
                spec["prefix"] = jax.ShapeDtypeStruct(
                    (b, cfg.prefix_len, cfg.d_model), dtype)
            return spec
        if shape.mode == "prefill":
            spec = {"tokens": jax.ShapeDtypeStruct((b, s - cfg.prefix_len), i32)}
            if cfg.prefix_len:
                spec["prefix"] = jax.ShapeDtypeStruct(
                    (b, cfg.prefix_len, cfg.d_model), dtype)
            return spec
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    return ModelAPI(cfg, init, train_loss, prefill, decode, init_cache, input_specs)


# ---------------------------------------------------------------------------
# Encoder-decoder (audio)
# ---------------------------------------------------------------------------


def _bind_encdec(cfg: ModelConfig, remat: bool, unroll: bool = False) -> ModelAPI:
    def init(key, dtype=jnp.float32):
        return encdec_mod.init_encdec(key, cfg, dtype)

    def train_loss(params, batch):
        logits, aux = encdec_mod.decode_train(
            params, batch["frames"], batch["tokens"], cfg, remat=remat,
            unroll=unroll)
        loss = softmax_xent(logits, batch["labels"])
        return loss + aux, {"xent": loss, "aux": aux}

    def prefill(params, batch, cache):
        return encdec_mod.prefill(params, batch["frames"], batch["tokens"],
                                  cfg, cache, unroll=unroll)

    def decode(params, tokens, pos, cache):
        return encdec_mod.decode_step(params, tokens, pos, cfg, cache,
                                      unroll=unroll)

    def init_cache(batch_size, max_len, dtype=jnp.float32, enc_len: int = 1024):
        return encdec_mod.init_encdec_cache(cfg, batch_size, max_len, enc_len, dtype)

    def input_specs(shape: InputShape, dtype=jnp.float32, batch: Optional[int] = None):
        b = batch if batch is not None else shape.global_batch
        i32 = jnp.int32
        if shape.mode in ("train", "prefill"):
            enc_len, dec_len = _enc_dec_split(shape.seq_len)
            spec = {
                "frames": jax.ShapeDtypeStruct((b, enc_len, cfg.d_model), dtype),
                "tokens": jax.ShapeDtypeStruct((b, dec_len), i32),
            }
            if shape.mode == "train":
                spec["labels"] = jax.ShapeDtypeStruct((b, dec_len), i32)
            return spec
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    return ModelAPI(cfg, init, train_loss, prefill, decode, init_cache, input_specs)
