"""Mixture-of-Experts FFN: token-choice top-k routing with capacity dispatch.

Two execution paths over the same parameters:

* ``moe_dense_ref`` — every expert sees every token, weighted by gates.
  O(E) compute; exact; used as the test oracle and for tiny smoke configs.
* ``moe_apply`` — sorted capacity dispatch (MaxText/MegaBlocks style):
  tokens are argsorted by expert id, packed into (E, C) buffers (static
  capacity C, overflow dropped), expert FFNs run batched, results scattered
  back with gates.  Under the mesh the (E, C, d) buffers are sharded over
  'expert'->'model', so GSPMD emits the all-to-all style dispatch
  collectives.

Covers deepseek-moe (2 shared + 64 routed, top-6), qwen3-moe (128e top-8)
and jamba (16e top-2).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import activation, lecun_init
from repro.sharding import constrain


def moe_init(key, d_model: int, spec, dtype):
    ks = jax.random.split(key, 8)
    e, de = spec.n_experts, spec.d_expert
    p = {
        "router": lecun_init(ks[0], (d_model, e), jnp.float32),
        "w_gate": lecun_init(ks[1], (e, d_model, de), dtype),
        "w_up": lecun_init(ks[2], (e, d_model, de), dtype),
        "w_down": lecun_init(ks[3], (e, de, d_model), dtype, fan_in=de),
    }
    if spec.n_shared > 0:
        ds = spec.d_expert * spec.n_shared
        p["shared"] = {
            "w_gate": lecun_init(ks[4], (d_model, ds), dtype),
            "w_up": lecun_init(ks[5], (d_model, ds), dtype),
            "w_down": lecun_init(ks[6], (ds, d_model), dtype, fan_in=ds),
        }
    return p


def _expert_ffn(p, xb, act):
    """xb: (E, C, d) -> (E, C, d), batched gated FFN over experts."""
    h = act(jnp.einsum("ecd,edf->ecf", xb, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xb, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _shared_ffn(p, x, act):
    h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def _route(params, xf, spec):
    """xf: (N, d) -> gates (N, k), expert ids (N, k), probs (N, E) [f32]."""
    logits = (xf.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, spec.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eids, probs


def aux_load_balance_loss(probs: jax.Array, eids: jax.Array, n_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    n, k = eids.shape
    f = jnp.zeros((n_experts,), jnp.float32).at[eids.reshape(-1)].add(1.0)
    f = f / (n * k)
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def capacity_for(n_tokens: int, spec) -> int:
    c = int(math.ceil(n_tokens * spec.top_k / spec.n_experts * spec.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(params, x: jax.Array, spec, act_name: str = "silu"):
    """Sorted capacity dispatch.  x: (B, S, d) -> (y, aux_loss)."""
    act = activation(act_name)
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    gates, eids, probs = _route(params, xf, spec)
    k = spec.top_k
    cap = capacity_for(n, spec)
    e = spec.n_experts

    ee = eids.reshape(n * k)
    tt = jnp.repeat(jnp.arange(n), k)
    gg = gates.reshape(n * k).astype(x.dtype)

    order = jnp.argsort(ee)  # stable
    ee_s, tt_s, gg_s = ee[order], tt[order], gg[order]
    counts = jnp.zeros((e,), jnp.int32).at[ee_s].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(n * k) - offsets[ee_s]
    keep = pos_in_e < cap
    slot = jnp.where(keep, ee_s * cap + jnp.minimum(pos_in_e, cap - 1), e * cap)

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[tt_s] * keep[:, None].astype(x.dtype))
    xb = buf[: e * cap].reshape(e, cap, d)
    xb = constrain(xb, ("expert", "expert_cap", "embed"))
    yb = _expert_ffn(params, xb, act)
    yb = constrain(yb, ("expert", "expert_cap", "embed"))
    yb = jnp.concatenate([yb.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], 0)

    contrib = yb[slot] * (gg_s * keep.astype(x.dtype))[:, None]
    y = jnp.zeros((n, d), x.dtype).at[tt_s].add(contrib)

    if spec.n_shared > 0:
        y = y + _shared_ffn(params["shared"], xf, act)
    aux = aux_load_balance_loss(probs, eids, e) * spec.router_aux_coef
    return y.reshape(b, s, d), aux


def moe_dense_ref(params, x: jax.Array, spec, act_name: str = "silu"):
    """Oracle: every expert computes every token; exact top-k combine."""
    act = activation(act_name)
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    gates, eids, probs = _route(params, xf, spec)
    # (E, N, d) all-experts compute
    h = act(jnp.einsum("nd,edf->enf", xf, params["w_gate"]))
    h = h * jnp.einsum("nd,edf->enf", xf, params["w_up"])
    ye = jnp.einsum("enf,efd->end", h, params["w_down"])
    onehot = jax.nn.one_hot(eids, spec.n_experts, dtype=x.dtype)  # (N,k,E)
    w = (onehot * gates[..., None].astype(x.dtype)).sum(1)  # (N,E)
    y = jnp.einsum("ne,end->nd", w, ye)
    if spec.n_shared > 0:
        y = y + _shared_ffn(params["shared"], xf, act)
    aux = aux_load_balance_loss(probs, eids, spec.n_experts) * spec.router_aux_coef
    return y.reshape(b, s, d), aux
