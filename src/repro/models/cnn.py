"""Paper-faithful CIFAR backbones: ResNet18 and VGG11 with GroupNorm.

The paper (App. B.2) uses torchvision-style ResNet18/VGG11 with every
BatchNorm replaced by GroupNorm (Hsieh et al. 2020 motivate dropping BN in
FL).  The CIFAR ResNet18 variant uses a 3x3 stem without max-pool.

Besides init/apply, ``*_fwd_flops`` return per-weight-leaf forward FLOPs
(multiply-add = 2 FLOPs) keyed by the *same paths* as the parameter pytree,
so the ERK layer densities can be applied layer-wise — this is what lets the
benchmark reproduce the paper's Table 1 FLOPS column (8.3e12 dense,
~7.0e12 at density 0.5) analytically.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import groupnorm, groupnorm_init, lecun_init

PyTree = Any


# ---------------------------------------------------------------------------
# conv helpers (NHWC, HWIO)
# ---------------------------------------------------------------------------


def conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    return {"w": lecun_init(key, (kh, kw, cin, cout), dtype, fan_in=fan_in)}


def conv(params, x, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, params["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv_flops(kh, kw, cin, cout, out_h, out_w):
    return 2.0 * kh * kw * cin * cout * out_h * out_w


# ---------------------------------------------------------------------------
# ResNet18-GN (CIFAR variant)
# ---------------------------------------------------------------------------

RESNET18_STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]  # (width, first stride)


def _basic_block_init(key, cin, cout, stride, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": conv_init(ks[0], 3, 3, cin, cout, dtype),
        "gn1": groupnorm_init(cout, dtype),
        "conv2": conv_init(ks[1], 3, 3, cout, cout, dtype),
        "gn2": groupnorm_init(cout, dtype),
    }
    if stride != 1 or cin != cout:
        p["down"] = conv_init(ks[2], 1, 1, cin, cout, dtype)
        p["gn_down"] = groupnorm_init(cout, dtype)
    return p


def _basic_block(p, x, stride):
    y = conv(p["conv1"], x, stride)
    y = jax.nn.relu(groupnorm(p["gn1"], y))
    y = conv(p["conv2"], y, 1)
    y = groupnorm(p["gn2"], y)
    if "down" in p:
        x = groupnorm(p["gn_down"], conv(p["down"], x, stride))
    return jax.nn.relu(x + y)


def init_resnet18(key, num_classes: int, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 10)
    p: dict = {"stem": conv_init(ks[0], 3, 3, 3, 64, dtype),
               "gn_stem": groupnorm_init(64, dtype)}
    cin = 64
    ki = 1
    for si, (w, stride) in enumerate(RESNET18_STAGES):
        for bi in range(2):
            s = stride if bi == 0 else 1
            p[f"s{si}b{bi}"] = _basic_block_init(ks[ki], cin, w, s, dtype)
            cin = w
            ki += 1
    p["fc"] = {"w": lecun_init(ks[9], (512, num_classes), dtype, fan_in=512),
               "b": jnp.zeros((num_classes,), dtype)}
    return p


def resnet18_apply(params, images: jax.Array) -> jax.Array:
    """images: (B, 32, 32, 3) -> logits (B, classes)."""
    x = jax.nn.relu(groupnorm(params["gn_stem"], conv(params["stem"], images, 1)))
    for si, (w, stride) in enumerate(RESNET18_STAGES):
        for bi in range(2):
            s = stride if bi == 0 else 1
            x = _basic_block(params[f"s{si}b{bi}"], x, s)
    x = x.mean(axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]


def resnet18_fwd_flops(num_classes: int, hw: int = 32) -> dict[str, float]:
    """Per-conv-leaf forward FLOPs for one (hw, hw, 3) image."""
    out: dict[str, float] = {}
    h = hw
    out["stem/w"] = conv_flops(3, 3, 3, 64, h, h)
    cin = 64
    for si, (w, stride) in enumerate(RESNET18_STAGES):
        for bi in range(2):
            s = stride if bi == 0 else 1
            h_out = h // s
            out[f"s{si}b{bi}/conv1/w"] = conv_flops(3, 3, cin, w, h_out, h_out)
            out[f"s{si}b{bi}/conv2/w"] = conv_flops(3, 3, w, w, h_out, h_out)
            if s != 1 or cin != w:
                out[f"s{si}b{bi}/down/w"] = conv_flops(1, 1, cin, w, h_out, h_out)
            cin = w
            h = h_out
    out["fc/w"] = 2.0 * 512 * num_classes
    return out


# ---------------------------------------------------------------------------
# VGG11-GN (CIFAR variant)
# ---------------------------------------------------------------------------

VGG11_CFG = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def init_vgg11(key, num_classes: int, dtype=jnp.float32) -> PyTree:
    n_convs = sum(1 for c in VGG11_CFG if c != "M")
    ks = jax.random.split(key, n_convs + 1)
    p: dict = {}
    cin = 3
    i = 0
    for c in VGG11_CFG:
        if c == "M":
            continue
        p[f"conv{i}"] = conv_init(ks[i], 3, 3, cin, c, dtype)
        p[f"gn{i}"] = groupnorm_init(c, dtype)
        cin = c
        i += 1
    p["fc"] = {"w": lecun_init(ks[-1], (512, num_classes), dtype, fan_in=512),
               "b": jnp.zeros((num_classes,), dtype)}
    return p


def vgg11_apply(params, images: jax.Array) -> jax.Array:
    x = images
    i = 0
    for c in VGG11_CFG:
        if c == "M":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        else:
            x = jax.nn.relu(groupnorm(params[f"gn{i}"], conv(params[f"conv{i}"], x, 1)))
            i += 1
    x = x.mean(axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]


def vgg11_fwd_flops(num_classes: int, hw: int = 32) -> dict[str, float]:
    out: dict[str, float] = {}
    h = hw
    cin = 3
    i = 0
    for c in VGG11_CFG:
        if c == "M":
            h //= 2
        else:
            out[f"conv{i}/w"] = conv_flops(3, 3, cin, c, h, h)
            cin = c
            i += 1
    out["fc/w"] = 2.0 * 512 * num_classes
    return out


# ---------------------------------------------------------------------------
# Small CNN (fast CPU experiments / tests)
# ---------------------------------------------------------------------------


def init_smallcnn(key, num_classes: int, dtype=jnp.float32, width: int = 16,
                  in_ch: int = 3) -> PyTree:
    ks = jax.random.split(key, 4)
    return {
        "conv0": conv_init(ks[0], 3, 3, in_ch, width, dtype),
        "gn0": groupnorm_init(width, dtype),
        "conv1": conv_init(ks[1], 3, 3, width, 2 * width, dtype),
        "gn1": groupnorm_init(2 * width, dtype),
        "conv2": conv_init(ks[2], 3, 3, 2 * width, 4 * width, dtype),
        "gn2": groupnorm_init(4 * width, dtype),
        "fc": {"w": lecun_init(ks[3], (4 * width, num_classes), dtype, fan_in=4 * width),
               "b": jnp.zeros((num_classes,), dtype)},
    }


def smallcnn_apply(params, images: jax.Array) -> jax.Array:
    x = jax.nn.relu(groupnorm(params["gn0"], conv(params["conv0"], images, 2)))
    x = jax.nn.relu(groupnorm(params["gn1"], conv(params["conv1"], x, 2)))
    x = jax.nn.relu(groupnorm(params["gn2"], conv(params["conv2"], x, 2)))
    x = x.mean(axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]


def smallcnn_fwd_flops(num_classes: int, hw: int = 32, width: int = 16,
                       in_ch: int = 3) -> dict[str, float]:
    h = hw // 2
    out = {"conv0/w": conv_flops(3, 3, in_ch, width, h, h)}
    h //= 2
    out["conv1/w"] = conv_flops(3, 3, width, 2 * width, h, h)
    h //= 2
    out["conv2/w"] = conv_flops(3, 3, 2 * width, 4 * width, h, h)
    out["fc/w"] = 2.0 * 4 * width * num_classes
    return out


def count_params(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))
