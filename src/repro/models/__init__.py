from repro.models.registry import ModelAPI, bind  # noqa: F401
