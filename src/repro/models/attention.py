"""GQA/MQA attention with RoPE, optional qk-norm, sliding windows, KV cache.

Covers every assigned attention variant:
  * GQA grouping (qwen3, starcoder2, llava/mistral, jamba, deepseek MHA)
  * MQA (gemma-2b / gemma3-1b, n_kv = 1)
  * qk_norm (qwen3)
  * sliding-window local layers (gemma3 5:1 local:global)
  * full-sequence (train), prefill (writes cache) and single-token decode
    (reads+writes cache at position `pos`).

Shapes: x (B, S, d).  Cache: {'k': (B, S_max, Hkv, Dh), 'v': same}.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init
from repro.sharding import constrain

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * dh, dtype, cfg.use_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * dh, dtype, cfg.use_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * dh, dtype, cfg.use_bias),
        "wo": dense_init(ks[3], cfg.n_heads * dh, cfg.d_model, dtype, cfg.use_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, dtype)
        p["k_norm"] = rmsnorm_init(dh, dtype)
    return p


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    dh = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
    }


def _qkv(params, x, cfg, positions):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, dh)
    k = dense(params["wk"], x).reshape(b, s, cfg.n_kv_heads, dh)
    v = dense(params["wv"], x).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, cfg, mask):
    """q: (B,Sq,H,Dh); k,v: (B,Sk,Hkv,Dh); mask: (B,Sq,Sk) or (Sq,Sk) bool."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q = q.reshape(b, sq, hkv, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, dh)


def _local_attention(q, k, v, cfg, window: int):
    """Banded sliding-window attention for full-sequence passes.

    Queries in block i attend only to keys in blocks i-1 and i (window == the
    block width covers exactly that span), so score tensors are
    (B, nb, W, 2W) instead of (B, S, S) — an S/(2W) reduction in score
    bytes/FLOPs (§Perf iteration C2 on gemma3's 5:1 local layers).
    Numerically identical to the masked full-attention path.
    """
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    w = window
    nb = s // w
    g = h // hkv
    scale = dh ** -0.5
    qb = q.reshape(b, nb, w, hkv, g, dh)
    kb = k.reshape(b, nb, w, hkv, dh)
    vb = v.reshape(b, nb, w, hkv, dh)
    # keys/values from the previous block and own block: (B, nb, 2W, Hkv, D)
    prev_k = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    prev_v = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([prev_k, kb], axis=2)
    v2 = jnp.concatenate([prev_v, vb], axis=2)
    scores = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2).astype(jnp.float32) * scale
    # positions within the 2W span: query i (local) = global w + i of span
    qpos = w + jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    # first block has no previous block: mask out the padded keys
    first = jnp.arange(nb)[:, None, None] == 0
    valid = jnp.where(first, mask[None] & (kpos >= w)[None], mask[None])
    scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", probs, v2)
    return out.reshape(b, s, h, dh)


def causal_mask(sq: int, sk: int, offset: int = 0, window: int = 0) -> jax.Array:
    """(sq, sk) bool; query i (global position offset+i) may see key j iff
    j <= offset+i and (window==0 or j > offset+i-window)."""
    qpos = offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m


def attention(
    params,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    window: int = 0,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    cross_kv: Optional[tuple] = None,
):
    """Returns (y, new_cache).

    * full-seq train: cache=None.
    * prefill: cache provided (zeros), pos=None -> writes k/v at [0, S).
    * decode: S==1 and pos (scalar int32) given -> read full cache, write at
      pos, attend to positions <= pos (within window if any).
    * cross-attention: cross_kv = (k, v) precomputed from the encoder; the
      cache/positions machinery is bypassed.
    """
    b, s, _ = x.shape
    if cross_kv is not None:
        dh = cfg.resolved_head_dim
        q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, dh)
        if cfg.qk_norm:
            q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k, v = cross_kv
        mask = jnp.ones((s, k.shape[1]), bool)
        out = _sdpa(q, k, v, cfg, mask)
        return dense(params["wo"], out.reshape(b, s, -1)), cache

    q, k, v = _qkv(params, x, cfg, positions)

    if cache is None:
        if window > 0 and s % window == 0 and s > window:
            out = _local_attention(q, k, v, cfg, window)
        else:
            mask = causal_mask(s, s, 0, window)
            out = _sdpa(q, k, v, cfg, mask)
    elif pos is None:
        # prefill: write the first s slots
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        cache = {"k": ck, "v": cv}
        mask = causal_mask(s, s, 0, window)
        out = _sdpa(q, k, v, cfg, mask)
    else:
        # single-token decode
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        cache = {"k": ck, "v": cv}
        sk = ck.shape[1]
        kpos = jnp.arange(sk)[None, :]
        m = kpos <= pos
        if window > 0:
            m = m & (kpos > pos - window)
        ck = constrain(ck, ("batch", "kv_seq", "kv_heads", "head_dim"))
        cv = constrain(cv, ("batch", "kv_seq", "kv_heads", "head_dim"))
        out = _sdpa(q, ck, cv, cfg, jnp.broadcast_to(m, (b, 1, sk)))

    y = dense(params["wo"], out.reshape(b, s, -1))
    return y, cache


def cross_kv_from_encoder(params, enc_out: jax.Array, cfg):
    """Precompute cross-attention K/V from encoder outputs (no RoPE)."""
    b, s, _ = enc_out.shape
    dh = cfg.resolved_head_dim
    k = dense(params["wk"], enc_out).reshape(b, s, cfg.n_kv_heads, dh)
    v = dense(params["wv"], enc_out).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return k, v
