"""Mamba-2 blocks via the SSD (state-space duality) chunked algorithm.

Implements the full Mamba-2 mixer (arXiv:2405.21060): fused in-projection
(z, x, B, C, dt), depthwise causal conv over (x, B, C), softplus dt with
bias, scalar-per-head A, chunked SSD scan, D skip, gated RMSNorm, output
projection.  Single dispatch group (G=1), heads H = d_inner / head_dim.

Three entry points:
  * ``ssm_apply``      — full sequence (training / prefill), chunked SSD with
                         a lax.scan over chunks for the inter-chunk state
                         recurrence (sub-quadratic in S: O(S * Q) with chunk
                         size Q).
  * ``ssm_decode_step``— O(1)-per-token recurrent update with carried
                         (ssm_state, conv_state) — this is what makes
                         long_500k decode tractable.
  * caches from ``init_ssm_cache``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import lecun_init, rmsnorm_init
from repro.sharding import constrain


def _dims(cfg):
    spec = cfg.ssm
    d_inner = spec.expand * cfg.d_model
    n_heads = d_inner // spec.head_dim
    conv_dim = d_inner + 2 * spec.d_state
    return spec, d_inner, n_heads, conv_dim


def ssm_init(key, cfg, dtype):
    spec, d_inner, n_heads, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * spec.d_state + n_heads
    ks = jax.random.split(key, 4)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[2], (n_heads,), jnp.float32)
    dt = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    a_init = jnp.log(jnp.linspace(1.0, 16.0, n_heads))
    return {
        "in_proj": lecun_init(ks[0], (cfg.d_model, d_in_proj), dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.conv_width, conv_dim), jnp.float32)
                   * (spec.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": a_init.astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": lecun_init(ks[3], (d_inner, cfg.d_model), dtype, fan_in=d_inner),
    }


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    spec, d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "ssm_state": jnp.zeros((batch, n_heads, spec.head_dim, spec.d_state), jnp.float32),
        "conv_state": jnp.zeros((batch, spec.conv_width - 1, conv_dim), dtype),
    }


def _gated_norm(norm_params, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    out = yf * jax.lax.rsqrt(var + eps)
    return out * (1.0 + norm_params["scale"].astype(jnp.float32))


def _split_proj(cfg, zxbcdt):
    spec, d_inner, n_heads, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: 2 * d_inner + 2 * spec.d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def _conv_full(params, xbc):
    """Depthwise causal conv over (B, L, C_conv)."""
    w = params["conv_w"].astype(jnp.float32)  # (W, C)
    width = w.shape[0]
    xf = xbc.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xf)
    for i in range(width):
        out = out + pad[:, i: i + xf.shape[1], :] * w[i]
    out = out + params["conv_b"].astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def _segsum(dA):
    """dA: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(xh, dt, a, Bm, Cm, chunk):
    """SSD over chunks.

    xh: (B, L, H, P)   inputs per head
    dt: (B, L, H)      softplus'd step sizes
    a:  (H,)           -exp(A_log), negative
    Bm, Cm: (B, L, N)  shared across heads (G=1)
    Returns y: (B, L, H, P) and final state (B, H, P, N).
    """
    b, l, h, p = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    nc = l // q
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"

    xh = (xh * dt[..., None]).reshape(b, nc, q, h, p).astype(jnp.float32)
    dA = (dt * a).reshape(b, nc, q, h)          # (B,C,Q,H) log decay
    dA = jnp.moveaxis(dA, -1, 2)                # (B,C,H,Q)
    Bc = Bm.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, q, n).astype(jnp.float32)

    # -- intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA))                    # (B,C,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,C,Q,Q)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores,
                        L, xh)

    # -- chunk states (right factors)
    cum = jnp.cumsum(dA, axis=-1)               # (B,C,H,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B,C,H,Q)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bc, decay_to_end, xh)

    # -- inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])          # (B,C,H)

    def step(carry, inp):
        st, dec = inp                            # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev                         # emit state BEFORE this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,C,H,P,N)

    # -- contribution of carried-in states
    decay_in = jnp.exp(cum)                      # (B,C,H,Q)
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp", Cc, decay_in, prev_states)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def ssm_apply(params, x: jax.Array, cfg, cache=None):
    """Full-sequence Mamba-2 block.  Returns (y, new_cache).

    If ``cache`` is given (prefill), the final SSD state and conv tail are
    written into it for subsequent decode steps.
    """
    spec, d_inner, n_heads, conv_dim = _dims(cfg)
    b, l, _ = x.shape
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc_conv = _conv_full(params, xbc)
    xs = xbc_conv[..., :d_inner]
    Bm = xbc_conv[..., d_inner: d_inner + spec.d_state]
    Cm = xbc_conv[..., d_inner + spec.d_state:]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xh = xs.reshape(b, l, n_heads, spec.head_dim)
    xh = constrain(xh, ("batch_noshard", "seq", "heads", "head_dim"))
    y, final_state = _ssd_chunked(xh.astype(jnp.float32), dtv, a, Bm, Cm, spec.chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, d_inner)
    y = _gated_norm(params["norm"], y, z, cfg.norm_eps)
    out = (y.astype(x.dtype)) @ params["out_proj"]
    if cache is not None:
        tail = xbc[:, -(spec.conv_width - 1):, :]
        cache = {"ssm_state": final_state,
                 "conv_state": tail.astype(cache["conv_state"].dtype)}
    return out, cache


def ssm_decode_step(params, x: jax.Array, cfg, cache: dict):
    """Single-token recurrent step.  x: (B, 1, d)."""
    spec, d_inner, n_heads, conv_dim = _dims(cfg)
    b = x.shape[0]
    zxbcdt = x[:, 0, :] @ params["in_proj"]      # (B, d_in_proj)
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    # depthwise conv via cached tail
    conv_state = cache["conv_state"]             # (B, W-1, conv_dim)
    window = jnp.concatenate([conv_state.astype(jnp.float32),
                              xbc.astype(jnp.float32)[:, None, :]], axis=1)
    w = params["conv_w"].astype(jnp.float32)     # (W, conv_dim)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"].astype(jnp.float32)
    xbc_c = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:, :].astype(conv_state.dtype)

    xs = xbc_c[..., :d_inner]
    Bm = xbc_c[..., d_inner: d_inner + spec.d_state]
    Cm = xbc_c[..., d_inner + spec.d_state:]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])                # (H,)
    dA = jnp.exp(dtv * a)                        # (B,H)
    xh = xs.reshape(b, n_heads, spec.head_dim).astype(jnp.float32)

    st = cache["ssm_state"]                      # (B,H,P,N)
    st = st * dA[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, Bm.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), st)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, d_inner)
    y = _gated_norm(params["norm"], y, z, cfg.norm_eps)
    out = (y.astype(x.dtype)) @ params["out_proj"]
    return out[:, None, :], {"ssm_state": st, "conv_state": new_conv_state}
