"""Encoder-decoder transformer (seamless-m4t backbone).

The encoder consumes *precomputed frame embeddings* (B, S_enc, d) — the
audio frontend (mel + conformer conv) is the allowed stub — and runs
bidirectional self-attention layers.  The decoder is a causal LM stack with
cross-attention into the encoder outputs.

Caching: cross-attention K/V are computed once at prefill and carried in the
decode cache alongside the self-attention KV cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.common import embed_init, embed_lookup, lecun_init, rmsnorm, rmsnorm_init
from repro.models.lm import _head, _mlp_apply, _mlp_init
from repro.utils.tree import tree_stack

PyTree = Any


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(ks[0], cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": _mlp_init(ks[1], cfg, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "self_attn": attn_mod.attn_init(ks[0], cfg, dtype),
        "norm_x": rmsnorm_init(cfg.d_model, dtype),
        "cross_attn": attn_mod.attn_init(ks[1], cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": _mlp_init(ks[2], cfg, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 4)
    enc = [_enc_layer_init(k, cfg, dtype)
           for k in jax.random.split(ks[0], cfg.enc_layers)]
    dec = [_dec_layer_init(k, cfg, dtype)
           for k in jax.random.split(ks[1], cfg.n_layers)]
    return {
        "embed": {"table": embed_init(ks[2], (cfg.vocab, cfg.d_model), dtype)},
        "encoder": tree_stack(enc),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "decoder": tree_stack(dec),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def _sinusoidal_pos(s: int, d: int, dtype) -> jax.Array:
    """Length-agnostic sinusoidal encoder positions (frame counts vary from
    seconds of audio to half-hour streams; a learned table would cap them)."""
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe.astype(dtype)


def encode(params, frames: jax.Array, cfg: ModelConfig, unroll: bool = False):
    """frames: (B, S_enc, d) stub embeddings -> (B, S_enc, d)."""
    b, s, _ = frames.shape
    x = frames + _sinusoidal_pos(s, cfg.d_model, frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def layer(x, p):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = attn_mod._qkv(p["attn"], h, cfg, positions)
        mask = jnp.ones((s, s), bool)  # bidirectional
        y = attn_mod._sdpa(q, k, v, cfg, mask)
        from repro.models.common import dense
        x = x + dense(p["attn"]["wo"], y.reshape(b, s, -1))
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + _mlp_apply(p["mlp"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(layer, x, params["encoder"], unroll=unroll)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(p, x, cfg, positions, cross_kv, cache=None, pos=None):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    y, cache = attn_mod.attention(p["self_attn"], h, positions, cfg,
                                  cache=cache, pos=pos)
    x = x + y
    h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
    y, _ = attn_mod.attention(p["cross_attn"], h, positions, cfg,
                              cross_kv=cross_kv)
    x = x + y
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], h, cfg)
    return x, cache


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
                      dtype=jnp.float32) -> PyTree:
    dh = cfg.resolved_head_dim
    self_kv = [attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
               for _ in range(cfg.n_layers)]
    cross = [{"k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, dh), dtype),
              "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, dh), dtype)}
             for _ in range(cfg.n_layers)]
    return {"self": tree_stack(self_kv), "cross": tree_stack(cross)}


def decode_train(params, frames, tokens, cfg: ModelConfig, remat: bool = True,
                 unroll: bool = False):
    """Teacher-forced training pass.  Returns (logits, aux=0)."""
    enc_out = encode(params, frames, cfg, unroll=unroll)
    x = embed_lookup(params["embed"]["table"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def layer(x, p):
        kv = attn_mod.cross_kv_from_encoder(p["cross_attn"], enc_out, cfg)
        x, _ = _dec_layer(p, x, cfg, positions, kv)
        return x, None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["decoder"],
                        unroll=unroll)
    return _head(params, x, cfg), jnp.zeros((), jnp.float32)


def prefill(params, frames, tokens, cfg: ModelConfig, cache, unroll: bool = False):
    """Encode + teacher-forced decoder prefill; fills self+cross caches."""
    enc_out = encode(params, frames, cfg, unroll=unroll)
    x = embed_lookup(params["embed"]["table"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def layer(x, inp):
        p, c_self = inp
        kv = attn_mod.cross_kv_from_encoder(p["cross_attn"], enc_out, cfg)
        x, c = _dec_layer(p, x, cfg, positions, kv, cache=c_self)
        return x, (c, {"k": kv[0], "v": kv[1]})

    x, (self_c, cross_c) = jax.lax.scan(layer, x, (params["decoder"], cache["self"]),
                                        unroll=unroll)
    logits = _head(params, x[:, -1:, :], cfg)
    return logits, {"self": self_c, "cross": cross_c}


def decode_step(params, tokens, pos, cfg: ModelConfig, cache, unroll: bool = False):
    """One-token decode using cached self KV + cross KV."""
    x = embed_lookup(params["embed"]["table"], tokens)
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.reshape(pos, (1, 1)), (b, 1))

    def layer(x, inp):
        p, c_self, c_cross = inp
        kv = (c_cross["k"], c_cross["v"])
        x, c = _dec_layer(p, x, cfg, positions, kv, cache=c_self, pos=pos)
        return x, c

    x, self_c = jax.lax.scan(
        layer, x, (params["decoder"], cache["self"], cache["cross"]),
        unroll=unroll)
    return _head(params, x, cfg), {"self": self_c, "cross": cache["cross"]}
