"""Decoder-only LM stack covering dense / MoE / SSM / hybrid / VLM families.

Layers are grouped into repeating *period blocks* (period = lcm of the
local/global, MoE and hybrid interleave periods) and executed with
``jax.lax.scan`` over stacked block parameters, so a 72-layer Jamba lowers to
a small HLO.  Layers outside the periodic body (a special first layer, or a
non-divisible tail) are unrolled.

Modes:
  * ``forward_train``   — full sequence, returns (logits, aux_loss)
  * ``forward_prefill`` — full sequence, writes KV/SSM caches
  * ``forward_decode``  — one token at position ``pos`` with caches

VLM/audio decoder-only variants accept ``prefix`` — precomputed patch/frame
embeddings (B, P, d) occupying the first P positions (the allowed frontend
stub); labels over the prefix must be -1 (ignored) in the loss.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SubLayer, layer_kinds
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    activation,
    dense,
    embed_init,
    embed_lookup,
    lecun_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.sharding import constrain
from repro.utils.tree import tree_stack

PyTree = Any


# ---------------------------------------------------------------------------
# Structure resolution
# ---------------------------------------------------------------------------


def intrinsic_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.local_period > 0:
        p = math.lcm(p, cfg.local_period)
    if cfg.moe is not None and cfg.moe_period > 1:
        p = math.lcm(p, cfg.moe_period)
    if cfg.ssm is not None and cfg.attn_period > 0:
        p = math.lcm(p, cfg.attn_period)
    return p


def layer_plan(cfg: ModelConfig):
    """Returns (prelude_idx, period, n_blocks, tail_idx, kinds)."""
    kinds = layer_kinds(cfg)
    prelude = [0] if cfg.dense_ff_first > 0 else []
    start = len(prelude)
    period = intrinsic_period(cfg)
    body = cfg.n_layers - start
    n_blocks = body // period
    tail_start = start + n_blocks * period
    tail = list(range(tail_start, cfg.n_layers))
    return prelude, period, n_blocks, tail, kinds


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def _mlp_init(key, cfg, d_ff, dtype):
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {
            "w_gate": lecun_init(ks[0], (cfg.d_model, d_ff), dtype),
            "w_up": lecun_init(ks[1], (cfg.d_model, d_ff), dtype),
            "w_down": lecun_init(ks[2], (d_ff, cfg.d_model), dtype, fan_in=d_ff),
        }
    return {
        "w_up": lecun_init(ks[0], (cfg.d_model, d_ff), dtype),
        "w_down": lecun_init(ks[1], (d_ff, cfg.d_model), dtype, fan_in=d_ff),
    }


def _mlp_apply(p, x, cfg):
    act = activation(cfg.act)
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    h = constrain(h, ("batch_noshard", "seq", "ffn"))
    return h @ p["w_down"]


def layer_init(key, cfg: ModelConfig, sub: SubLayer, dtype):
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if sub.kind == "attn":
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
    if sub.ffn == "mlp":
        d_ff = sub.d_ff_override or cfg.d_ff
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["mlp"] = _mlp_init(ks[1], cfg, d_ff, dtype)
    elif sub.ffn == "moe":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.moe, dtype)
    return p


def layer_cache_init(cfg: ModelConfig, sub: SubLayer, batch: int, max_len: int, dtype):
    if sub.kind == "attn":
        return attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
    return ssm_mod.init_ssm_cache(cfg, batch, dtype)


def layer_apply(p, x, sub: SubLayer, cfg: ModelConfig, positions,
                cache=None, pos=None, moe_dense: bool = False):
    """Pre-norm residual layer.  Returns (x, cache_out, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if sub.kind == "attn":
        y, cache = attn_mod.attention(p["attn"], h, positions, cfg,
                                      window=sub.window, cache=cache, pos=pos)
    else:
        if pos is None and cache is None:
            y, cache = ssm_mod.ssm_apply(p["ssm"], h, cfg, cache=None)
        elif pos is None:
            y, cache = ssm_mod.ssm_apply(p["ssm"], h, cfg, cache=cache)
        else:
            y, cache = ssm_mod.ssm_decode_step(p["ssm"], h, cfg, cache)
    x = x + y
    if sub.ffn == "mlp":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + _mlp_apply(p["mlp"], h, cfg)
    elif sub.ffn == "moe":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if moe_dense:
            y, a = moe_mod.moe_dense_ref(p["moe"], h, cfg.moe, cfg.act)
        else:
            y, a = moe_mod.moe_apply(p["moe"], h, cfg.moe, cfg.act)
        x = x + y
        aux = aux + a
    return x, cache, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    prelude, period, n_blocks, tail, kinds = layer_plan(cfg)
    n_keys = 3 + len(prelude) + n_blocks * period + len(tail)
    ks = iter(jax.random.split(key, n_keys))
    params: dict = {"embed": {"table": embed_init(next(ks), (cfg.vocab, cfg.d_model), dtype)}}
    if prelude:
        params["prelude"] = {
            str(i): layer_init(next(ks), cfg, kinds[i], dtype) for i in prelude
        }
    if n_blocks > 0:
        blocks = {}
        start = len(prelude)
        for j in range(period):
            per_block = [
                layer_init(next(ks), cfg, kinds[start + b * period + j], dtype)
                for b in range(n_blocks)
            ]
            blocks[f"p{j}"] = tree_stack(per_block)
        params["blocks"] = blocks
    if tail:
        params["tail"] = {
            str(i): layer_init(next(ks), cfg, kinds[i], dtype) for i in tail
        }
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = {"w": lecun_init(next(ks), (cfg.d_model, cfg.vocab), dtype)}
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32) -> PyTree:
    prelude, period, n_blocks, tail, kinds = layer_plan(cfg)
    cache: dict = {}
    if prelude:
        cache["prelude"] = {
            str(i): layer_cache_init(cfg, kinds[i], batch, max_len, dtype) for i in prelude
        }
    if n_blocks > 0:
        start = len(prelude)
        blocks = {}
        for j in range(period):
            per_block = [
                layer_cache_init(cfg, kinds[start + b * period + j], batch, max_len, dtype)
                for b in range(n_blocks)
            ]
            blocks[f"p{j}"] = tree_stack(per_block)
        cache["blocks"] = blocks
    if tail:
        cache["tail"] = {
            str(i): layer_cache_init(cfg, kinds[i], batch, max_len, dtype) for i in tail
        }
    return cache


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg, prefix=None):
    x = embed_lookup(params["embed"]["table"], tokens)
    if cfg.family in ("vlm", "audio") or prefix is not None:
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    return x


def _head(params, x, cfg):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["head"], x)
    if cfg.logit_softcap > 0:
        lf = logits.astype(jnp.float32)
        logits = (jnp.tanh(lf / cfg.logit_softcap) * cfg.logit_softcap).astype(logits.dtype)
    return constrain(logits, ("batch_noshard", "seq", "vocab"))


def _sub_for(cfg, kinds, idx):
    return kinds[idx]


def forward_train(params, tokens, cfg: ModelConfig, prefix=None, remat: bool = True,
                  unroll: bool = False, remat_policy: str = "full",
                  moe_dense: bool = False):
    """tokens: (B, S_text); prefix: optional (B, P, d).  Returns
    (logits (B, S_total, V), aux_loss scalar)."""
    prelude, period, n_blocks, tail, kinds = layer_plan(cfg)
    x = _embed(params, tokens, cfg, prefix)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    aux_total = jnp.zeros((), jnp.float32)

    for i in prelude:
        x, _, a = layer_apply(params["prelude"][str(i)], x, kinds[i], cfg, positions,
                              moe_dense=moe_dense)
        aux_total += a

    if n_blocks > 0:
        start = len(prelude)

        def block_fn(x, block_params):
            aux = jnp.zeros((), jnp.float32)
            for j in range(period):
                sub = kinds[start + j]  # same structure for every block
                x, _, a = layer_apply(block_params[f"p{j}"], x, sub, cfg, positions,
                                      moe_dense=moe_dense)
                aux += a
            return x, aux

        if not remat:
            body = block_fn
        elif remat_policy == "dots":
            # NOTE: dots_with_no_batch_dims_saveable is useless here — the
            # client vmap gives every dot a batch dim; save all dot outputs
            body = jax.checkpoint(
                block_fn, policy=jax.checkpoint_policies.dots_saveable)
        else:
            body = jax.checkpoint(block_fn)
        x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, params["blocks"],
                               unroll=unroll)
        aux_total += jnp.sum(auxs)

    for i in tail:
        x, _, a = layer_apply(params["tail"][str(i)], x, kinds[i], cfg, positions,
                              moe_dense=moe_dense)
        aux_total += a

    return _head(params, x, cfg), aux_total


def forward_prefill(params, tokens, cfg: ModelConfig, cache, prefix=None,
                    unroll: bool = False, moe_dense: bool = False):
    """Full-sequence forward writing caches.  Returns (logits, cache)."""
    prelude, period, n_blocks, tail, kinds = layer_plan(cfg)
    x = _embed(params, tokens, cfg, prefix)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    new_cache: dict = {k: {} for k in cache}

    for i in prelude:
        x, c, _ = layer_apply(params["prelude"][str(i)], x, kinds[i], cfg,
                              positions, cache=cache["prelude"][str(i)], moe_dense=moe_dense)
        new_cache["prelude"][str(i)] = c

    if n_blocks > 0:
        start = len(prelude)

        def block_fn(x, inp):
            block_params, block_cache = inp
            outs = {}
            for j in range(period):
                sub = kinds[start + j]
                x, c, _ = layer_apply(block_params[f"p{j}"], x, sub, cfg,
                                      positions, cache=block_cache[f"p{j}"], moe_dense=moe_dense)
                outs[f"p{j}"] = c
            return x, outs

        x, blocks_cache = jax.lax.scan(
            block_fn, x, (params["blocks"], cache["blocks"]), unroll=unroll)
        new_cache["blocks"] = blocks_cache

    for i in tail:
        x, c, _ = layer_apply(params["tail"][str(i)], x, kinds[i], cfg,
                              positions, cache=cache["tail"][str(i)], moe_dense=moe_dense)
        new_cache["tail"][str(i)] = c

    logits = _head(params, x[:, -1:, :], cfg)
    return logits, new_cache


def forward_decode(params, tokens, pos, cfg: ModelConfig, cache,
                   unroll: bool = False, moe_dense: bool = False):
    """One-token decode.  tokens: (B, 1); pos: scalar int32 (current write
    position, == number of tokens already in cache).  Returns (logits, cache)."""
    prelude, period, n_blocks, tail, kinds = layer_plan(cfg)
    x = embed_lookup(params["embed"]["table"], tokens)
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None] if pos.ndim == 0 else pos, (b, 1))
    new_cache: dict = {k: {} for k in cache}

    for i in prelude:
        x, c, _ = layer_apply(params["prelude"][str(i)], x, kinds[i], cfg,
                              positions, cache=cache["prelude"][str(i)], pos=pos, moe_dense=moe_dense)
        new_cache["prelude"][str(i)] = c

    if n_blocks > 0:
        start = len(prelude)

        def block_fn(x, inp):
            block_params, block_cache = inp
            outs = {}
            for j in range(period):
                sub = kinds[start + j]
                x, c, _ = layer_apply(block_params[f"p{j}"], x, sub, cfg,
                                      positions, cache=block_cache[f"p{j}"], pos=pos, moe_dense=moe_dense)
                outs[f"p{j}"] = c
            return x, outs

        x, blocks_cache = jax.lax.scan(
            block_fn, x, (params["blocks"], cache["blocks"]), unroll=unroll)
        new_cache["blocks"] = blocks_cache

    for i in tail:
        x, c, _ = layer_apply(params["tail"][str(i)], x, kinds[i], cfg,
                              positions, cache=cache["tail"][str(i)], pos=pos, moe_dense=moe_dense)
        new_cache["tail"][str(i)] = c

    return _head(params, x, cfg), new_cache
