"""Shared model building blocks: init, norms, RoPE, activations, embeddings.

Everything is framework-free: params are nested dicts of jnp arrays, modules
are (init_fn, apply_fn) pairs of plain functions.  Compute dtype and param
dtype are separated so the same definitions serve the CPU simulator (f32)
and the TPU dry-run (bf16 params, f32 accumulation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, stddev):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def lecun_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return normal_init(key, shape, dtype, 1.0 / np.sqrt(max(fan_in, 1)))


def embed_init(key, shape, dtype):
    return normal_init(key, shape, dtype, 1.0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # (1+scale) parameterization


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def groupnorm_init(c, dtype):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def groupnorm(params, x, groups=32, eps=1e-5):
    """GroupNorm over NHWC tensors (paper replaces BN with GN, App. B.2)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xf = x.astype(jnp.float32).reshape(n, h, w, g, c // g)
    mean = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = xf.var(axis=(1, 2, 4), keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(n, h, w, c)
    return (xf * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    ang = ang[..., None, :]  # (..., S, 1, D/2) broadcast over heads
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# Dense / embedding layers
# ---------------------------------------------------------------------------


def dense_init(key, d_in, d_out, dtype, use_bias=False):
    p = {"w": lecun_init(key, (d_in, d_out), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array, mask=None):
    """Mean token cross-entropy with f32 logsumexp; labels < 0 are padding."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    per_tok = lse - ll
    valid = (labels >= 0).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask.astype(jnp.float32)
    return jnp.sum(per_tok * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def accuracy(logits: jax.Array, labels: jax.Array):
    pred = jnp.argmax(logits, axis=-1)
    valid = labels >= 0
    return jnp.sum((pred == labels) & valid) / jnp.maximum(jnp.sum(valid), 1)


@dataclasses.dataclass
class DTypePolicy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @staticmethod
    def tpu():
        return DTypePolicy(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)
