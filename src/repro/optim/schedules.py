"""Learning-rate schedules."""
from __future__ import annotations

import math


def exp_decay(lr0: float, decay: float, round_idx: int) -> float:
    """Paper schedule: lr = lr0 * decay**round (0.1, 0.998)."""
    return lr0 * (decay ** round_idx)


def cosine_schedule(lr0: float, step: int, total: int, min_frac: float = 0.1) -> float:
    t = min(step, total) / max(total, 1)
    return lr0 * (min_frac + (1 - min_frac) * 0.5 * (1 + math.cos(math.pi * t)))
