from repro.optim.sgd import (  # noqa: F401
    SGDConfig,
    apply_updates,
    init_sgd,
    masked_sgd_step,
    sgd_step,
)
from repro.optim.schedules import exp_decay, cosine_schedule  # noqa: F401
