"""SGD with momentum + weight decay, and the masked variant used by DisPFL.

The paper (App. B.3) uses SGD, weight decay 5e-4, lr 0.1 decayed by 0.998
per communication round, batch 128, 5 local epochs.

``masked_sgd_step`` implements Alg. 1 line 12:
    w <- w - eta * m ⊙ g
with momentum also masked so dormant coordinates carry no stale state (they
must re-enter at exactly 0 so the next gossip warm-starts them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.0
    weight_decay: float = 5e-4
    nesterov: bool = False


def init_sgd(params: PyTree, cfg: SGDConfig) -> PyTree:
    if cfg.momentum == 0.0:
        return {}
    return {"mu": jax.tree.map(jnp.zeros_like, params)}


def _momentum_update(g, mu, cfg: SGDConfig):
    if cfg.momentum == 0.0:
        return g, None
    new_mu = cfg.momentum * mu + g
    if cfg.nesterov:
        upd = g + cfg.momentum * new_mu
    else:
        upd = new_mu
    return upd, new_mu


def sgd_step(params: PyTree, grads: PyTree, state: PyTree, cfg: SGDConfig,
             lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state)."""
    lr = cfg.lr if lr is None else lr

    def upd(w, g, mu):
        g = g + cfg.weight_decay * w
        u, new_mu = _momentum_update(g, mu, cfg)
        return w - lr * u, new_mu

    if cfg.momentum == 0.0:
        new = jax.tree.map(lambda w, g: w - lr * (g + cfg.weight_decay * w),
                           params, grads)
        return new, state
    out = jax.tree.map(upd, params, grads, state["mu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu}


def masked_sgd_step(params: PyTree, grads: PyTree, mask: PyTree, state: PyTree,
                    cfg: SGDConfig, lr: Optional[jax.Array] = None):
    """w <- w - eta * m ⊙ (g + wd*w); momentum masked the same way."""
    lr = cfg.lr if lr is None else lr

    def upd(w, g, m, mu):
        mf = m.astype(w.dtype)
        g = (g + cfg.weight_decay * w) * mf
        u, new_mu = _momentum_update(g, mu, cfg)
        if new_mu is not None:
            new_mu = new_mu * mf
        return (w - lr * u) * mf, new_mu

    if cfg.momentum == 0.0:
        new = jax.tree.map(
            lambda w, g, m: (w - lr * (g + cfg.weight_decay * w) * m.astype(w.dtype))
            * m.astype(w.dtype),
            params, grads, mask)
        return new, state
    out = jax.tree.map(upd, params, grads, mask, state["mu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu}


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, params, updates)
