"""Stacked-client state containers and the stacked compute primitives.

Everything in this module operates on *client-stacked* pytrees: every leaf
carries a leading ``K`` (client) dimension, so one jitted program expresses
what the reference engine does with a Python loop over clients.  Under a
mesh (``sharding.rules.tree_stacked_shardings``) the K dim is sharded over
the client axes and GSPMD emits the collectives for the gossip fold.

Primitives
----------
``masked_gossip_stacked``   DisPFL's intersection-weighted gossip as an
                            adjacency-weighted masked fold over the K dim.
                            ``reduction="einsum"`` is the fast SPMD form
                            (one matmul per leaf; fp reduction order is
                            XLA's); ``reduction="ordered"`` reproduces the
                            reference engine's per-client accumulation
                            order (own model first, then neighbors in
                            ascending index) bit for bit — the form the
                            golden-equivalence suite pins down.
``plain_mix_stacked``       row-stochastic mixing (D-PSGD Metropolis), same
                            two reductions.
``stacked_local_phase``     the engine's vmap-over-clients local SGD scan
                            (identical update rule, ragged schedules padded
                            and live-masked, momentum as stacked state) as
                            a *traceable* function, so it can fuse into the
                            single round program.
``stacked_evolve_exact``    Alg. 2 prune/regrow batched over clients with
                            *traced* per-layer (n_keep, n_prune) counts —
                            exact argsort top-k semantics (bit-identical to
                            ``core.evolve.evolve_mask_layer``), and no
                            recompilation when the cosine schedule or an
                            annealed density changes the counts per round.
``stacked_prune_regrow_threshold``
                            the threshold-based variant for giant archs
                            (sampled-sort thresholds, tie drift tolerated)
                            — previously a private body inside
                            ``launch/steps.make_mask_update_step``; it now
                            lives here so there is exactly one stacked
                            mask-search implementation.

Stacked packed payloads
-----------------------
``StackedPacked`` is the K-client form of ``repro.sparse.PackedSparse``:
bitmaps stacked ``(K, n_words)``, values right-padded to the max nnz with a
``(K,)`` nnz vector.  ``pack_stacked``/``unpack_stacked`` round-trip a
stacked state bit-exactly; ``split_stacked`` yields the K individual
``PackedSparse`` trees (what actually crosses a link, codec-sized), and
``fold_stacked`` accumulates a stacked payload into stacked (num, den)
accumulators through ``repro.kernels.packed_accum`` (ref or Pallas
backend).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import softmax_xent
from repro.optim import SGDConfig, masked_sgd_step, sgd_step
from repro.sparse.packed import (
    PackedSparse,
    _is_packed,
    _pack_bits,
    _unpack_bits,
    n_words,
)
from repro.utils.tree import tree_map_with_path

PyTree = Any

REDUCTIONS = ("einsum", "ordered")


def _check_reduction(reduction: str) -> None:
    if reduction not in REDUCTIONS:
        raise ValueError(
            f"reduction must be one of {REDUCTIONS}, got {reduction!r}")


# ---------------------------------------------------------------------------
# Stacked gossip folds
# ---------------------------------------------------------------------------


def masked_gossip_stacked(params: PyTree, masks: PyTree, adjacency: jax.Array,
                          reduction: str = "einsum",
                          accum_dtype=jnp.float32) -> PyTree:
    """Intersection-weighted gossip over the stacked client dim.

    ``adjacency`` is the (K, K) receive matrix with unit diagonal (client k
    mixes the models of every j with A[k, j] > 0, itself included).

    * ``"einsum"``: num/den are adjacency matmuls over K — the SPMD form
      (GSPMD turns the K-sharded contraction into collectives).  XLA picks
      the fp reduction order, so results match the reference engine to a
      few ulps, not bitwise.
    * ``"ordered"``: a fori-loop fold that adds contributions in exactly the
      reference order (own model first, then senders in ascending index),
      bit-identical to ``core.gossip.gossip_average_one`` per client.
    """
    _check_reduction(reduction)
    a = adjacency.astype(accum_dtype)

    if reduction == "einsum":

        def one(w, m):
            mf = m.astype(accum_dtype)
            wf = w.astype(accum_dtype) * mf
            num = jnp.einsum("kj,j...->k...", a, wf)
            den = jnp.einsum("kj,j...->k...", a, mf)
            mix = (num.astype(jnp.float32)
                   / jnp.maximum(den.astype(jnp.float32), 1.0))
            return (mix * m.astype(jnp.float32)).astype(w.dtype)

        return jax.tree.map(one, params, masks)

    k_clients = adjacency.shape[0]
    # off-diagonal gate: sender j contributes to receiver k iff an edge
    gate = a * (1.0 - jnp.eye(k_clients, dtype=accum_dtype))
    gate = (gate > 0).astype(accum_dtype)

    def one(w, m):
        mf = m.astype(accum_dtype)
        wf = w.astype(accum_dtype)
        bshape = (k_clients,) + (1,) * (w.ndim - 1)

        def body(j, carry):
            num, den = carry
            g = gate[:, j].reshape(bshape)
            return (num + g * (wf[j] * mf[j]), den + g * mf[j])

        num, den = jax.lax.fori_loop(0, k_clients, body, (wf * mf, mf))
        mix = (num.astype(jnp.float32)
               / jnp.maximum(den.astype(jnp.float32), 1.0))
        return (mix * m.astype(jnp.float32)).astype(w.dtype)

    return jax.tree.map(one, params, masks)


def plain_mix_stacked(params: PyTree, mixing: jax.Array,
                      reduction: str = "einsum") -> PyTree:
    """Row-stochastic mixing ``w_k <- sum_j W[k, j] w_j`` over the K dim
    (D-PSGD / Metropolis).  ``"ordered"`` adds terms in ascending sender
    index, matching the reference engine's accumulation bit for bit."""
    _check_reduction(reduction)
    if reduction == "einsum":

        def one(w):
            return jnp.einsum("kj,j...->k...", mixing.astype(w.dtype), w)

        return jax.tree.map(one, params)

    k_clients = mixing.shape[0]

    def one(w):
        wm = mixing.astype(w.dtype)
        bshape = (k_clients,) + (1,) * (w.ndim - 1)

        def body(j, acc):
            return acc + wm[:, j].reshape(bshape) * w[j]

        return jax.lax.fori_loop(0, k_clients, body, jnp.zeros_like(w))

    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# Stacked local phase (traceable; fuses into the single round program)
# ---------------------------------------------------------------------------


def stacked_local_phase(apply_fn: Callable, opt: SGDConfig, params: PyTree,
                        masks: Optional[PyTree], bx: jax.Array, by: jax.Array,
                        live: jax.Array, lr: jax.Array) -> PyTree:
    """The engine's vmap local phase as a plain traceable function.

    Identical semantics to ``RoundEngine._vmapped_fn``: a lax.scan over the
    padded step schedule per client, masked/unmasked SGD steps from
    ``repro.optim``, padded (non-live) steps are exact no-ops, momentum is
    zero-initialized stacked per-client state.
    """

    def loss(p, x, y):
        return softmax_xent(apply_fn(p, x), y)

    grad = jax.grad(loss)
    use_mask = masks is not None

    def per_client(p, m, cx, cy, lv):
        def body(carry, xyl):
            w, st = carry
            x, y, alive = xyl
            g = grad(w, x, y)
            if use_mask:
                w2, st2 = masked_sgd_step(w, g, m, st, opt, lr)
            else:
                w2, st2 = sgd_step(w, g, st, opt, lr)
            w = jax.tree.map(lambda o, nn: jnp.where(alive, nn, o), w, w2)
            st = jax.tree.map(lambda o, nn: jnp.where(alive, nn, o), st, st2)
            return (w, st), None

        st0 = ({"mu": jax.tree.map(jnp.zeros_like, p)}
               if opt.momentum != 0.0 else {})
        (p, _), _ = jax.lax.scan(body, (p, st0), (cx, cy, lv))
        return p

    if use_mask:
        return jax.vmap(per_client)(params, masks, bx, by, live)
    return jax.vmap(
        lambda p, cx, cy, lv: per_client(p, None, cx, cy, lv))(
            params, bx, by, live)


# ---------------------------------------------------------------------------
# Stacked mask evolution — exact (golden) and threshold (giant-arch) forms
# ---------------------------------------------------------------------------


def _topk_rows(scores: jax.Array, k: jax.Array) -> jax.Array:
    """Per-row {0,1} selection of the ``k`` largest scores, exact count and
    argsort tie-breaking identical to ``core.evolve._exact_topk_mask``, but
    with ``k`` *traced* (rank < k instead of a static scatter slice)."""
    n = scores.shape[1]
    order = jnp.argsort(-scores, axis=1)
    rows = jnp.arange(scores.shape[0])[:, None]
    ranks = jnp.zeros(scores.shape, jnp.int32).at[rows, order].set(
        jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), scores.shape))
    return (ranks < k).astype(jnp.float32)


def stacked_evolve_exact(params: PyTree, masks: PyTree, grads: PyTree,
                         counts: dict) -> tuple[PyTree, PyTree]:
    """Alg. 2 (magnitude prune + gradient regrow), batched over the K dim.

    ``counts`` maps sparsifiable leaf paths (unstacked convention, e.g.
    ``"conv0/w"``) to traced ``(n_keep, n_prune)`` int32 scalars — the same
    integers the reference computes from ``(prune_rate, n_active)`` with
    ``math.ceil`` on the host, so the cosine schedule (and dispfl_anneal's
    per-round ERK budgets) never trigger a recompile.  Leaves without an
    entry pass through unchanged.  Bit-identical per client to
    ``core.evolve.evolve_mask_layer``.
    """

    def one(path, w, m, g):
        if path not in counts:
            return m, w
        n_keep, n_prune = counts[path]
        kdim = w.shape[0]
        mf = m.reshape(kdim, -1).astype(jnp.float32)
        wf = w.reshape(kdim, -1).astype(jnp.float32)
        gf = g.reshape(kdim, -1).astype(jnp.float32)
        neg_inf = jnp.float32(-jnp.inf)
        keep_scores = jnp.where(mf > 0, jnp.abs(wf), neg_inf)
        m_half = _topk_rows(keep_scores, n_keep)
        grow_scores = jnp.where(m_half > 0, neg_inf, jnp.abs(gf))
        grown = _topk_rows(grow_scores, n_prune)
        new_m = (m_half + grown).reshape(w.shape)
        new_w = w * new_m.astype(w.dtype)
        return new_m.astype(m.dtype), new_w

    paired = tree_map_with_path(one, params, masks, grads)
    is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
    new_masks = jax.tree.map(lambda t: t[0], paired, is_leaf=is_pair)
    new_params = jax.tree.map(lambda t: t[1], paired, is_leaf=is_pair)
    return new_masks, new_params


def evolve_counts_for(budgets: dict[str, int], prune_rate: float) -> dict:
    """Host-side per-round counts: the exact ``(n_keep, n_prune)`` integers
    the reference derives per layer (``math.ceil`` on the host float, so no
    f32 rounding drift against ``core.evolve.evolve_mask_layer``)."""
    import math

    out = {}
    for path, n_active in budgets.items():
        n_prune = int(math.ceil(prune_rate * n_active))
        out[path] = (jnp.int32(n_active - n_prune), jnp.int32(n_prune))
    return out


def default_threshold_sparsifiable(w: jax.Array) -> bool:
    """Matrix-shaped stacked leaves; stacked norm scales / biases / dt
    vectors stay dense (mirrors ``core.masks.default_sparsifiable`` on the
    unstacked tree)."""
    return w.ndim >= 3 and w.shape[-1] >= 64 and w.shape[-2] >= 64


def stacked_prune_regrow_threshold(
    params: PyTree, masks: PyTree, grads: PyTree, prune_rate: jax.Array,
    density: float,
    sparsifiable: Callable[[jax.Array], bool] = default_threshold_sparsifiable,
) -> tuple[PyTree, PyTree]:
    """Threshold-based stacked prune/regrow for giant archs.

    Per client and leaf: kth-order-statistic thresholds via sort (identical
    semantics to ``kernels/ops.prune_regrow`` up to ties).  Layer budgets
    are static (``density`` x numel) so the program is shape-static; the
    |g| > 0 guard keeps zero-gradient coordinates (embedding rows absent
    from the batch) from mass-regrowing on threshold ties at 0.  This is
    the sampled-threshold counterpart of ``stacked_evolve_exact`` — tie
    drift tolerated, no exact-count guarantee — practical for leaves where
    an argsort-based exact top-k would dominate the step.
    """

    def one(w, g, m):
        if not sparsifiable(w):
            return m, w
        k = w.shape[0]
        wf = w.reshape(k, -1).astype(jnp.float32)
        gf = g.reshape(k, -1).astype(jnp.float32)
        mf = m.reshape(k, -1).astype(jnp.float32)
        n = wf.shape[1]
        n_active = max(1, int(round(density * n)))
        n_prune = jnp.ceil(prune_rate * n_active).astype(jnp.int32)
        n_keep = n_active - n_prune
        keep_sorted = jnp.sort(
            jnp.where(mf > 0, jnp.abs(wf), -jnp.inf), axis=1)[:, ::-1]
        w_th = jnp.take_along_axis(
            keep_sorted,
            jnp.broadcast_to(jnp.maximum(n_keep - 1, 0), (k,))[:, None],
            axis=1)
        grow_sorted = jnp.sort(
            jnp.where(mf > 0, -jnp.inf, jnp.abs(gf)), axis=1)[:, ::-1]
        g_th = jnp.take_along_axis(
            grow_sorted,
            jnp.broadcast_to(jnp.maximum(n_prune - 1, 0), (k,))[:, None],
            axis=1)
        keep = (mf > 0) & (jnp.abs(wf) >= w_th)
        grown = (mf <= 0) & (jnp.abs(gf) >= g_th) & (jnp.abs(gf) > 0)
        new_m = keep | grown
        new_w = (wf * keep).astype(w.dtype).reshape(w.shape)
        return new_m.astype(m.dtype).reshape(m.shape), new_w

    paired = jax.tree.map(one, params, grads, masks)
    is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
    new_masks = jax.tree.map(lambda t: t[0], paired, is_leaf=is_pair)
    new_params = jax.tree.map(lambda t: t[1], paired, is_leaf=is_pair)
    return new_masks, new_params


# ---------------------------------------------------------------------------
# Stacked packed payloads
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StackedPacked:
    """K clients' packed messages for one leaf, in stacked form.

    ``bitmap`` is (K, n_words) uint32; ``values`` is (K, max_nnz) with each
    client's held values left-aligned and zero right-padding; ``nnz`` is
    the (K,) true counts.  ``shape`` is the *per-client* dense leaf shape
    (static aux data)."""

    bitmap: jax.Array
    values: jax.Array
    nnz: jax.Array
    shape: tuple[int, ...]

    @property
    def n_clients(self) -> int:
        return int(self.bitmap.shape[0])

    @property
    def n_coords(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def tree_flatten(self):
        return (self.bitmap, self.values, self.nnz), (tuple(self.shape),)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bitmap, values, nnz = children
        return cls(bitmap=bitmap, values=values, nnz=nnz, shape=aux[0])


def _is_stacked_packed(x) -> bool:
    return isinstance(x, StackedPacked)


def pack_stacked(stacked_params: PyTree, stacked_masks: Optional[PyTree] = None,
                 dtype=None) -> PyTree:
    """Pack a stacked (K-leading) state into ``StackedPacked`` leaves.

    Eager, data-dependent-shape (message-boundary) work — the stacked
    analogue of ``sparse.pack_tree``; ``masks=None`` packs dense (all-ones
    bitmaps, max_nnz = n_coords)."""

    def one(w, m):
        w = np.asarray(w)
        kdim = w.shape[0]
        shape = tuple(w.shape[1:])
        flat = w.reshape(kdim, -1)
        if m is None:
            flags = np.ones(flat.shape, dtype=bool)
        else:
            flags = np.asarray(m).reshape(kdim, -1) != 0
        nnz = flags.sum(axis=1).astype(np.int32)
        width = int(nnz.max()) if kdim else 0
        vals = np.zeros((kdim, width),
                        dtype=flat.dtype if dtype is None else dtype)
        words = np.zeros((kdim, n_words(flat.shape[1])), dtype=np.uint32)
        for k in range(kdim):
            held = flat[k][flags[k]]
            vals[k, : nnz[k]] = held if dtype is None else held.astype(dtype)
            words[k] = _pack_bits(flags[k])
        return StackedPacked(bitmap=jnp.asarray(words),
                             values=jnp.asarray(vals),
                             nnz=jnp.asarray(nnz), shape=shape)

    if stacked_masks is None:
        return jax.tree.map(lambda w: one(w, None), stacked_params)
    return jax.tree.map(one, stacked_params, stacked_masks)


def unpack_stacked(packed: PyTree) -> PyTree:
    """Dense stacked state from ``StackedPacked`` leaves (exact zeros off
    the bitmaps — ``unpack_stacked(pack_stacked(w, m)) == w ⊙ m``)."""

    def one(sp: StackedPacked):
        kdim = sp.n_clients
        out = np.zeros((kdim, sp.n_coords),
                       dtype=np.asarray(sp.values).dtype)
        words = np.asarray(sp.bitmap)
        vals = np.asarray(sp.values)
        nnz = np.asarray(sp.nnz)
        for k in range(kdim):
            flags = _unpack_bits(words[k], sp.n_coords)
            out[k, flags] = vals[k, : nnz[k]]
        return jnp.asarray(out.reshape((kdim,) + sp.shape))

    return jax.tree.map(one, packed, is_leaf=_is_stacked_packed)


def split_stacked(packed: PyTree) -> list[PyTree]:
    """The K individual ``PackedSparse`` trees of a stacked payload — what
    physically crosses a link (codec-framable, padding stripped)."""
    leaves = jax.tree.leaves(packed, is_leaf=_is_stacked_packed)
    if not leaves:
        return []
    kdim = leaves[0].n_clients

    def one_client(k):
        return jax.tree.map(
            lambda sp: PackedSparse(
                bitmap=sp.bitmap[k],
                values=sp.values[k, : int(sp.nnz[k])],
                shape=sp.shape),
            packed, is_leaf=_is_stacked_packed)

    return [one_client(k) for k in range(kdim)]


def stack_payloads(payloads: Sequence[PyTree]) -> PyTree:
    """Inverse of ``split_stacked``: K ``PackedSparse`` trees (identical
    structure/shapes, possibly ragged nnz) into one ``StackedPacked``."""

    def one(*leaves: PackedSparse):
        nnz = np.asarray([p.nnz for p in leaves], dtype=np.int32)
        width = int(nnz.max()) if leaves else 0
        vals = np.zeros((len(leaves), width),
                        dtype=np.asarray(leaves[0].values).dtype)
        for k, p in enumerate(leaves):
            vals[k, : nnz[k]] = np.asarray(p.values)
        return StackedPacked(
            bitmap=jnp.stack([p.bitmap for p in leaves]),
            values=jnp.asarray(vals), nnz=jnp.asarray(nnz),
            shape=leaves[0].shape)

    return jax.tree.map(one, *payloads, is_leaf=_is_packed)


def _fold_rows_pallas(nu: jax.Array, de: jax.Array, sp: StackedPacked,
                      alpha: float) -> tuple[jax.Array, jax.Array]:
    """One-launch stacked fold via ``kernels.packed_accum.packed_accum_rows``
    (grid = clients x coordinate blocks)."""
    from repro.kernels.packed_accum import BLOCK_N, packed_accum_rows

    kdim = sp.n_clients
    n = sp.n_coords
    pad = (-n) % BLOCK_N
    n_pad = n + pad
    words = np.zeros((kdim, n_pad // 32), dtype=np.uint32)
    words[:, : n_words(n)] = np.asarray(sp.bitmap)
    vals_in = np.asarray(sp.values)
    vals = np.zeros((kdim, vals_in.shape[1] + BLOCK_N), dtype=vals_in.dtype)
    vals[:, : vals_in.shape[1]] = vals_in
    # per-client exclusive prefixes of per-block popcounts (host, tiny)
    offsets = np.zeros((kdim, n_pad // BLOCK_N), dtype=np.int32)
    for k in range(kdim):
        pc = _unpack_bits(words[k], n_pad).reshape(-1, BLOCK_N).sum(axis=1)
        offsets[k] = np.concatenate([[0], np.cumsum(pc)[:-1]])
    shape = (kdim,) + sp.shape
    numf = jnp.pad(nu.reshape(kdim, -1).astype(jnp.float32), ((0, 0), (0, pad)))
    denf = jnp.pad(de.reshape(kdim, -1).astype(jnp.float32), ((0, 0), (0, pad)))
    num2, den2 = packed_accum_rows(
        numf, denf, jnp.asarray(words), jnp.asarray(vals),
        jnp.asarray(offsets), jnp.float32(alpha))
    return (num2[:, :n].reshape(shape).astype(nu.dtype),
            den2[:, :n].reshape(shape).astype(de.dtype))


def fold_stacked(num: PyTree, den: PyTree, packed: PyTree, alpha: float = 1.0,
                 backend: str = "ref") -> tuple[PyTree, PyTree]:
    """Fold a stacked payload into stacked (num, den) accumulators —
    client k's payload into accumulator row k.  Backends: ``"ref"`` /
    ``"pallas"`` loop clients through the same per-payload
    ``repro.sparse.ops.accumulate`` fold the per-client mix uses;
    ``"pallas_rows"`` launches the batched ``packed_accum_rows`` kernel
    once per leaf (grid = clients x blocks)."""
    from repro.sparse.ops import accumulate

    def one(nu, de, sp: StackedPacked):
        if backend == "pallas_rows":
            return _fold_rows_pallas(nu, de, sp, alpha)
        rows_n, rows_d = [], []
        for k in range(sp.n_clients):
            ps = PackedSparse(bitmap=sp.bitmap[k],
                              values=sp.values[k, : int(sp.nnz[k])],
                              shape=sp.shape)
            rn, rd = accumulate(nu[k], de[k], ps, alpha, backend)
            rows_n.append(rn)
            rows_d.append(rd)
        return jnp.stack(rows_n), jnp.stack(rows_d)

    paired = jax.tree.map(one, num, den, packed,
                          is_leaf=lambda x: _is_stacked_packed(x))
    is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
    new_num = jax.tree.map(lambda t: t[0], paired, is_leaf=is_pair)
    new_den = jax.tree.map(lambda t: t[1], paired, is_leaf=is_pair)
    return new_num, new_den


def stacked_nnz_per_client(stacked_masks: PyTree) -> list[int]:
    """Per-client nnz of a stacked mask tree (the comm-accounting input)."""
    total = None
    for leaf in jax.tree.leaves(stacked_masks):
        kdim = leaf.shape[0]
        counts = np.asarray(
            jnp.sum(jnp.reshape(leaf != 0, (kdim, -1)), axis=1))
        total = counts if total is None else total + counts
    return [int(c) for c in total]
