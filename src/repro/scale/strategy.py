"""StackedStrategy — the adapter protocol that runs a registered Strategy
as one client-stacked SPMD program.

An adapter wraps an *existing* ``StrategyBase`` instance (the hook class the
loop engine, the vmap fast path and the network simulator all drive) and
re-expresses its round phases over stacked (K-leading) state:

    stacked_init(task, clients, cfg)   -> stacked state (via the base's own
                                          init_state, then tree_stack — so
                                          round-0 state is bit-identical)
    mix_matrix(ctx)                    -> (K, K) host matrix for the fold
                                          (adjacency gate / Metropolis W)
    stacked_mix(state, mix)            -> traced communication phase
    stacked_evolve(state, grads, counts) -> traced mask search (optional)
    evolve_counts(ctx)                 -> host per-round traced count inputs
                                          (so schedules never recompile)

plus ``round_comm``/``round_flops`` (delegating to the base strategy's
accounting) and ``eval_params``/``unstack_state`` for evaluation and
checkpoint interop.  ``ScaleEngine`` composes these into a single jitted
round step: mix -> local phase -> evolve.

Adapters are looked up by the *registered* strategy name
(``@register_stacked("dispfl")``); ``make_stacked(strategy)`` raises with
the supported list for strategies that have no stacked form yet.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.core.accounting import decentralized_comm
from repro.fl.engine import RoundCtx, StrategyBase
from repro.scale.stacked import (
    evolve_counts_for,
    masked_gossip_stacked,
    plain_mix_stacked,
    stacked_evolve_exact,
    stacked_nnz_per_client,
)
from repro.utils.tree import tree_stack, tree_unstack

PyTree = Any

_STACKED_REGISTRY: dict[str, type] = {}


def register_stacked(*names: str):
    """Class decorator: map registered strategy names to their adapter."""

    def deco(cls):
        for name in names:
            _STACKED_REGISTRY[name] = cls
        return cls

    return deco


def stacked_strategy_names() -> list[str]:
    return sorted(_STACKED_REGISTRY)


def make_stacked(strategy: StrategyBase,
                 reduction: str = "einsum") -> "StackedStrategyBase":
    """Adapter for an already-constructed strategy instance."""
    cls = _STACKED_REGISTRY.get(strategy.name)
    if cls is None:
        raise KeyError(
            f"strategy '{strategy.name}' has no stacked adapter; "
            f"supported: {stacked_strategy_names()}")
    return cls(strategy, reduction=reduction)


class StackedStrategyBase:
    """Default adapter plumbing; subclasses fill in the traced phases."""

    #: state keys that carry per-client lists in the base strategy's state
    state_keys: tuple[str, ...] = ("params",)
    #: whether the strategy runs a post-local mask search
    evolves: bool = False

    def __init__(self, base: StrategyBase, reduction: str = "einsum"):
        self.base = base
        self.reduction = reduction

    @property
    def name(self) -> str:
        return self.base.name

    # -- lifecycle ---------------------------------------------------------
    def validate(self, cfg) -> None:
        """Reject configurations the stacked program cannot express."""
        if cfg.capacities is not None:
            raise ValueError(
                "ScaleEngine requires homogeneous client densities "
                "(cfg.capacities=None); heterogeneous capacities imply "
                "per-client layer budgets, which the stacked evolve cannot "
                "batch — use RoundEngine")

    def stacked_init(self, task, clients, cfg) -> dict:
        """Init through the base strategy (bit-identical round-0 state),
        then stack the per-client lists."""
        state = self.base.init_state(task, clients, cfg)
        return self.stack_state(state)

    def stack_state(self, state: dict) -> dict:
        """Per-client lists (``state_keys``) -> stacked trees; any other
        state entries pass through untouched."""
        return {k: tree_stack(v) if k in self.state_keys else v
                for k, v in state.items()}

    def unstack_state(self, state: dict) -> dict:
        kdim = len(self.base.clients)
        return {k: tree_unstack(v, kdim) if k in self.state_keys else v
                for k, v in state.items()}

    # -- traced phases -----------------------------------------------------
    def mix_matrix(self, ctx: RoundCtx) -> np.ndarray:
        raise NotImplementedError

    def stacked_mix(self, state: dict, mix: jax.Array) -> dict:
        raise NotImplementedError

    def stacked_masks(self, state: dict) -> Optional[PyTree]:
        """Stacked masks for the local phase (None = unmasked SGD)."""
        return None

    def stacked_evolve(self, state: dict, grads: PyTree,
                       counts: dict) -> dict:
        return state

    def evolve_counts(self, ctx: RoundCtx) -> dict:
        return {}

    # -- evaluation / accounting ------------------------------------------
    def eval_params(self, state: dict) -> list[PyTree]:
        return tree_unstack(state["params"], len(self.base.clients))

    def stacked_eval_params(self, state: dict) -> PyTree:
        """Client-stacked personalized params for the vmapped eval path —
        same models as ``eval_params``, without the host-side unstack."""
        return state["params"]

    def round_comm(self, state: dict, ctx: RoundCtx):
        raise NotImplementedError

    def round_flops(self, ctx: RoundCtx):
        # the zoo's round_flops are pure functions of (cfg, task, round)
        return self.base.round_flops({}, ctx)


@register_stacked("dispfl", "dispfl_anneal")
class StackedDisPFL(StackedStrategyBase):
    """DisPFL (and its sparse-to-sparser anneal variant) in stacked form:
    intersection gossip as the adjacency-weighted masked fold, masked local
    SGD, exact batched prune/regrow with per-round traced counts (the
    anneal schedule changes only the counts, never the program)."""

    state_keys = ("params", "masks")
    evolves = True

    def validate(self, cfg) -> None:
        super().validate(cfg)
        if getattr(self.base, "payload_dtype", "fp32") != "fp32":
            raise ValueError(
                "ScaleEngine's stacked mix computes on dense fp32 state and "
                "never crosses a message boundary, so payload_dtype='fp16' "
                "would silently have no effect — use RoundEngine/SimEngine "
                "for half-precision wire payloads")

    def mix_matrix(self, ctx: RoundCtx) -> np.ndarray:
        return np.asarray(ctx.adjacency, dtype=np.float32)

    def stacked_mix(self, state: dict, mix: jax.Array) -> dict:
        params = masked_gossip_stacked(state["params"], state["masks"], mix,
                                       reduction=self.reduction)
        return {**state, "params": params}

    def stacked_masks(self, state: dict) -> PyTree:
        return state["masks"]

    def stacked_evolve(self, state: dict, grads: PyTree,
                       counts: dict) -> dict:
        masks, params = stacked_evolve_exact(state["params"], state["masks"],
                                             grads, counts)
        return {"params": params, "masks": masks}

    def evolve_counts(self, ctx: RoundCtx) -> dict:
        base = self.base
        if hasattr(base, "_budgets_at"):          # dispfl_anneal
            budgets = base._budgets_at(ctx.t, 0)
        else:
            budgets = base.budgets[0]
        return evolve_counts_for(budgets, ctx.prune_rate)

    def round_comm(self, state: dict, ctx: RoundCtx):
        nnz = stacked_nnz_per_client(state["masks"])
        return decentralized_comm(ctx.adjacency, nnz, self.base.n_coords)


@register_stacked("dpsgd", "dpsgd_ft")
class StackedDPSGD(StackedStrategyBase):
    """D-PSGD in stacked form: Metropolis mixing as the row-stochastic fold
    over K, unmasked local SGD, no mask search.  (``dpsgd_ft`` maps here so
    it fails with a precise unsupported-variant error rather than a generic
    registry miss.)"""

    def validate(self, cfg) -> None:
        super().validate(cfg)
        if getattr(self.base, "param_fraction", 1.0) < 1.0:
            raise ValueError(
                "stacked dpsgd supports param_fraction=1.0 only (the shared "
                "static-mask baseline stays on RoundEngine)")
        if getattr(self.base, "finetune", False):
            raise ValueError(
                "stacked dpsgd does not implement the -FT eval variant; "
                "use RoundEngine for dpsgd_ft")

    def mix_matrix(self, ctx: RoundCtx) -> np.ndarray:
        from repro.fl.decentralized import metropolis_weights

        return metropolis_weights(ctx.adjacency).astype(np.float32)

    def stacked_mix(self, state: dict, mix: jax.Array) -> dict:
        return {**state,
                "params": plain_mix_stacked(state["params"], mix,
                                            reduction=self.reduction)}

    def round_comm(self, state: dict, ctx: RoundCtx):
        n = len(self.base.clients)
        return decentralized_comm(ctx.adjacency,
                                  [self.base.n_coords] * n,
                                  self.base.n_coords)
