"""repro.scale — the client-sharded SPMD round engine.

DisPFL's pitch is that decentralized sparse training stays cheap as the
client count grows; this package is the execution layer that makes the
*simulation* scale the same way.  Where ``repro.fl.engine.RoundEngine``
walks clients in Python (vmap covers only the local phase), ``ScaleEngine``
compiles the entire round — gossip mix, local SGD, mask evolution — into
ONE jitted program over client-stacked state, and shards the leading K dim
over a device mesh's client axes (hundreds–thousands of clients per round;
GSPMD emits the gossip collectives).

The StackedStrategy contract
----------------------------
A strategy joins the scale path by registering an adapter
(``scale.strategy.register_stacked``) that wraps its ordinary
``StrategyBase`` hooks:

    class MyStacked(StackedStrategyBase):
        state_keys = ("params", ...)        # per-client lists to stack
        evolves = True/False                # post-local mask search?

        def mix_matrix(self, ctx): ...      # host: (K, K) fold matrix
        def stacked_mix(self, state, mix):  # traced: communication phase
        def stacked_masks(self, state):     # masks for the local phase
        def stacked_evolve(self, state, grads, counts):  # traced search
        def evolve_counts(self, ctx): ...   # host: per-round traced counts
        def round_comm(self, state, ctx):   # accounting on stacked state

``stacked_init`` (inherited) builds round-0 state through the base
strategy's own ``init_state`` and stacks it, so the stacked program starts
from bit-identical state; ``evolve_counts`` routes *schedule* changes
(cosine prune rate, dispfl_anneal's shrinking ERK budgets) through traced
scalars, so the program compiles once for a whole run.  Built-in adapters:
``dispfl``, ``dispfl_anneal``, ``dpsgd``.

Fidelity
--------
``reduction="ordered"`` reproduces the reference engine's accumulation
order — the trajectory (params, masks, metrics) is bit-identical to
``RoundEngine(local_exec="loop")``, pinned by tests/test_scale_engine.py.
``reduction="einsum"`` (default) is the SPMD matmul fold: values agree to
fp-reduction-order tolerance, masks and rng draws stay identical-by-
construction round for round only as long as value drift never crosses a
top-k tie (asserted at the golden suite's scale).  Checkpoints are written
in the engine's per-client list layout, so ScaleEngine and RoundEngine
archives are interchangeable.

Entry points: ``ScaleEngine``; ``launch/train.py --scale [--mesh-shape]``;
``benchmarks/scale_engine.py`` (rounds/s + bytes vs K, gated);
``examples/scale_mesh.py`` (K=256 on forced host devices).
"""
from repro.scale.engine import ScaleEngine  # noqa: F401
from repro.scale.stacked import (  # noqa: F401
    StackedPacked,
    fold_stacked,
    masked_gossip_stacked,
    pack_stacked,
    plain_mix_stacked,
    split_stacked,
    stack_payloads,
    stacked_evolve_exact,
    stacked_local_phase,
    stacked_nnz_per_client,
    stacked_prune_regrow_threshold,
    unpack_stacked,
)
from repro.scale.strategy import (  # noqa: F401
    StackedStrategyBase,
    make_stacked,
    register_stacked,
    stacked_strategy_names,
)
