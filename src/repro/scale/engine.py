"""``ScaleEngine`` — the client-sharded SPMD round engine.

One ``RoundEngine`` subclass whose entire round — gossip mix, local SGD
phase, mask evolution — is a single jitted program over client-stacked
state.  The Python-per-client work of the reference engine (its loop *and*
its vmap fast path still mix/evolve eagerly per client) collapses into one
XLA dispatch per round, and under a device mesh the leading K dim is
sharded over the client axes (``sharding.rules.tree_stacked_shardings``) so
GSPMD emits the gossip collectives — the K=256-clients-per-round regime.

Semantics contract (the golden suite in tests/test_scale_engine.py):

* round-0 state is bit-identical to ``RoundEngine`` (the adapter inits
  through the base strategy's own ``init_state``);
* all randomness (batch orders, evolve batches, topology) derives from the
  same ``(seed, round, client)`` streams in the same draw order, so a
  ``ScaleEngine`` checkpoint resumes bit-identically — and interchangeably
  with ``RoundEngine`` (checkpoints are written in the engine's per-client
  list layout);
* with ``reduction="ordered"`` the gossip fold reproduces the reference
  accumulation order and the whole trajectory — params, masks, metrics —
  is bit-identical to ``RoundEngine(local_exec="loop")``;
* with ``reduction="einsum"`` (the default: the SPMD matmul form) values
  agree to fp reduction-order tolerance (~1e-6 relative per round) and the
  documented golden criterion is: masks identical, per-round metrics within
  tolerance.

Constraints (checked at construction, with pointers back to RoundEngine):
homogeneous client densities, all clients sharing one effective batch size
(ragged step counts are fine — padded and live-masked exactly like the
vmap fast path), and a strategy with a registered ``StackedStrategy``
adapter (``dispfl``, ``dispfl_anneal``, ``dpsgd``).
"""
from __future__ import annotations

import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.base import (
    FLResult,
    Task,
    _pad_order,
    evaluate_clients_stacked,
    rounds_to_targets,
    stack_eval_arrays,
)
from repro.fl.engine import Callback, RoundCtx, RoundEngine, RoundMetrics, StrategyBase
from repro.core.accounting import CommReport, FlopsReport
from repro.models.common import softmax_xent
from repro.obs import (
    CounterSet,
    SeriesSet,
    install_jax_hooks,
    jax_compile_count,
    span,
)
from repro.optim import SGDConfig
from repro.scale.stacked import (
    pack_stacked,
    split_stacked,
    stacked_local_phase,
)
from repro.scale.strategy import make_stacked

PyTree = Any


class ScaleEngine(RoundEngine):
    """Runs a Strategy-zoo member as one compiled stacked round program.

    Usage::

        engine = ScaleEngine(make_strategy("dispfl"), task, clients, cfg,
                             mesh=make_test_mesh(4, 1))   # or mesh=None
        for m in engine.rounds():
            ...
        result = engine.result()

    ``mesh=None`` runs the same single program on one device (still one
    dispatch per round); with a mesh the stacked state and batches are
    sharded over the client axes.  ``reduction`` picks the gossip fold:
    ``"einsum"`` (SPMD matmul, default) or ``"ordered"`` (bit-exact
    reference accumulation order).
    """

    def __init__(self, strategy: StrategyBase, task: Task, clients,
                 cfg, callbacks: Sequence[Callback] = (),
                 mesh=None, reduction: str = "einsum"):
        # the base class wires strategy/task/clients/cfg and builds the
        # per-client list state via the strategy's own init_state — the
        # adapter then stacks it, so round-0 state matches RoundEngine
        # bit for bit
        super().__init__(strategy, task, clients, cfg, callbacks=callbacks,
                         local_exec="loop")
        self.adapter = make_stacked(strategy, reduction=reduction)
        self.adapter.validate(cfg)
        self.mesh = mesh
        self._validate_clients()
        self.state = self.adapter.stack_state(self.state)
        self._opt = SGDConfig(momentum=cfg.momentum,
                              weight_decay=cfg.weight_decay)
        self._round_step = None
        self._eval_arrays = None
        # compile-vs-execute observability: the jax.monitoring bridge makes
        # "traced scalars never recompile" an assertable counter — one
        # backend compile on the first step, zero after, whatever the
        # lr/prune schedule does (tests/test_obs.py pins this)
        install_jax_hooks()
        self.scale_obs = CounterSet("scale.engine")
        self._c_step_calls = self.scale_obs.counter("step_calls")
        self._c_step_compiles = self.scale_obs.counter("step_compiles")
        # cumulative step/compile series on the wall clock (counter-kind:
        # the deltas reconcile against the counters above); not
        # checkpointed — a resumed run restarts its series
        self.scale_series = SeriesSet("scale.engine")

    # ------------------------------------------------------------------
    # construction-time checks
    # ------------------------------------------------------------------
    def _validate_clients(self) -> None:
        cfg = self.cfg
        bss = {min(cfg.batch_size, c.n_train) for c in self.clients}
        if len(bss) != 1:
            raise ValueError(
                "ScaleEngine requires all clients to share one effective "
                f"batch size (min(batch_size, n_train)); got {sorted(bss)} "
                "— ragged *step counts* are fine (padded + masked), ragged "
                "batch shapes are not; use RoundEngine")

    # ------------------------------------------------------------------
    # the compiled round step
    # ------------------------------------------------------------------
    def _build_round_step(self):
        adapter = self.adapter
        apply_fn = self.task.apply_fn
        opt = self._opt
        evolves = adapter.evolves

        def loss(p, x, y):
            return softmax_xent(apply_fn(p, x), y)

        grad = jax.grad(loss)

        def round_step(state, mix, bx, by, live, ev_x, ev_y, lr, counts):
            state = adapter.stacked_mix(state, mix)
            params = stacked_local_phase(
                apply_fn, opt, state["params"], adapter.stacked_masks(state),
                bx, by, live, lr)
            state = {**state, "params": params}
            if evolves:
                grads = jax.vmap(grad)(params, ev_x, ev_y)
                state = adapter.stacked_evolve(state, grads, counts)
            return state

        if self.mesh is None:
            return jax.jit(round_step)

        from jax.sharding import NamedSharding

        from repro.sharding import use_mesh_rules
        from repro.sharding.rules import stacked_spec, tree_stacked_shardings

        mesh = self.mesh
        state_sh = tree_stacked_shardings(self.state, mesh)

        def shard_stacked(x):
            # batches/live carry the same leading K dim as the state; pin
            # them to the client axes so GSPMD keeps the whole round local
            # to each client shard (modulo the gossip collectives)
            if x is None:
                return None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, stacked_spec(tuple(x.shape), mesh)))

        def sharded_step(state, mix, bx, by, live, ev_x, ev_y, lr, counts):
            return round_step(state, mix, shard_stacked(bx),
                              shard_stacked(by), shard_stacked(live),
                              shard_stacked(ev_x), shard_stacked(ev_y),
                              lr, counts)

        with use_mesh_rules(mesh):
            return jax.jit(
                sharded_step,
                in_shardings=(state_sh,) + (None,) * 8,
                out_shardings=state_sh,
            )

    def _step_fn(self):
        if self._round_step is None:
            self._round_step = self._build_round_step()
        return self._round_step

    @property
    def step_compiles(self) -> int:
        """Rounds whose step dispatch triggered a backend compile — the
        "traced scalars never recompile" invariant says this stays at 1."""
        return int(self._c_step_compiles.value)

    # ------------------------------------------------------------------
    # host-side per-round inputs (identical draws to the reference engine)
    # ------------------------------------------------------------------
    def _batch_schedule(self, ctx: RoundCtx):
        """Stacked padded batch schedule — the same permutations, padding
        and live-masking as ``RoundEngine._vmap_local_phase`` (and therefore
        the same draws as the per-client reference loop)."""
        cfg = self.cfg
        epochs = self.strategy.local_epochs({}, ctx)
        bs = min(cfg.batch_size, min(c.n_train for c in self.clients))
        orders = []
        for k in range(len(self.clients)):
            rng = ctx.client_rng(k)
            orders.append(np.concatenate(
                [_pad_order(self.clients[k].n_train, bs, rng)
                 for _ in range(epochs)]))
        s_max = max(len(o) // bs for o in orders)
        xb, yb, live = [], [], []
        for k, order in enumerate(orders):
            steps = len(order) // bs
            c = self.clients[k]
            padded = np.resize(order, s_max * bs)
            xb.append(c.train_x[padded].reshape(
                (s_max, bs) + c.train_x.shape[1:]))
            yb.append(c.train_y[padded].reshape(s_max, bs))
            live.append(np.arange(s_max) < steps)
        return (jnp.asarray(np.stack(xb)), jnp.asarray(np.stack(yb)),
                jnp.asarray(np.stack(live)))

    def _evolve_batches(self, ctx: RoundCtx):
        """The mask-search batches, drawn from the *same* per-client rng
        stream right after the local-phase orders — exactly the draw order
        of ``Strategy.evolve`` in the reference engine."""
        bs = self.cfg.batch_size
        xs, ys = [], []
        for k, c in enumerate(self.clients):
            xbk, ybk = c.sample_batch(ctx.client_rng(k), bs)
            xs.append(xbk)
            ys.append(ybk)
        return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))

    # ------------------------------------------------------------------
    # the round
    # ------------------------------------------------------------------
    def _run_one_round(self, t: int) -> RoundMetrics:
        cfg = self.cfg
        t0 = time.perf_counter()
        ctx = self._make_ctx(t)
        self._pre_round(ctx)

        bx, by, live = self._batch_schedule(ctx)
        if self.adapter.evolves:
            ev_x, ev_y = self._evolve_batches(ctx)
        else:
            ev_x = ev_y = None
        mix = jnp.asarray(self.adapter.mix_matrix(ctx))
        counts = self.adapter.evolve_counts(ctx)
        # snapshot the compile counter around the step dispatch only —
        # _stacked_eval below jit-compiles separately and must not pollute
        # the "the round step compiled" signal
        n_compiles = jax_compile_count()
        with span("scale.step", track="engine", round=t) as sp:
            self.state = self._step_fn()(
                self.state, mix, bx, by, live, ev_x, ev_y,
                jnp.float32(ctx.lr), counts)
            delta = jax_compile_count() - n_compiles
            sp.attrs["compiles"] = delta
        self._c_step_calls.inc()
        if delta > 0:
            self._c_step_compiles.inc()
        tw = time.perf_counter() - self._series_epoch
        self.scale_series.series("step_calls", kind="counter").observe(
            tw, float(self._c_step_calls.value))
        self.scale_series.series("step_compiles", kind="counter").observe(
            tw, float(self._c_step_compiles.value))

        comm = self.adapter.round_comm(self.state, ctx)
        flops = self.adapter.round_flops(ctx)
        for key in self._comm:
            self._comm[key].append(float(getattr(comm, key)))
        for key in self._flops:
            self._flops[key].append(float(getattr(flops, key)))

        acc_mean = acc_std = None
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            accs = self._stacked_eval()
            acc_mean = float(np.mean(accs))
            acc_std = float(np.std(accs))
            self._acc_history.append(acc_mean)
            self._acc_stds.append(acc_std)
            self._eval_rounds.append(t)

        self._next_round = t + 1
        metrics = RoundMetrics(
            round=t, lr=ctx.lr, prune_rate=ctx.prune_rate,
            comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
            flops_round=flops.per_round_flops,
            cum_flops=float(np.sum(self._flops["per_round_flops"])),
            acc_mean=acc_mean, acc_std=acc_std,
            wall_s=time.perf_counter() - t0)
        return self._finish_metrics(ctx, metrics)

    def _stacked_eval(self) -> list[float]:
        """Personalized eval without leaving the device: one vmapped
        launch over the client-stacked params (golden-equal to the
        per-client ``evaluate_clients`` loop)."""
        if self._eval_arrays is None:
            self._eval_arrays = stack_eval_arrays(self.clients)
        return evaluate_clients_stacked(
            self.task, self.adapter.stacked_eval_params(self.state),
            self.clients, arrays=self._eval_arrays)

    # ------------------------------------------------------------------
    # results / messages / checkpoints
    # ------------------------------------------------------------------
    def result(self, targets: Sequence[float] = (0.5,)) -> FLResult:
        final = self._stacked_eval()
        comm = CommReport(**{k: float(np.mean(v)) if v else 0.0
                             for k, v in self._comm.items()})
        flops = FlopsReport(**{k: float(np.mean(v)) if v else 0.0
                               for k, v in self._flops.items()})
        return FLResult(
            acc_history=list(self._acc_history),
            final_accs=final,
            comm_busiest_mb=comm.busiest_mb, comm_rows=comm.row(),
            flops_per_round=flops.per_round_flops, flops_rows=flops.row(),
            rounds_to=rounds_to_targets(self._acc_history, list(targets)))

    def snapshot_messages(self) -> list[dict]:
        """Per-client packed payloads of the current stacked state — what
        each client would put on the wire right now (codec-framable; dense
        strategies ride all-ones bitmaps), via the stacked packed
        container."""
        masks = self.adapter.stacked_masks(self.state)
        stacked = pack_stacked(self.state["params"], masks)
        return [{"packed": p} for p in split_stacked(stacked)]

    def _checkpoint_payload(self) -> dict:
        # write checkpoints in the engine's per-client list layout, so
        # ScaleEngine and RoundEngine archives are interchangeable
        stacked = self.state
        self.state = self.adapter.unstack_state(stacked)
        try:
            return super()._checkpoint_payload()
        finally:
            self.state = stacked

    def _restore_payload(self, payload: dict) -> None:
        super()._restore_payload(payload)
        self.state = self.adapter.stack_state(self.state)
