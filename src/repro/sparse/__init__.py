"""repro.sparse — packed sparse payloads, end to end.

DisPFL's communication claim is that a peer ships only ``nnz(mask)``
values per message.  This package makes that *physical* instead of
analytic: a message is a ``PackedSparse`` tree (uint32 mask bitmap + the
contiguous held values), it is what strategies snapshot, what the network
simulator's links carry (sized by the codec, byte-exact), and what the
per-client mix computes on — the dense pytree never crosses a link.

Modules
-------
``packed``   ``PackedSparse`` container (registered jax pytree) +
             ``pack/unpack``/``pack_tree``/``unpack_tree``; bit-exact
             roundtrip ``unpack(pack(w, m)) == w ⊙ m``
``codec``    deterministic wire frames: 8-byte header + word-aligned
             bitmap over the concatenated coordinates + values;
             ``encoded_nbytes`` equals ``core.accounting.message_bytes(...,
             with_bitmap=True)`` exactly, so analytic and measured comm
             reports agree bit for bit
``ops``      packed gossip / axpy: fold payloads into (num, den)
             accumulators, O(degree) folds per activation (degree-not-K;
             see the module docstring for the honest cost model) — jnp
             reference backend plus the fused
             ``repro.kernels.packed_accum`` Pallas kernel

Consumers
---------
``repro.fl.engine.StrategyBase`` snapshots messages as packed trees and
exposes a per-client ``mix_one`` hook; ``repro.fl.dispfl`` /
``repro.fl.decentralized`` implement it with ``ops.packed_gossip_one`` /
``ops.packed_axpy``; ``repro.sim`` stamps every simulated transfer with
``codec.encoded_nbytes`` of the actual payload.  The density-annealing
strategy (``dispfl_anneal``) exercises variable-size payloads round over
round.  ``benchmarks/sparse_codec.py`` tracks pack/gossip throughput and
bytes-vs-density.
"""
from repro.sparse.codec import (  # noqa: F401
    TreeSpec,
    decode,
    decode_dense,
    encode,
    encoded_nbytes,
)
from repro.sparse.ops import (  # noqa: F401
    packed_axpy,
    packed_gossip_one,
)
from repro.sparse.packed import (  # noqa: F401
    PackedSparse,
    pack,
    pack_tree,
    tree_packed_coords,
    tree_packed_nnz,
    unpack,
    unpack_mask,
    unpack_mask_tree,
    unpack_tree,
)
