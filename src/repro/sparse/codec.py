"""Deterministic wire serialization for packed sparse messages.

Frame layout (little-endian throughout)::

    [magic u16][version u8][dtype u8][nnz u32]     8-byte header
    [bitmap: ceil(n_coords / 32) uint32 words]     mask over the
                                                   *concatenated* leaf
                                                   coordinate space
    [values: nnz * itemsize bytes]                 held values, leaf order

Both endpoints share the model architecture, so leaf shapes / dtypes /
tree structure travel once as a ``TreeSpec`` (negotiated out of band, like
a schema), never per message.  Leaf bit-streams are concatenated *without*
inter-leaf padding: the frame size is therefore an exact function of
``(nnz, n_coords, itemsize)``, which is what lets ``core.accounting`` quote
the same number analytically —

    encoded_nbytes(packed) == accounting.message_bytes(
        nnz, n_coords, with_bitmap=True, value_nbytes=itemsize)

bit for bit (asserted across every registered strategy in
``tests/test_sparse.py``).  ``repro.sim`` stamps each simulated transfer
with ``encoded_nbytes`` of the actual payload, so measured bytes-on-wire
and analytic reports stay commensurable by construction.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import HEADER_NBYTES, bitmap_nbytes
from repro.obs import CounterSet, span
from repro.sparse.packed import (
    PackedSparse,
    _is_packed,
    _pack_bits,
    _unpack_bits,
    n_words,
)

PyTree = Any

# wire-format observability: frame counts and exact byte totals, shared by
# every engine that touches the codec (ROADMAP's serialization-bottleneck
# claim becomes measurable per run: span timers + these byte counters)
OBS = CounterSet("sparse.codec")
_C_ENCODES = OBS.counter("encodes")
_C_BYTES_OUT = OBS.counter("bytes_out")
_C_DECODES = OBS.counter("decodes")
_C_DENSE_DECODES = OBS.counter("dense_decodes")
_C_BYTES_IN = OBS.counter("bytes_in")

MAGIC = 0x5350            # "SP"
VERSION = 1
_HEADER = struct.Struct("<HBBI")
assert _HEADER.size == HEADER_NBYTES

_DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.float16): 1}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """The out-of-band message schema: tree structure + leaf shapes/dtype."""

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtype: np.dtype

    @classmethod
    def from_tree(cls, tree: PyTree, dtype=np.float32) -> "TreeSpec":
        """Build from a template — dense params or an already-packed tree."""
        leaves = jax.tree.leaves(tree, is_leaf=_is_packed)
        if leaves and isinstance(leaves[0], PackedSparse):
            shapes = tuple(p.shape for p in leaves)
            dtype = np.asarray(leaves[0].values).dtype
            treedef = jax.tree.structure(tree, is_leaf=_is_packed)
        else:
            shapes = tuple(tuple(x.shape) for x in leaves)
            treedef = jax.tree.structure(tree)
        return cls(treedef=treedef, shapes=shapes, dtype=np.dtype(dtype))

    @property
    def n_coords(self) -> int:
        return sum(int(np.prod(s)) for s in self.shapes)


def _leaves(packed: PyTree) -> list[PackedSparse]:
    leaves = jax.tree.leaves(packed, is_leaf=_is_packed)
    for p in leaves:
        if not isinstance(p, PackedSparse):
            raise TypeError(f"expected a tree of PackedSparse, got {type(p)}")
    return leaves


def encoded_nbytes(packed: PyTree) -> int:
    """Exact frame size of ``encode(packed)`` — header + word-aligned
    bitmap over the concatenated coordinates + value bytes."""
    leaves = _leaves(packed)
    nnz = sum(p.nnz for p in leaves)
    n_coords = sum(p.n_coords for p in leaves)
    # metadata only — never materializes device values
    itemsize = np.dtype(leaves[0].values.dtype).itemsize if leaves else 4
    return HEADER_NBYTES + bitmap_nbytes(n_coords) + itemsize * nnz


def encode(packed: PyTree) -> bytes:
    """Serialize a packed tree to one wire frame (little-endian)."""
    with span("codec.encode", track="codec") as sp:
        leaves = _leaves(packed)
        dtype = np.asarray(leaves[0].values).dtype
        if dtype not in _DTYPE_CODES:
            raise ValueError(f"unsupported wire dtype {dtype}")
        if any(np.asarray(p.values).dtype != dtype for p in leaves):
            raise ValueError(
                "all leaves of one message must share a value dtype")
        # concatenate leaf bit-streams with no inter-leaf padding, repack
        flags = np.concatenate(
            [_unpack_bits(np.asarray(p.bitmap), p.n_coords) for p in leaves]
        ) if leaves else np.zeros(0, dtype=bool)
        words = _pack_bits(flags)
        values = (np.concatenate([np.asarray(p.values) for p in leaves])
                  if leaves else np.zeros(0, dtype))
        nnz = int(values.size)
        out = b"".join([
            _HEADER.pack(MAGIC, VERSION, _DTYPE_CODES[dtype], nnz),
            words.astype("<u4").tobytes(),
            values.astype(values.dtype.newbyteorder("<")).tobytes(),
        ])
        assert len(out) == encoded_nbytes(packed)
        sp.attrs["nbytes"] = len(out)
        _C_ENCODES.inc()
        _C_BYTES_OUT.inc(len(out))
    return out


def _frame_arrays(data: bytes, spec: TreeSpec):
    """Parse one frame's header and pull out (flags, values, nnz) as host
    arrays — the shared prelude of ``decode`` / ``decode_dense``."""
    magic, version, code, nnz = _HEADER.unpack_from(data, 0)
    if magic != MAGIC:
        raise ValueError(f"bad magic 0x{magic:04x}")
    if version != VERSION:
        raise ValueError(f"unsupported codec version {version}")
    dtype = _CODE_DTYPES[code]
    n_coords = spec.n_coords
    off = HEADER_NBYTES
    nb_bitmap = bitmap_nbytes(n_coords)
    words = np.frombuffer(data, dtype="<u4", count=n_words(n_coords),
                          offset=off).astype(np.uint32)
    values = np.frombuffer(data, dtype=np.dtype(dtype).newbyteorder("<"),
                           count=nnz, offset=off + nb_bitmap).astype(dtype)
    flags = _unpack_bits(words, n_coords)
    return flags, values, nnz


def decode(data: bytes, spec: TreeSpec) -> PyTree:
    """Rebuild the packed tree from one frame + its out-of-band schema."""
    with span("codec.decode", track="codec", nbytes=len(data)):
        _C_DECODES.inc()
        _C_BYTES_IN.inc(len(data))
        flags, values, nnz = _frame_arrays(data, spec)
        leaves, pos, vpos = [], 0, 0
        for shape in spec.shapes:
            n = int(np.prod(shape))
            leaf_flags = flags[pos:pos + n]
            k = int(leaf_flags.sum())
            leaves.append(PackedSparse(
                bitmap=jnp.asarray(_pack_bits(leaf_flags)),
                values=jnp.asarray(values[vpos:vpos + k]),
                shape=tuple(shape)))
            pos += n
            vpos += k
        if vpos != nnz:
            raise ValueError(
                f"frame carries {nnz} values, schema holds {vpos}")
        return jax.tree.unflatten(spec.treedef, leaves)


def decode_dense(data: bytes, spec: TreeSpec,
                 mask_dtype=np.float32) -> tuple[PyTree, PyTree]:
    """Decode one frame straight to dense host leaves: ``(params, masks)``
    numpy trees, bit-exact vs ``unpack_tree(decode(...))``.

    This is the serving hot path (a cache miss stands between a request
    and its launch): one bit-unpack pass over the whole frame, one scatter
    per leaf, and no intermediate ``PackedSparse`` / device round-trips —
    ``decode`` + ``unpack_tree`` + ``unpack_mask_tree`` does the bitmap
    work three times and bounces every leaf through the device.
    """
    with span("codec.decode_dense", track="codec", nbytes=len(data)):
        _C_DENSE_DECODES.inc()
        _C_BYTES_IN.inc(len(data))
        flags, values, nnz = _frame_arrays(data, spec)
        params, masks, pos, vpos = [], [], 0, 0
        for shape in spec.shapes:
            n = int(np.prod(shape))
            leaf_flags = flags[pos:pos + n]
            k = int(leaf_flags.sum())
            dense = np.zeros(n, dtype=values.dtype)
            dense[leaf_flags] = values[vpos:vpos + k]
            params.append(dense.reshape(shape))
            masks.append(leaf_flags.reshape(shape).astype(mask_dtype))
            pos += n
            vpos += k
        if vpos != nnz:
            raise ValueError(
                f"frame carries {nnz} values, schema holds {vpos}")
        return (jax.tree.unflatten(spec.treedef, params),
                jax.tree.unflatten(spec.treedef, masks))
