"""Packed compute: gossip / axpy over ``PackedSparse`` payloads.

These are the ops the mix hot path runs on received messages: a client
keeps ONE pair of dense accumulators (num, den) per leaf and folds each
arrived payload in as

    num += alpha * scatter(values at bitmap support)      # packed axpy
    den += bitmap                                         # intersection count

then finalizes with the intersection average (``core.gossip``'s exact
formula), so ``packed_gossip_one`` is bit-identical to
``core.gossip.gossip_average_one`` fed the equivalent dense neighbors —
the golden contract ``tests/test_sparse.py`` pins down.

Cost model, stated honestly: per activation the work is O(degree) payload
folds — O(degree · nnz) value traffic plus one dense accumulator pass per
fold (the fused kernel's HBM round-trip) — versus the generic fallback's
O(K) full-tree mix.  It scales with node degree, never with the number of
clients; the *wire* is strictly O(nnz).

Two backends:

* ``"ref"`` (default) — eager numpy/jnp expansion, the oracle and the fast
  path on this CPU-only container,
* ``"pallas"`` — the fused ``repro.kernels.packed_accum`` kernel
  (interpret-mode here; written for the TPU lowering), accumulating in
  place block by block.

``COUNTERS`` tracks accumulate work (calls / values touched) so tests can
assert the O(degree · nnz) — not O(K · model) — scaling of the per-client
mix (``Strategy.mix_one``).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gossip import _intersection_avg
from repro.obs import CounterSet
from repro.sparse.packed import (
    PackedSparse,
    _unpack_bits,
    n_words,
    unpack,
    unpack_mask,
)

PyTree = Any

#: accumulate instrumentation: calls == payload-leaf folds performed,
#: values == nnz actually touched (reset with ``reset_counters``)
COUNTERS = {"accum_calls": 0, "accum_values": 0}

# mirror the dict into the process-wide registry (dict stays the API the
# scaling tests use; the gauges read it live, so snapshots never drift)
OBS = CounterSet("sparse.ops")
OBS.gauge("accum_calls", fn=lambda: COUNTERS["accum_calls"])
OBS.gauge("accum_values", fn=lambda: COUNTERS["accum_values"])


def reset_counters() -> None:
    COUNTERS["accum_calls"] = 0
    COUNTERS["accum_values"] = 0


def _accumulate_ref(num: jax.Array, den: jax.Array, ps: PackedSparse,
                    alpha: float) -> tuple[jax.Array, jax.Array]:
    up = unpack(ps).astype(num.dtype)
    m = unpack_mask(ps, den.dtype)
    # alpha == 1.0 folds with a bare add, matching the dense gossip loop's
    # ``num + w_j * m_j`` bit for bit
    num = num + up if alpha == 1.0 else num + alpha * up
    return num, den + m


def _accumulate_pallas(num: jax.Array, den: jax.Array, ps: PackedSparse,
                       alpha: float) -> tuple[jax.Array, jax.Array]:
    from repro.kernels.packed_accum import BLOCK_N, packed_accum_flat

    shape = num.shape
    n = ps.n_coords
    pad = (-n) % BLOCK_N
    n_pad = n + pad
    words = np.zeros(n_pad // 32, dtype=np.uint32)
    words[: n_words(n)] = np.asarray(ps.bitmap)
    vals = np.asarray(ps.values)
    vals = np.concatenate([vals, np.zeros(BLOCK_N, dtype=vals.dtype)])
    # exclusive prefix of per-block popcounts (host side, tiny)
    pc = _unpack_bits(words, n_pad).reshape(-1, BLOCK_N).sum(axis=1)
    offsets = np.concatenate([[0], np.cumsum(pc)[:-1]]).astype(np.int32)
    numf = jnp.pad(num.reshape(-1).astype(jnp.float32), (0, pad))
    denf = jnp.pad(den.reshape(-1).astype(jnp.float32), (0, pad))
    num2, den2 = packed_accum_flat(
        numf, denf, jnp.asarray(words), jnp.asarray(vals),
        jnp.asarray(offsets), jnp.float32(alpha))
    return (num2[:n].reshape(shape).astype(num.dtype),
            den2[:n].reshape(shape).astype(den.dtype))


def accumulate(num: jax.Array, den: jax.Array, ps: PackedSparse,
               alpha: float = 1.0, backend: str = "ref"):
    """Fold one packed leaf into dense (num, den) accumulators."""
    COUNTERS["accum_calls"] += 1
    COUNTERS["accum_values"] += ps.nnz
    if backend == "pallas":
        return _accumulate_pallas(num, den, ps, alpha)
    return _accumulate_ref(num, den, ps, alpha)


def packed_gossip_one(own_params: PyTree, own_mask: PyTree,
                      neighbor_packed: Sequence[PyTree],
                      backend: str = "ref") -> PyTree:
    """Intersection-weighted gossip for ONE client from packed neighbor
    payloads (paper Alg. 1 line 7) — O(degree · nnz) work, bit-identical to
    ``gossip_average_one`` on the densified neighbors."""

    def one(w, m, *packs):
        mf = m.astype(w.dtype)
        num = w * mf
        den = mf
        for p in packs:
            num, den = accumulate(num, den, p, 1.0, backend)
        return _intersection_avg(num, den, mf)

    return jax.tree.map(one, own_params, own_mask, *neighbor_packed)


def packed_axpy(acc: PyTree, packed: PyTree, alpha: float,
                backend: str = "ref") -> PyTree:
    """acc + alpha * densify(packed), leafwise, without materializing the
    densified payload outside the fused accumulate."""

    def one(a, p):
        COUNTERS["accum_calls"] += 1
        COUNTERS["accum_values"] += p.nnz
        if backend == "pallas":
            num, _ = _accumulate_pallas(a, jnp.zeros_like(a), p, alpha)
            return num
        return a + alpha * unpack(p).astype(a.dtype)

    return jax.tree.map(one, acc, packed)
