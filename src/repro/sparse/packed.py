"""``PackedSparse`` — the physical form of a DisPFL message.

One sparsifiable leaf travels as two arrays instead of a dense tensor:

* ``bitmap`` — the {0,1} mask packed 32 coordinates per ``uint32`` word
  (little-endian bit order: bit ``i % 32`` of word ``i // 32`` is
  coordinate ``i`` of the flattened leaf),
* ``values`` — the ``nnz`` held values, contiguous, in coordinate order
  (fp32 by default; fp16 supported for half-precision payloads).

``unpack(pack(w, m)) == w ⊙ m`` exactly (values are gathered, never
re-quantized), which is what makes the packed gossip path bit-identical to
the dense reference.  ``PackedSparse`` is registered as a jax pytree so
packed trees flow through ``jax.tree.map`` / the engine's payload plumbing
like any other state.

Packing is an eager (data-dependent-shape) operation: it happens at message
boundaries, outside jit.  The compute-side consumers are in
``repro.sparse.ops`` (fused expand/accumulate, with a Pallas kernel).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import CounterSet, span

PyTree = Any

BITS_PER_WORD = 32

# message-boundary observability (pack/unpack happen per gossip payload)
OBS = CounterSet("sparse.packed")
_C_PACKS = OBS.counter("tree_packs")
_C_UNPACKS = OBS.counter("tree_unpacks")


def n_words(n_coords: int) -> int:
    """uint32 words needed to hold a bitmap over ``n_coords`` coordinates."""
    return (n_coords + BITS_PER_WORD - 1) // BITS_PER_WORD


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedSparse:
    """One packed leaf: bitmap words + contiguous nnz values.

    ``shape`` is the dense leaf shape (static aux data, so jit/vmap see it
    as structure, not as a traced value).
    """

    bitmap: jax.Array          # (n_words,) uint32
    values: jax.Array          # (nnz,) fp32 or fp16
    shape: tuple[int, ...]

    @property
    def n_coords(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    @property
    def dtype(self):
        return self.values.dtype

    def tree_flatten(self):
        return (self.bitmap, self.values), (tuple(self.shape),)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bitmap, values = children
        return cls(bitmap=bitmap, values=values, shape=aux[0])


def _pack_bits(flags: np.ndarray) -> np.ndarray:
    """Bool (n,) -> uint32 words (n_words,), little-endian bit order."""
    flags = np.asarray(flags, dtype=bool).reshape(-1)
    pad = (-flags.size) % BITS_PER_WORD
    if pad:
        flags = np.concatenate([flags, np.zeros(pad, dtype=bool)])
    words = flags.reshape(-1, BITS_PER_WORD).astype(np.uint32)
    shifts = np.arange(BITS_PER_WORD, dtype=np.uint32)
    return (words << shifts).sum(axis=1, dtype=np.uint32)


def _unpack_bits(words: np.ndarray, n_coords: int) -> np.ndarray:
    """uint32 words -> bool (n_coords,), inverse of ``_pack_bits``."""
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(BITS_PER_WORD, dtype=np.uint32)
    bits = (words[:, None] >> shifts) & np.uint32(1)
    return bits.reshape(-1)[:n_coords].astype(bool)


def pack(dense: jax.Array, mask: Optional[jax.Array] = None,
         dtype=None) -> PackedSparse:
    """Pack one leaf.  ``mask=None`` means dense (all-ones bitmap).

    ``values`` are gathered from ``dense`` at the mask's support, so for a
    {0,1} mask ``unpack(pack(w, m))`` reconstructs ``w ⊙ m`` bit-exactly.
    """
    shape = tuple(dense.shape)
    flat = np.asarray(dense).reshape(-1)
    if mask is None:
        flags = np.ones(flat.size, dtype=bool)
    else:
        flags = np.asarray(mask).reshape(-1) != 0
    vals = flat[flags]
    if dtype is not None:
        vals = vals.astype(dtype)
    return PackedSparse(bitmap=jnp.asarray(_pack_bits(flags)),
                        values=jnp.asarray(vals), shape=shape)


def unpack(ps: PackedSparse) -> jax.Array:
    """Dense leaf: held values at their coordinates, exact zeros elsewhere."""
    flags = _unpack_bits(np.asarray(ps.bitmap), ps.n_coords)
    out = np.zeros(ps.n_coords, dtype=np.asarray(ps.values).dtype)
    out[flags] = np.asarray(ps.values)
    return jnp.asarray(out.reshape(ps.shape))


def unpack_mask(ps: PackedSparse, dtype=jnp.float32) -> jax.Array:
    """The {0,1} mask implied by the bitmap (dense leaf shape)."""
    flags = _unpack_bits(np.asarray(ps.bitmap), ps.n_coords)
    return jnp.asarray(flags.reshape(ps.shape).astype(dtype))


def _is_packed(x) -> bool:
    return isinstance(x, PackedSparse)


def pack_tree(params: PyTree, masks: Optional[PyTree] = None,
              dtype=None) -> PyTree:
    """Pack every leaf of a parameter pytree (``masks=None`` -> dense)."""
    with span("codec.pack_tree", track="codec"):
        _C_PACKS.inc()
        if masks is None:
            return jax.tree.map(lambda w: pack(w, None, dtype), params)
        return jax.tree.map(lambda w, m: pack(w, m, dtype), params, masks)


def unpack_tree(packed: PyTree) -> PyTree:
    """Dense parameter pytree from a packed one."""
    with span("codec.unpack_tree", track="codec"):
        _C_UNPACKS.inc()
        return jax.tree.map(unpack, packed, is_leaf=_is_packed)


def unpack_mask_tree(packed: PyTree, dtype=jnp.float32) -> PyTree:
    """Mask pytree ({0,1} floats) from a packed tree's bitmaps."""
    return jax.tree.map(lambda p: unpack_mask(p, dtype), packed,
                        is_leaf=_is_packed)


def tree_packed_nnz(packed: PyTree) -> int:
    """Total transmitted values across a packed tree."""
    return sum(p.nnz for p in jax.tree.leaves(packed, is_leaf=_is_packed))


def tree_packed_coords(packed: PyTree) -> int:
    """Total dense coordinate count across a packed tree."""
    return sum(p.n_coords
               for p in jax.tree.leaves(packed, is_leaf=_is_packed))
