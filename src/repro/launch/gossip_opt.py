"""Collective-optimized gossip paths (§Perf).

The baseline intersection gossip is an adjacency einsum over the stacked
client dim in f32; GSPMD lowers it to an *all-gather of every client's full
model (and mask)* over the client axis — O(K * params * 4B) bytes per device.
For sparse topologies that is mostly waste: a client only needs its
``degree`` neighbors.

``ppermute_gossip`` implements the ring-topology gossip (paper Fig. 2b,
Table 2) as ``jnp.roll`` over the client dim.  XLA lowers a roll over a
sharded axis to ``collective-permute`` — each device exchanges with exactly
two neighbors, O(2 * params) bytes regardless of K.  Two further wire
optimizations vs the baseline einsum:

  * weights travel in their storage dtype (bf16, 2x fewer bytes than the
    f32 einsum operand);
  * masks travel as int8 (4x fewer bytes than f32) and are only widened
    locally for the divide.

Same intersection-average math, so with a ring adjacency it is numerically
identical to the einsum path up to the f32-vs-bf16 summand rounding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def ppermute_gossip(params: PyTree, masks: PyTree, plan=None,
                    degree: int = 2) -> PyTree:
    """Ring intersection-weighted gossip over the stacked client dim.

    degree=2 exchanges with the +/-1 ring neighbors; degree=2h uses
    +/-1..+/-h (each extra hop adds one collective-permute pair).
    """
    hops = max(1, degree // 2)

    def mix(w, m):
        mf = m.astype(jnp.float32)
        wm = (w.astype(jnp.float32) * mf).astype(w.dtype)  # masked, bf16 wire
        num = wm.astype(jnp.float32)
        den = mf
        for h in range(1, hops + 1):
            # roll over the sharded client dim -> collective-permute of the
            # bf16 weights and int8 masks (cheapest possible wire format)
            num = num + jnp.roll(wm, h, axis=0).astype(jnp.float32) \
                      + jnp.roll(wm, -h, axis=0).astype(jnp.float32)
            den = den + jnp.roll(m, h, axis=0).astype(jnp.float32) \
                      + jnp.roll(m, -h, axis=0).astype(jnp.float32)
        return ((num / jnp.maximum(den, 1.0)) * mf).astype(w.dtype)

    return jax.tree.map(mix, params, masks)
