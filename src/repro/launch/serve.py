"""Thin CLI over the repro.serve serving plane.

Builds a ``ModelStore`` (synthetic per-user sparse personalizations, or a
trained engine checkpoint via ``--from-checkpoint``), replays a
seed-derived request stream through the micro-batcher, and streams p50/p99
latency, requests/s and cache counters as JSON lines.

    PYTHONPATH=src python -m repro.launch.serve \
        --users 64 --cache-size 16 --max-batch 8 --requests 256 \
        --backend ref --metrics-jsonl serve_metrics.jsonl

``--model`` picks the served family: ``mlp`` (matmul pipeline — supports
vmap/ref/pallas backends), ``smallcnn`` (FL task model, vmap only), or
any registered smoke arch name (one-step scorer, vmap only).
"""
from __future__ import annotations

import argparse


def build_model(name: str, rows: int):
    from repro.serve.model import ArchModel, MLPModel, TaskModel

    if name == "mlp":
        return MLPModel(d_in=64, widths=(128, 128), n_out=32, rows=rows)
    if name == "smallcnn":
        from repro.fl.base import make_cnn_task
        return TaskModel(make_cnn_task("smallcnn"), hw=16, rows=rows)
    from repro.configs import SMOKE_ARCHS
    if name in SMOKE_ARCHS:
        return ArchModel(SMOKE_ARCHS[name], rows=rows)
    raise SystemExit(
        f"unknown --model {name!r}: expected mlp, smallcnn, or one of "
        f"{sorted(SMOKE_ARCHS)}")


def build_store(args, model):
    import jax
    import numpy as np

    from repro.core.masks import apply_mask, init_mask
    from repro.serve.store import ModelStore

    if args.from_checkpoint:
        return ModelStore.from_checkpoint(
            args.from_checkpoint, cache_size=args.cache_size)
    base = model.init(jax.random.PRNGKey(args.seed))
    store = ModelStore(base, cache_size=args.cache_size)
    keys = jax.random.split(jax.random.PRNGKey(args.seed + 1), 2 * args.users)
    for u in range(args.users):
        p = model.init(keys[2 * u])
        m = init_mask(keys[2 * u + 1], p, args.density)
        store.put(u, apply_mask(p, m), m)
    return store


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=64)
    ap.add_argument("--cache-size", type=int, default=16, dest="cache_size")
    ap.add_argument("--max-batch", type=int, default=8, dest="max_batch")
    ap.add_argument("--max-wait", type=float, default=0.005, dest="max_wait",
                    help="virtual seconds a request may wait before flush")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--backend", default="vmap",
                    choices=("vmap", "ref", "pallas"))
    ap.add_argument("--model", default="mlp",
                    help="mlp | smallcnn | <smoke arch name>")
    ap.add_argument("--rows", type=int, default=4,
                    help="input rows per request (matmul M)")
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="virtual arrivals per second")
    ap.add_argument("--from-checkpoint", default=None, dest="from_checkpoint",
                    help="load users from a trained engine archive instead "
                         "of synthesizing them")
    ap.add_argument("--metrics-every", type=int, default=8,
                    dest="metrics_every")
    ap.add_argument("--metrics-jsonl", default="-", dest="metrics_jsonl",
                    help="stream JSON lines here ('-': stdout)")
    ap.add_argument("--trace", default="",
                    help="export a Perfetto-loadable trace_event JSON of "
                         "the run (repro.obs) to this path")
    ap.add_argument("--trace-mode", default=None, dest="trace_mode",
                    choices=["ring", "full"],
                    help="span recorder: ring = bounded buffer (default), "
                         "full = keep every span")
    ap.add_argument("--run-dir", default="", dest="run_dir",
                    help="write a run archive (manifest, counters, series, "
                         "trace, health events) to this directory; implies "
                         "tracing.  Render with repro.launch.dash")
    args = ap.parse_args()
    if args.trace_mode is not None and not (args.trace or args.run_dir):
        ap.error("--trace-mode requires --trace or --run-dir")

    from repro.serve.batcher import RequestStream
    from repro.serve.engine import ServeEngine
    from repro.sim.report import MetricsStream

    if args.trace or args.run_dir:
        from repro.obs import get_tracer
        get_tracer().enable(mode=args.trace_mode or "ring")

    model = build_model(args.model, args.rows)
    store = build_store(args, model)
    n_users = len(store.users()) or args.users

    with MetricsStream(args.metrics_jsonl) as stream:
        stream.emit({"event": "store", **store.stats(),
                     "model": args.model, "backend": args.backend})
        engine = ServeEngine(store, model, backend=args.backend,
                             max_batch=args.max_batch, max_wait=args.max_wait,
                             metrics=stream, metrics_every=args.metrics_every)
        requests = RequestStream(n_users=n_users, n_requests=args.requests,
                                 seed=args.seed, rate=args.rate)
        result = engine.serve(requests)
    if args.trace:
        from repro.obs import write_trace
        doc = write_trace(args.trace)
        print(f"wrote trace ({doc['otherData']['spans']} spans) to "
              f"{args.trace} — open at https://ui.perfetto.dev")
    if args.run_dir:
        import os

        from repro.obs import (
            RunManifest,
            emit_health,
            fleet_health,
            get_tracer,
            save_run,
            snapshot_counters,
        )

        config = {k: v for k, v in vars(args).items()
                  if isinstance(v, (int, float, str, bool, type(None)))}
        manifest = RunManifest.build("serve", seed=args.seed, config=config)
        tracer = get_tracer()
        save_run(args.run_dir, manifest,
                 tracer=tracer if tracer.enabled else None,
                 report=result.summary)
        _, events = fleet_health(tracer, counters=snapshot_counters(),
                                 dropped_spans=tracer.dropped)
        with MetricsStream(os.path.join(args.run_dir, "health.jsonl"),
                           header=True) as hs:
            emit_health(hs, events)
        for ev in events:
            print(f"[health] {ev.severity}: {ev.kind} — {ev.message}")
        print(f"saved run archive {manifest.run_id} to {args.run_dir} "
              f"({len(events)} health events)")


if __name__ == "__main__":
    main()
