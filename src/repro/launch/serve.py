"""Personalized sparse serving demo: batched generation from per-client
masked models (the serving counterpart of DisPFL — each request is routed to
its owner's personalized sparse model).

Metrics stream live as JSON lines (one object per ``--metrics-every`` decode
steps, plus a final summary line) through ``repro.sim.report.MetricsStream``
— the same streaming protocol the round engine and network simulator use —
instead of a single end-of-run dump.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --clients 4 --batch 2 --prompt-len 16 --gen 16 \
        --metrics-jsonl serve_metrics.jsonl
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16, dest="prompt_len")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-every", type=int, default=4,
                    dest="metrics_every",
                    help="emit a live metrics line every N decode steps")
    ap.add_argument("--metrics-jsonl", default="-", dest="metrics_jsonl",
                    help="stream JSON lines here ('-': stdout)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import SMOKE_ARCHS
    from repro.core.masks import apply_mask, init_mask
    from repro.models import bind
    from repro.utils.tree import tree_stack

    cfg = SMOKE_ARCHS[args.arch]
    api = bind(cfg, remat=False)
    k = args.clients
    keys = jax.random.split(jax.random.PRNGKey(args.seed), 2 * k)
    params, masks = [], []
    for i in range(k):
        p = api.init(keys[i])
        m = init_mask(keys[k + i], p, args.density)
        params.append(apply_mask(p, m))
        masks.append(m)
    sp = tree_stack(params)

    b, s0 = args.batch, args.prompt_len
    max_len = s0 + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(7), (k, b, s0), 0, cfg.vocab)

    extra = {}
    if cfg.prefix_len:
        extra["prefix"] = jnp.zeros((k, b, cfg.prefix_len, cfg.d_model))
    if cfg.enc_layers:
        extra["frames"] = jax.random.normal(
            jax.random.PRNGKey(9), (k, b, 8, cfg.d_model))

    def make_cache():
        if cfg.enc_layers:
            return jax.vmap(lambda _: api.init_cache(b, max_len, enc_len=8))(
                jnp.arange(k))
        return jax.vmap(lambda _: api.init_cache(b, max_len))(jnp.arange(k))

    cache = make_cache()

    @jax.jit
    def prefill(sp, prompts, cache, extra):
        batch = {"tokens": prompts, **extra}
        return jax.vmap(api.prefill)(sp, batch, cache)

    @jax.jit
    def decode(sp, toks, pos, cache):
        logits, cache = jax.vmap(api.decode)(sp, toks, pos, cache)
        nxt = jnp.argmax(logits[:, :, -1, :], axis=-1).astype(jnp.int32)
        return nxt, cache

    from repro.sim.report import MetricsStream

    stream = MetricsStream(args.metrics_jsonl)
    t0 = time.time()
    logits, cache = prefill(sp, prompts, cache, extra)
    nxt = jnp.argmax(logits[:, :, -1, :], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    stream.emit({"event": "prefill", "arch": cfg.name, "clients": k,
                 "batch_per_client": b, "prompt_len": s0,
                 "prefill_s": round(t_prefill, 3)})

    out_tokens = [nxt]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((k,), s0 + i, jnp.int32)
        nxt, cache = decode(sp, nxt[:, :, None], pos, cache)
        out_tokens.append(nxt)
        step = i + 1
        if step % args.metrics_every == 0 or step == args.gen - 1:
            elapsed = time.time() - t0
            stream.emit({
                "event": "decode", "step": step,
                "tokens_out": k * b * step,
                "elapsed_s": round(elapsed, 3),
                "tok_per_s": round(k * b * step / max(elapsed, 1e-9), 1)})
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=-1)  # (K, B, gen)
    stream.emit({
        "event": "summary",
        "arch": cfg.name,
        "clients": k,
        "batch_per_client": b,
        "prefill_s": round(t_prefill, 2),
        "decode_s": round(t_decode, 2),
        "tok_per_s": round(k * b * (args.gen - 1) / max(t_decode, 1e-9), 1),
        "sample_generation_client0": gen[0, 0].tolist(),
    })
    stream.close()


if __name__ == "__main__":
    main()
