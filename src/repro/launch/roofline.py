"""Three-term roofline analysis from a compiled dry-run artifact.

Hardware model (TPU v5e, per chip):
    peak_flops = 197e12 FLOP/s (bf16)
    hbm_bw     = 819e9  B/s
    ici_bw     = 50e9   B/s per link (we assume 1 effective link per chip —
                 conservative; v5e has more, so the collective term is an
                 upper bound)

Terms (seconds per step):
    compute    = global_HLO_FLOPs   / (chips * peak_flops)
    memory     = global_HLO_bytes   / (chips * hbm_bw)
    collective = global_coll_bytes  / (chips * ici_bw)

``cost_analysis()`` and the parsed HLO are *per-device* (post-SPMD), so the
global quantities are per_device * chips and the terms reduce to
per-device / per-chip-rate; both views are recorded.

MODEL_FLOPS (the useful compute): 6*N*D for training (N = active params for
MoE), 2*N*D for forward-only serving; D = tokens processed in the step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    per_device_flops: float
    per_device_bytes: float
    per_device_coll_bytes: float
    model_flops_global: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    step_s: float = 0.0
    mfu: float = 0.0

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.per_device_flops / PEAK_FLOPS
        self.memory_s = self.per_device_bytes / HBM_BW
        self.collective_s = self.per_device_coll_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        hlo_global = self.per_device_flops * self.chips
        self.useful_ratio = (self.model_flops_global / hlo_global
                             if hlo_global else 0.0)
        self.step_s = max(terms.values())
        peak_total = self.chips * PEAK_FLOPS
        self.mfu = (self.model_flops_global / (self.step_s * peak_total)
                    if self.step_s else 0.0)
        return self

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "bottleneck": self.bottleneck,
            "useful_ratio": round(self.useful_ratio, 3),
            "roofline_step_ms": round(self.step_s * 1e3, 3),
            "mfu_bound": round(self.mfu, 3),
        }


def active_params(cfg) -> float:
    """Active (per-token) parameter count — MoE counts top_k + shared
    experts, not the full expert bank.  Computed from config dims."""
    from repro.configs.base import layer_kinds

    d = cfg.d_model
    dh = cfg.resolved_head_dim
    # input-embedding lookups are gathers (0 matmul FLOPs); only the LM head
    # projection contributes compute, tied or not
    total = cfg.vocab * d
    for sub in layer_kinds(cfg):
        if sub.kind == "attn":
            total += d * (cfg.n_heads * dh) * 2 + d * (cfg.n_kv_heads * dh) * 2
        else:
            spec = cfg.ssm
            d_inner = spec.expand * d
            n_heads = d_inner // spec.head_dim
            d_in_proj = 2 * d_inner + 2 * spec.d_state + n_heads
            total += d * d_in_proj + d_inner * d
        if sub.ffn == "mlp":
            ff = sub.d_ff_override or cfg.d_ff
            mult = 3 if cfg.mlp_gated else 2
            total += mult * d * ff
        elif sub.ffn == "moe":
            spec = cfg.moe
            total += 3 * d * spec.d_expert * (spec.top_k + spec.n_shared)
            total += d * spec.n_experts  # router
    if cfg.enc_layers:
        total += cfg.enc_layers * (4 * d * d + (3 if cfg.mlp_gated else 2) * d * cfg.d_ff)
        # decoder cross-attention
        total += cfg.n_layers * 4 * d * d
    return float(total)


def total_params(cfg) -> float:
    """Full parameter count (MoE counts every expert)."""
    from repro.configs.base import layer_kinds

    d = cfg.d_model
    dh = cfg.resolved_head_dim
    total = cfg.vocab * d
    if not cfg.tie_embeddings:
        total += cfg.vocab * d
    for sub in layer_kinds(cfg):
        if sub.kind == "attn":
            total += d * (cfg.n_heads * dh) * 2 + d * (cfg.n_kv_heads * dh) * 2
        else:
            spec = cfg.ssm
            d_inner = spec.expand * d
            n_heads = d_inner // spec.head_dim
            d_in_proj = 2 * d_inner + 2 * spec.d_state + n_heads
            total += d * d_in_proj + d_inner * d
        if sub.ffn == "mlp":
            ff = sub.d_ff_override or cfg.d_ff
            total += (3 if cfg.mlp_gated else 2) * d * ff
        elif sub.ffn == "moe":
            spec = cfg.moe
            total += 3 * d * spec.d_expert * (spec.n_experts + spec.n_shared)
            total += d * spec.n_experts
    if cfg.enc_layers:
        total += cfg.enc_layers * (4 * d * d + (3 if cfg.mlp_gated else 2) * d * cfg.d_ff)
        total += cfg.n_layers * 4 * d * d
    return float(total)


def model_flops(cfg, shape, density: float = 1.0) -> float:
    """6*N_active*D for train, 2*N_active*D for serve steps.  ``density``
    scales for DisPFL sparse models (coordinate density)."""
    n = active_params(cfg) * density
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def build_report(arch_cfg, shape, mesh_name: str, chips: int,
                 cost: dict, coll_bytes_per_device: float,
                 density: float = 1.0) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch_cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        per_device_flops=flops, per_device_bytes=bts,
        per_device_coll_bytes=coll_bytes_per_device,
        model_flops_global=model_flops(arch_cfg, shape, density),
    ).finalize()


def measured_phase_rows(phase_summary: dict,
                        analytic: Optional[dict] = None) -> list[dict]:
    """Predicted-vs-observed rows from a ``repro.obs`` run.

    ``phase_summary`` is ``repro.obs.export.phase_summary`` output
    (``{phase: {count, total_s, mean_s, max_s}}`` of *measured* spans);
    ``analytic`` optionally maps a phase name to ``(quantity, unit)`` with
    unit ``"flops"`` or ``"bytes"`` — the analytic cost of ONE call, priced
    on the reference chip (peak FLOP/s or HBM bandwidth) into a predicted
    ms so the report shows the roofline model next to what the host
    actually spent.  ``achieved_per_s`` is quantity / observed seconds —
    the honest rate, however far from the roof the host is.
    """
    rates = {"flops": PEAK_FLOPS, "bytes": HBM_BW}
    rows = []
    for phase in sorted(phase_summary):
        agg = phase_summary[phase]
        row = {
            "phase": phase,
            "calls": int(agg["count"]),
            "observed_ms_per_call": round(agg["mean_s"] * 1e3, 4),
            "observed_total_ms": round(agg["total_s"] * 1e3, 3),
        }
        spec = (analytic or {}).get(phase)
        if spec is not None:
            quantity, unit = spec
            if unit not in rates:
                raise ValueError(f"analytic unit must be flops|bytes, "
                                 f"got {unit!r}")
            row["analytic_" + unit] = float(quantity)
            row["predicted_ms_per_call"] = round(
                quantity / rates[unit] * 1e3, 6)
            if agg["mean_s"] > 0:
                row["achieved_per_s"] = float(quantity / agg["mean_s"])
        rows.append(row)
    return rows
