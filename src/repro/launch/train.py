"""Training launcher.

Two modes:

1. ``simulate`` (default) — the paper's experiment: K clients, non-IID
   partitions, any strategy from the zoo, full comm/FLOP accounting and
   per-client personalized checkpoints.

       PYTHONPATH=src python -m repro.launch.train simulate \
           --strategy dispfl --clients 16 --rounds 30 --partition dirichlet

2. ``lm`` — end-to-end DisPFL on a transformer LM over synthetic Markov
   domains (one domain per client), demonstrating the technique on the
   assigned-architecture substrate (reduced configs on CPU).

       PYTHONPATH=src python -m repro.launch.train lm \
           --arch qwen3-8b --steps 100 --clients 4
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_simulate(args) -> dict:
    from repro.checkpoint import save_clients
    from repro.data import build_federated_image_task
    from repro.fl import (
        Checkpointer,
        EarlyStopAtTarget,
        FLConfig,
        JsonlLogger,
        RoundEngine,
        make_cnn_task,
        make_strategy,
    )

    clients, _ = build_federated_image_task(
        args.seed, n_clients=args.clients, partition=args.partition,
        alpha=args.alpha, classes_per_client=args.classes_per_client,
        n_train_per_class=args.samples_per_class, hw=args.hw)
    task = make_cnn_task(args.model, n_classes=10, hw=args.hw,
                         width=args.width)
    capacities = None
    if args.heterogeneous:
        levels = [0.2, 0.4, 0.6, 0.8, 1.0]
        capacities = [levels[k % 5] for k in range(args.clients)]
    cfg = FLConfig(
        n_clients=args.clients, rounds=args.rounds,
        local_epochs=args.local_epochs, batch_size=args.batch_size,
        lr0=args.lr, topology=args.topology, degree=args.degree,
        density=args.density, capacities=capacities, seed=args.seed,
        drop_prob=args.drop_prob, eval_every=args.eval_every)

    callbacks = []
    if args.log_jsonl:
        callbacks.append(JsonlLogger(args.log_jsonl))
    if args.checkpoint:
        callbacks.append(Checkpointer(args.checkpoint,
                                      every=args.checkpoint_every))
    if args.target > 0:
        callbacks.append(EarlyStopAtTarget(args.target))
    if args.scale:
        from repro.scale import ScaleEngine

        mesh = None
        if args.mesh_shape:
            from repro.launch.mesh import make_test_mesh

            try:
                dims = [int(x) for x in args.mesh_shape.lower().split("x")]
            except ValueError:
                dims = []
            if len(dims) not in (2, 3):
                raise SystemExit(
                    f"--mesh-shape wants DATAxMODEL or PODSxDATAxMODEL, "
                    f"got {args.mesh_shape!r}")
            try:
                if len(dims) == 2:
                    mesh = make_test_mesh(data=dims[0], model=dims[1])
                else:
                    mesh = make_test_mesh(pods=dims[0], data=dims[1],
                                          model=dims[2])
            except ValueError as e:
                raise SystemExit(
                    f"cannot build mesh {args.mesh_shape}: {e}\n"
                    "(on CPU, export XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=<n_devices> before launching)")
        engine = ScaleEngine(
            make_strategy(args.strategy), task, clients, cfg,
            callbacks=callbacks, mesh=mesh, reduction=args.scale_reduction)
    elif args.sim:
        from repro.sim import (
            AlwaysUp,
            BandwidthTrace,
            BernoulliAvailability,
            LinkModel,
            LossModel,
            SimEngine,
            hetero_speeds,
        )
        trace = (BandwidthTrace.from_json(args.bandwidth_trace)
                 if args.bandwidth_trace else None)
        links = (LinkModel.skewed(args.clients, args.bandwidth_mbps,
                                  args.bandwidth_skew,
                                  latency_ms=args.latency_ms, seed=args.seed,
                                  trace=trace)
                 if args.bandwidth_skew > 1.0 else
                 LinkModel.uniform(args.clients, args.bandwidth_mbps,
                                   args.latency_ms, trace=trace))
        avail = (BernoulliAvailability(args.clients, args.drop_prob, args.seed)
                 if args.drop_prob > 0 else AlwaysUp(args.clients))
        speeds = (hetero_speeds(args.clients, seed=args.seed)
                  if args.compute_hetero else None)
        loss = (LossModel(args.loss_prob, args.retransmit_timeout,
                          seed=args.seed)
                if args.loss_prob > 0 else None)
        if args.sim_checkpoint:
            callbacks.append(Checkpointer(args.sim_checkpoint,
                                          every=args.checkpoint_every))
        engine = SimEngine(
            make_strategy(args.strategy), task, clients, cfg,
            callbacks=callbacks, local_exec=args.local_exec,
            mode="async" if args.sim_async else "sync",
            staleness=args.staleness, links=links, availability=avail,
            round_s=args.round_s, compute_speeds=speeds,
            uplink=args.uplink_mode, loss=loss)
    else:
        engine = RoundEngine(make_strategy(args.strategy), task, clients, cfg,
                             callbacks=callbacks, local_exec=args.local_exec)
    if args.resume:
        engine.restore(args.resume)
        print(f"resumed from {args.resume} at round {engine._next_round}")
    if args.trace or args.run_dir:
        # --run-dir implies tracing: the archive's rollups/dashboard are
        # derived from spans, so an archive without them is near-empty
        from repro.obs import get_tracer
        get_tracer().enable(mode=args.trace_mode or "ring")

    t0 = time.time()
    for m in engine.rounds():
        if m.acc_mean is not None:
            sim_note = (f" t_sim={m.sim_time_s:.1f}s"
                        if hasattr(m, "sim_time_s") else "")
            print(f"[round {m.round + 1}/{cfg.rounds}] "
                  f"acc={m.acc_mean:.3f}±{m.acc_std:.3f} "
                  f"comm={m.comm_busiest_mb:.2f}MB lr={m.lr:.4f} "
                  f"({m.wall_s:.1f}s){sim_note}")
    res = engine.result()
    out = {
        "strategy": args.strategy, "partition": args.partition,
        "final_acc": res.final_acc, "acc_history": res.acc_history,
        "comm": res.comm_rows, "flops": res.flops_rows,
        "wall_s": round(time.time() - t0, 1),
    }
    if args.sim:
        targets = (args.target,) if args.target > 0 else ()
        out["sim"] = engine.report(targets=targets).row()
    print(json.dumps(out, indent=2))
    if args.trace:
        from repro.obs import write_trace
        doc = write_trace(args.trace)
        print(f"wrote trace ({doc['otherData']['spans']} spans) to "
              f"{args.trace} — open at https://ui.perfetto.dev")
    if args.run_dir:
        _save_run_archive(args, engine, out)
    if args.save:
        save_clients(args.save, [{"final_acc": np.asarray(a)}
                                 for a in res.final_accs])
        print(f"saved per-client results to {args.save}")
    return out


def _save_run_archive(args, engine, out: dict) -> None:
    """Write the run archive (manifest + counters + series + trace) and
    stream fleet-health events to ``<run_dir>/health.jsonl`` — the layout
    ``repro.launch.dash`` renders and ``RunRegistry`` lists."""
    import os

    from repro.obs import (
        RunManifest,
        fleet_health,
        get_tracer,
        save_run,
    )
    from repro.sim.report import MetricsStream

    kind = "scale" if args.scale else ("sim" if args.sim else "train")
    config = {k: v for k, v in vars(args).items()
              if isinstance(v, (int, float, str, bool, type(None)))}
    manifest = RunManifest.build(kind, seed=args.seed, config=config)
    tracer = get_tracer()
    save_run(args.run_dir, manifest,
             tracer=tracer if tracer.enabled else None, report=out)

    density = None
    dm = engine.series.series("density_measured")
    dt = engine.series.series("density_target")
    if dm.points() and dt.points():
        density = (dm, dt)
    from repro.obs import snapshot_counters
    _, events = fleet_health(
        tracer, counters=snapshot_counters(), density=density,
        dropped_spans=tracer.dropped)
    with MetricsStream(os.path.join(args.run_dir, "health.jsonl"),
                       header=True) as stream:
        from repro.obs import emit_health
        emit_health(stream, events)
    for ev in events:
        print(f"[health] {ev.severity}: {ev.kind} — {ev.message}")
    print(f"saved run archive {manifest.run_id} to {args.run_dir} "
          f"({len(events)} health events)")


def run_lm(args) -> dict:
    """DisPFL over a reduced assigned-arch LM on synthetic non-IID corpora."""
    import jax
    import jax.numpy as jnp

    from repro.configs import SMOKE_ARCHS, get_arch
    from repro.core.evolve import cosine_prune_rate, evolve_masks, layer_nnz_budgets
    from repro.core.gossip import gossip_average_stacked
    from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
    from repro.core.topology import make_adjacency
    from repro.data import make_lm_corpus
    from repro.models import bind
    from repro.utils.tree import tree_stack, tree_index, tree_size

    cfg = SMOKE_ARCHS[args.arch].replace(
        d_model=args.d_model, n_layers=max(SMOKE_ARCHS[args.arch].n_layers,
                                           args.layers),
        vocab=256)
    api = bind(cfg, remat=False)
    k_clients = args.clients
    seq, bs = args.seq, args.batch_size
    streams = make_lm_corpus(args.seed, vocab=256, n_domains=k_clients,
                             tokens_per_domain=args.tokens_per_client)

    keys = jax.random.split(jax.random.PRNGKey(args.seed), 2 * k_clients)
    params = [api.init(keys[i]) for i in range(k_clients)]
    masks = [init_mask(keys[k_clients + i], params[i], args.density)
             for i in range(k_clients)]
    densities = erk_densities_for_params(params[0], args.density)
    budgets = layer_nnz_budgets(params[0], densities)
    params = [apply_mask(p, m) for p, m in zip(params, masks)]
    print(f"[lm] arch={cfg.name} params/client={tree_size(params[0])/1e6:.2f}M "
          f"density={args.density}")

    rng = np.random.default_rng(args.seed)

    def batch_for(k):
        s = streams[k]
        starts = rng.integers(0, len(s) - seq - 1, size=bs)
        toks = np.stack([s[i: i + seq] for i in starts])
        labs = np.stack([s[i + 1: i + seq + 1] for i in starts])
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}

    @jax.jit
    def step(stacked_params, stacked_masks, batch, adjacency, lr):
        mixed = gossip_average_stacked(stacked_params, stacked_masks, adjacency)

        def total(ps):
            losses, _ = jax.vmap(lambda p, b: api.train_loss(p, b))(ps, batch)
            return jnp.sum(losses), losses

        (_, losses), grads = jax.value_and_grad(total, has_aux=True)(mixed)
        new = jax.tree.map(
            lambda w, g, m: (w - lr * g * m.astype(w.dtype)) * m.astype(w.dtype),
            mixed, grads, stacked_masks)
        return new, losses

    sp = tree_stack(params)
    sm = tree_stack(masks)
    hist = []
    steps_per_round = max(1, args.steps // args.rounds)
    t0 = time.time()
    it = 0
    for r in range(args.rounds):
        adj = jnp.asarray(make_adjacency("random", k_clients, r,
                                         degree=min(3, k_clients - 1),
                                         seed=args.seed))
        lr = args.lr * (0.998 ** r)
        for _ in range(steps_per_round):
            batch = tree_stack([batch_for(k) for k in range(k_clients)])
            sp, losses = step(sp, sm, batch, adj, lr)
            it += 1
        # mask evolution once per round
        alpha = cosine_prune_rate(0.5, r, args.rounds)
        ps = [tree_index(sp, i) for i in range(k_clients)]
        ms = [tree_index(sm, i) for i in range(k_clients)]
        for k in range(k_clients):
            g = jax.grad(lambda p: api.train_loss(p, batch_for(k))[0])(ps[k])
            ms[k], ps[k] = evolve_masks(ps[k], ms[k], g, alpha, budgets)
        sp, sm = tree_stack(ps), tree_stack(ms)
        mean_loss = float(jnp.mean(losses))
        hist.append(mean_loss)
        print(f"[lm] round {r+1}/{args.rounds} step {it} loss={mean_loss:.4f} "
              f"lr={lr:.4f} ({time.time()-t0:.0f}s)")
    out = {"arch": cfg.name, "loss_history": hist,
           "improved": hist[-1] < hist[0]}
    print(json.dumps({k: v for k, v in out.items() if k != "loss_history"}))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    sim = sub.add_parser("simulate")
    sim.add_argument("--strategy", default="dispfl")
    sim.add_argument("--clients", type=int, default=16)
    sim.add_argument("--rounds", type=int, default=30)
    sim.add_argument("--local-epochs", type=int, default=5, dest="local_epochs")
    sim.add_argument("--batch-size", type=int, default=32, dest="batch_size")
    sim.add_argument("--lr", type=float, default=0.1)
    sim.add_argument("--partition", default="dirichlet",
                     choices=["dirichlet", "pathological"])
    sim.add_argument("--alpha", type=float, default=0.3)
    sim.add_argument("--classes-per-client", type=int, default=2,
                     dest="classes_per_client")
    sim.add_argument("--samples-per-class", type=int, default=100,
                     dest="samples_per_class")
    sim.add_argument("--topology", default="random",
                     choices=["random", "ring", "fc"])
    sim.add_argument("--degree", type=int, default=10)
    sim.add_argument("--density", type=float, default=0.5)
    sim.add_argument("--heterogeneous", action="store_true")
    sim.add_argument("--drop-prob", type=float, default=0.0, dest="drop_prob")
    sim.add_argument("--model", default="smallcnn",
                     choices=["smallcnn", "resnet18", "vgg11"])
    sim.add_argument("--width", type=int, default=16)
    sim.add_argument("--hw", type=int, default=16)
    sim.add_argument("--eval-every", type=int, default=1, dest="eval_every")
    sim.add_argument("--seed", type=int, default=0)
    sim.add_argument("--save", default="")
    sim.add_argument("--exec", default="auto", dest="local_exec",
                     choices=["auto", "loop", "vmap"],
                     help="local-phase execution: vmap = stacked fast path")
    sim.add_argument("--log-jsonl", default="", dest="log_jsonl",
                     help="stream per-round RoundMetrics to this JSONL file")
    sim.add_argument("--checkpoint", default="",
                     help="save engine state to this .npz after rounds")
    sim.add_argument("--checkpoint-every", type=int, default=1,
                     dest="checkpoint_every")
    sim.add_argument("--resume", default="",
                     help="restore engine state from this .npz and continue")
    sim.add_argument("--target", type=float, default=0.0,
                     help="early-stop once mean personalized acc >= target")
    sim.add_argument("--trace", default="",
                     help="export a Perfetto-loadable trace_event JSON of "
                          "the run (repro.obs) to this path")
    sim.add_argument("--trace-mode", default=None, dest="trace_mode",
                     choices=["ring", "full"],
                     help="span recorder: ring = bounded buffer (default), "
                          "full = keep every span")
    sim.add_argument("--run-dir", default="", dest="run_dir",
                     help="write a run archive (manifest, counters, series, "
                          "trace, health events) to this directory; implies "
                          "tracing.  Render with repro.launch.dash")
    # client-sharded SPMD execution (repro.scale)
    sim.add_argument("--scale", action="store_true",
                     help="run through ScaleEngine: the whole round "
                          "(mix + local phase + evolve) as one jitted "
                          "stacked program (dispfl / dispfl_anneal / dpsgd)")
    sim.add_argument("--mesh-shape", default="", dest="mesh_shape",
                     help="shard the stacked client dim over a device mesh "
                          "DATAxMODEL or PODSxDATAxMODEL (e.g. 8x1); on "
                          "CPU set XLA_FLAGS=--xla_force_host_platform_"
                          "device_count first")
    sim.add_argument("--scale-reduction", default="einsum",
                     dest="scale_reduction", choices=["einsum", "ordered"],
                     help="gossip fold: einsum = SPMD matmul (default), "
                          "ordered = bit-exact reference accumulation order")
    # event-driven network simulation (repro.sim)
    sim.add_argument("--sim", action="store_true",
                     help="run through the event-driven network simulator")
    sim.add_argument("--async", dest="sim_async", action="store_true",
                     help="asynchronous staleness-bounded gossip (default: "
                          "synchronous barrier, bit-identical to the engine)")
    sim.add_argument("--staleness", type=int, default=None,
                     help="max rounds any client may run ahead "
                          "(-1: unbounded; default 2)")
    sim.add_argument("--bandwidth-mbps", type=float, default=None,
                     dest="bandwidth_mbps", help="default 100")
    sim.add_argument("--bandwidth-skew", type=float, default=None,
                     dest="bandwidth_skew",
                     help=">1: half the clients sit behind skew-x slower links")
    sim.add_argument("--latency-ms", type=float, default=None,
                     dest="latency_ms", help="default 10")
    sim.add_argument("--compute-hetero", action="store_true",
                     dest="compute_hetero",
                     help="0.2x..1.0x per-client compute speed multipliers")
    sim.add_argument("--round-s", type=float, default=None, dest="round_s",
                     help="virtual seconds a full-speed client spends per "
                          "round (default 1.0)")
    # fault realism (sim v2)
    sim.add_argument("--loss-prob", type=float, default=None,
                     dest="loss_prob",
                     help="per-link Bernoulli message drop probability "
                          "(retransmitted after --retransmit-timeout; every "
                          "attempt's bytes are counted on the wire)")
    sim.add_argument("--retransmit-timeout", type=float, default=None,
                     dest="retransmit_timeout",
                     help="virtual seconds the sender waits before resending "
                          "a dropped message (default 0.5)")
    sim.add_argument("--uplink-mode", default=None, dest="uplink_mode",
                     choices=["parallel", "fifo", "fair"],
                     help="shared-uplink discipline: parallel = idealized "
                          "per-edge links (default), fifo/fair serialize a "
                          "sender's concurrent transfers on one uplink")
    sim.add_argument("--bandwidth-trace", default=None,
                     dest="bandwidth_trace",
                     help='JSON file {"times": [...], "scale": [...]} of '
                          "time-varying bandwidth multipliers (scale rows "
                          "scalar or per-client)")
    sim.add_argument("--sim-checkpoint", default="", dest="sim_checkpoint",
                     help="save the full simulator state (virtual clock, "
                          "event queue, link stats) to this .npz every "
                          "--checkpoint-every rounds; resume with --resume "
                          "(--checkpoint writes the same archive under "
                          "--sim; this alias just keeps sim runs explicit)")

    lm = sub.add_parser("lm")
    lm.add_argument("--arch", default="qwen3-8b")
    lm.add_argument("--clients", type=int, default=4)
    lm.add_argument("--steps", type=int, default=100)
    lm.add_argument("--rounds", type=int, default=10)
    lm.add_argument("--seq", type=int, default=128)
    lm.add_argument("--batch-size", type=int, default=8, dest="batch_size")
    lm.add_argument("--lr", type=float, default=0.05)
    lm.add_argument("--density", type=float, default=0.5)
    lm.add_argument("--d-model", type=int, default=256, dest="d_model")
    lm.add_argument("--layers", type=int, default=2)
    lm.add_argument("--tokens-per-client", type=int, default=32768,
                    dest="tokens_per_client")
    lm.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    if args.mode == "simulate":
        if args.scale and args.sim:
            ap.error("--scale and --sim are mutually exclusive engines")
        if args.trace_mode is not None and not (args.trace or args.run_dir):
            ap.error("--trace-mode requires --trace or --run-dir")
        if not args.scale:
            scale_only = {"--mesh-shape": bool(args.mesh_shape),
                          "--scale-reduction":
                              args.scale_reduction != "einsum"}
            used = [f for f, on in scale_only.items() if on]
            if used:
                ap.error(f"{', '.join(used)} require(s) --scale")
        if not args.sim:
            sim_only = {"--async": args.sim_async,
                        "--staleness": args.staleness is not None,
                        "--bandwidth-mbps": args.bandwidth_mbps is not None,
                        "--bandwidth-skew": args.bandwidth_skew is not None,
                        "--latency-ms": args.latency_ms is not None,
                        "--compute-hetero": args.compute_hetero,
                        "--round-s": args.round_s is not None,
                        "--loss-prob": args.loss_prob is not None,
                        "--retransmit-timeout":
                            args.retransmit_timeout is not None,
                        "--uplink-mode": args.uplink_mode is not None,
                        "--bandwidth-trace": args.bandwidth_trace is not None,
                        "--sim-checkpoint": bool(args.sim_checkpoint)}
            used = [f for f, on in sim_only.items() if on]
            if used:
                ap.error(f"{', '.join(used)} require(s) --sim")
        # resolve sim defaults after the guard above (`is None`, never `or`:
        # an explicit 0 must reach the models' own validation, not be
        # silently replaced by the default)
        args.staleness = 2 if args.staleness is None else args.staleness
        args.bandwidth_mbps = (100.0 if args.bandwidth_mbps is None
                               else args.bandwidth_mbps)
        args.bandwidth_skew = (1.0 if args.bandwidth_skew is None
                               else args.bandwidth_skew)
        args.latency_ms = 10.0 if args.latency_ms is None else args.latency_ms
        args.round_s = 1.0 if args.round_s is None else args.round_s
        args.loss_prob = 0.0 if args.loss_prob is None else args.loss_prob
        args.retransmit_timeout = (0.5 if args.retransmit_timeout is None
                                   else args.retransmit_timeout)
        args.uplink_mode = ("parallel" if args.uplink_mode is None
                            else args.uplink_mode)
        if args.sim and args.bandwidth_skew < 1.0:
            ap.error("--bandwidth-skew must be >= 1 (1 = uniform links)")
        run_simulate(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
