"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
artifacts.  Roofline terms are recomputed from the stored cost/collective
numbers with the current hardware model (so the artifacts don't go stale
when the roofline code improves).

    PYTHONPATH=src python -m repro.launch.report [--mesh pod16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.roofline import build_report

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
UNROLL_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun_unroll")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(art_dir: str = ART_DIR, gossip: str = "einsum",
                 prefer_unroll: bool = True) -> list[dict]:
    """Load artifacts; roofline-quality (scan-unrolled) records override the
    scanned lowering-proof records when available."""
    by_tag: dict[str, dict] = {}
    dirs = [art_dir] + ([UNROLL_DIR] if prefer_unroll else [])
    for d in dirs:
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("gossip", "einsum") != gossip:
                continue
            if "test" in rec.get("mesh", ""):
                continue
            if rec.get("status") == "ok" or rec["tag"] not in by_tag:
                by_tag[rec["tag"]] = rec
    return list(by_tag.values())


def fresh_report(rec: dict):
    arch = ARCHS[rec["arch"]]
    shape = INPUT_SHAPES[rec["shape"]]
    return build_report(arch, shape, rec["mesh"], rec["chips"], rec["cost"],
                        rec["coll_bytes_per_device"])


def roofline_table(records: list[dict], mesh: str) -> str:
    hdr = ("| arch | shape | K | mode | compute (ms) | memory (ms) "
           "| collective (ms) | bound | 6ND/HLO | HBM GB/dev |\n"
           "|---|---|--:|---|--:|--:|--:|---|--:|--:|\n")
    lines = []
    for rec in records:
        if rec["mesh"] != mesh:
            continue
        if rec["status"] == "skipped":
            lines.append((rec["arch"], rec["shape"],
                          f"| {rec['arch']} | {rec['shape']} | — | — | — | — "
                          f"| — | skipped | — | — |"))
            continue
        if rec["status"] != "ok":
            lines.append((rec["arch"], rec["shape"],
                          f"| {rec['arch']} | {rec['shape']} | — | FAILED | | | | | | |"))
            continue
        r = fresh_report(rec)
        arg_gb = rec.get("memory", {}).get("argument_size_in_bytes", 0) / 1e9
        mode = "u" if rec.get("unroll") else "s"
        lines.append((rec["arch"], rec["shape"], (
            f"| {rec['arch']} | {rec['shape']} | {rec['n_clients']} | {mode} "
            f"| {r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} "
            f"| {r.collective_s*1e3:.2f} | **{r.bottleneck}** "
            f"| {r.useful_ratio:.2f} | {arg_gb:.2f} |")))
    lines.sort(key=lambda t: (list(ARCHS).index(t[0]), SHAPE_ORDER.index(t[1])))
    return hdr + "\n".join(l for _, _, l in lines) + "\n"


def dryrun_table(records: list[dict], mesh: str) -> str:
    hdr = ("| arch | shape | K | compile (s) | HLO GFLOP/dev | HBM GB/dev | "
           "coll GB/dev | top collectives |\n"
           "|---|---|--:|--:|--:|--:|--:|---|\n")
    lines = []
    for rec in records:
        if rec["mesh"] != mesh or rec["status"] != "ok":
            continue
        counts = rec["collectives"].get("counts", {})
        top = ", ".join(f"{k}x{v}" for k, v in
                        sorted(counts.items(), key=lambda kv: -kv[1])[:3])
        lines.append((rec["arch"], rec["shape"], (
            f"| {rec['arch']} | {rec['shape']} | {rec['n_clients']} "
            f"| {rec['compile_s']:.0f} | {rec['cost']['flops']/1e9:.1f} "
            f"| {rec['cost']['bytes accessed']/1e9:.1f} "
            f"| {rec['coll_bytes_per_device']/1e9:.2f} | {top} |")))
    lines.sort(key=lambda t: (list(ARCHS).index(t[0]), SHAPE_ORDER.index(t[1])))
    return hdr + "\n".join(l for _, _, l in lines) + "\n"


def render() -> tuple[str, str]:
    """Returns (dryrun_md, roofline_md) for EXPERIMENTS.md embedding."""
    records = load_records()
    dr = []
    rf = []
    for mesh in ("pod16x16", "pod2x16x16"):
        dr.append(f"\n#### Dry-run — {mesh}\n\n" + dryrun_table(records, mesh))
    # roofline is single-pod per the assignment
    rf.append("\n#### Roofline — pod16x16 (mode u = scan-unrolled cost-"
              "faithful, s = scanned)\n\n"
              + roofline_table(records, "pod16x16"))
    return "".join(dr), "".join(rf)


def write_experiments(path: str) -> None:
    with open(path) as f:
        text = f.read()
    dr, rf = render()
    text = text.replace("<!-- DRYRUN_TABLES -->", dr)
    text = text.replace("<!-- ROOFLINE_TABLES -->", rf)
    with open(path, "w") as f:
        f.write(text)
    print(f"updated {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=ART_DIR)
    ap.add_argument("--gossip", default="einsum")
    ap.add_argument("--write-experiments", default="",
                    help="patch the marker sections of this EXPERIMENTS.md")
    args = ap.parse_args()
    if args.write_experiments:
        write_experiments(args.write_experiments)
        return
    records = load_records(args.dir, args.gossip)
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### Dry-run — {mesh}\n")
        print(dryrun_table(records, mesh))
        print(f"\n### Roofline — {mesh}\n")
        print(roofline_table(records, mesh))


if __name__ == "__main__":
    main()
