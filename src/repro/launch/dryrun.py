import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_DRYRUN_DEVICES", "512")

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count at first init) — hence no `from __future__ import` here.

DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this script
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the DisPFL ``train_step`` (train shapes) or ``serve_step``
     (prefill/decode shapes) with ShapeDtypeStruct inputs under the sharding
     rules of sharding/rules.py,
  3. compiles, printing ``memory_analysis()`` and ``cost_analysis()``,
  4. parses collective bytes out of the partitioned HLO,
  5. writes a JSON artifact consumed by benchmarks/roofline.py and
     EXPERIMENTS.md.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the run aborts loudly.
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_report, total_params
from repro.launch.steps import lower_for, plan_for
from repro.models.registry import bind
from repro.utils import hlo as hlo_mod

# long_500k needs sub-quadratic attention / recurrent decode; only these
# archs run it (DESIGN.md §Arch-applicability) — pure full-attention archs
# skip with a recorded reason.
LONG_CONTEXT_OK = {"gemma3-1b", "mamba2-1.3b", "jamba-1.5-large-398b"}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def should_skip(arch_name: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch_name not in LONG_CONTEXT_OK:
        return ("full-attention arch: 500k decode KV memory/latency is not "
                "servable without sliding-window/SSM; skipped per assignment")
    return None


def analytic_state_bytes_per_device(plan, lowered_args_bytes: float) -> float:
    del plan
    return lowered_args_bytes


def run_one(arch_name: str, shape_name: str, multi_pod: bool,
            gossip: str = "einsum", out_dir: str = OUT_DIR,
            verbose: bool = True, smoke: bool = False,
            unroll: bool = False, remat: str = "full") -> dict:
    arch = ARCHS[arch_name]
    shape = INPUT_SHAPES[shape_name]
    if smoke:
        # reduced configs + tiny shapes on a small test mesh: exercises the
        # whole lowering pipeline in seconds (used by the integration test)
        import dataclasses as _dc
        from repro.configs import SMOKE_ARCHS
        from repro.launch.mesh import make_test_mesh
        arch = SMOKE_ARCHS[arch_name]
        shape = _dc.replace(shape, seq_len=max(64, shape.seq_len // 4096),
                            global_batch=min(shape.global_batch, 8))
    mesh_name = ("test" if smoke else "") + _mesh_name(multi_pod)
    tag = f"{arch_name}__{shape_name}__{mesh_name}" + (
        f"__{gossip}" if gossip != "einsum" else "") + (
        f"__remat_{remat}" if remat != "full" else "")
    skip = should_skip(arch_name, shape_name)
    record: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                    "gossip": gossip, "tag": tag, "unroll": unroll}
    if skip and not smoke:
        record.update(status="skipped", reason=skip)
        _write(out_dir, tag, record)
        if verbose:
            print(f"[dryrun] SKIP {tag}: {skip}")
        return record

    t0 = time.time()
    if smoke:
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 2, pods=2 if multi_pod else 0)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    plan, lowered = lower_for(arch, shape, mesh, gossip=gossip, unroll=unroll,
                              remat=(remat != "none"),
                              remat_policy=(remat if remat != "none" else "full"))
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ---- memory ----------------------------------------------------------
    mem: dict = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
        print("[memory_analysis]", mem if mem else ma)
    except Exception as e:  # CPU backend may not implement it fully
        mem["error"] = str(e)
        print("[memory_analysis] unavailable:", e)

    # ---- cost ------------------------------------------------------------
    cost_raw = compiled.cost_analysis()
    if isinstance(cost_raw, (list, tuple)):  # jax<=0.4.x: list of dicts
        cost_raw = cost_raw[0] if cost_raw else {}
    cost = {k: float(v) for k, v in cost_raw.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "optimal_seconds")}
    print("[cost_analysis]", {k: f"{v:.3e}" for k, v in cost.items()})

    # ---- collectives -----------------------------------------------------
    hlo_text = compiled.as_text()
    coll = hlo_mod.collective_bytes(hlo_text)

    report = build_report(arch, shape, mesh_name, chips, cost,
                          coll.total_bytes, density=1.0)
    record.update(
        status="ok",
        chips=chips,
        n_clients=plan.n_clients,
        per_client_batch=plan.per_client_batch,
        fsdp2d=plan.fsdp2d,
        seq_data=plan.seq_data,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem,
        cost=cost,
        collectives=coll.row(),
        coll_bytes_per_device=coll.total_bytes,
        total_params=total_params(arch),
        roofline=report.row(),
        hlo_ops=hlo_mod.op_histogram(hlo_text, top=12),
    )
    _write(out_dir, tag, record)
    if verbose:
        print(f"[dryrun] OK {tag}: clients={plan.n_clients} "
              f"compile={t_compile:.0f}s bottleneck={report.bottleneck} "
              f"terms(ms)=({report.compute_s*1e3:.2f}, {report.memory_s*1e3:.2f}, "
              f"{report.collective_s*1e3:.2f})")
    return record


def _write(out_dir: str, tag: str, record: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=2, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--gossip", default="einsum",
                    choices=["einsum", "einsum_bf16", "einsum_noopt", "ppermute", "none"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for trip-count-faithful "
                         "cost_analysis (roofline pass)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced archs + tiny shapes on a 2x2(x2) test mesh "
                         "(set REPRO_DRYRUN_DEVICES=8 first)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                tag = f"{a}__{s}__{_mesh_name(mp)}" + (
                    f"__{args.gossip}" if args.gossip != "einsum" else "") + (
                    f"__remat_{args.remat}" if args.remat != "full" else "")
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] cached {tag}")
                            continue
                try:
                    run_one(a, s, mp, gossip=args.gossip, out_dir=args.out,
                            smoke=args.smoke, unroll=args.unroll,
                            remat=args.remat)
                except Exception:
                    traceback.print_exc()
                    failures.append(tag)
                    _write(args.out, tag,
                           {"arch": a, "shape": s, "mesh": _mesh_name(mp),
                            "status": "failed",
                            "error": traceback.format_exc()[-2000:]})
    if failures:
        print(f"[dryrun] FAILURES ({len(failures)}):")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("[dryrun] all combinations lowered + compiled successfully")


if __name__ == "__main__":
    main()
