"""Mesh-scale step builders: stacked-client DisPFL training and personalized
sparse serving.

The whole decentralized system is one SPMD program: client models are
stacked on a leading K dim (sharded over the client mesh axes), the
intersection gossip is an adjacency einsum over that dim (GSPMD emits the
collectives), and the local masked-SGD step is a vmap over clients.

``plan_for`` decides the client mapping per (arch x input-shape x mesh):
  * normal archs: K = client capacity of the mesh (16 / 32), per-client
    batch = global_batch // K;
  * jamba-scale archs (``fsdp2d``): K = 1 per pod (2 on the multi-pod mesh),
    weights 2-D sharded (FSDP 'data' x TP 'model') inside the pod;
  * long_500k (global_batch=1): K = 1, 2-D weights, KV-cache seq dim sharded
    over 'data' (context parallelism).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import client_capacity
from repro.models.registry import ModelAPI, bind
from repro.sharding.rules import (
    tree_batch_shardings,
    tree_cache_shardings,
    tree_param_shardings,
)

PyTree = Any

WEIGHT_DECAY = 5e-4
FSDP2D_ARCHS = ("jamba-1.5-large-398b",)


@dataclasses.dataclass
class ScalePlan:
    arch: ModelConfig
    shape: InputShape
    mesh: Mesh
    n_clients: int
    per_client_batch: int
    fsdp2d: bool
    seq_data: bool          # context-parallel KV cache (long-context K=1)
    dtype: Any = jnp.bfloat16

    @property
    def max_cache_len(self) -> int:
        return self.shape.seq_len


def plan_for(arch: ModelConfig, shape: InputShape, mesh: Mesh,
             dtype=jnp.bfloat16) -> ScalePlan:
    gb = shape.global_batch
    big = arch.name in FSDP2D_ARCHS
    if big:
        k = 2 if "pod" in mesh.axis_names else 1
        k = min(k, gb)
    else:
        k = client_capacity(mesh)
        if gb < k or gb % k:
            k = 1                      # long_500k path: single sharded client
    fsdp2d = big or k == 1
    seq_data = shape.mode == "decode" and k == 1 and shape.seq_len >= 65536
    return ScalePlan(arch=arch, shape=shape, mesh=mesh, n_clients=k,
                     per_client_batch=gb // k, fsdp2d=fsdp2d,
                     seq_data=seq_data, dtype=dtype)


# ---------------------------------------------------------------------------
# Abstract state construction (ShapeDtypeStruct only — no allocation)
# ---------------------------------------------------------------------------


def _stack_specs(tree: PyTree, k: int) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((k,) + tuple(s.shape), s.dtype), tree)


def abstract_params(api: ModelAPI, plan: ScalePlan) -> PyTree:
    shapes = jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0), plan.dtype))
    return _stack_specs(shapes, plan.n_clients)


def abstract_masks(params_spec: PyTree) -> PyTree:
    """Masks stored as int8 (w ⊙ m casts at use sites)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.int8), params_spec)


def abstract_cache(api: ModelAPI, plan: ScalePlan) -> PyTree:
    shapes = jax.eval_shape(
        lambda: api.init_cache(plan.per_client_batch, plan.max_cache_len,
                               plan.dtype))
    return _stack_specs(shapes, plan.n_clients)


def input_specs(api: ModelAPI, plan: ScalePlan) -> PyTree:
    """Stacked (K, ...) batch ShapeDtypeStructs for the plan's shape."""
    per = api.input_specs(plan.shape, plan.dtype, batch=plan.per_client_batch)
    stacked = _stack_specs(per, plan.n_clients)
    if plan.shape.mode == "decode":
        stacked["pos"] = jax.ShapeDtypeStruct((plan.n_clients,), jnp.int32)
    return stacked


def adjacency_spec(plan: ScalePlan) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((plan.n_clients, plan.n_clients), jnp.float32)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step(api: ModelAPI, plan: ScalePlan, gossip: str = "einsum"):
    """One DisPFL round step: intersection gossip + one masked-SGD step.

    gossip: 'einsum' (adjacency matmul over the stacked client dim — the
    baseline, delegating to ``repro.scale.masked_gossip_stacked``, the one
    stacked gossip implementation shared with ``ScaleEngine``), 'none'
    (ablation / non-FL training), or 'ppermute' (neighbor exchange via
    shard_map collective_permute — §Perf optimized path, see
    launch/gossip_opt.py).
    """
    from repro.scale.stacked import masked_gossip_stacked

    wd = WEIGHT_DECAY

    def train_step(params, masks, batch, adjacency, lr):
        if gossip in ("einsum", "einsum_bf16") and plan.n_clients == 1:
            # adjacency is the 1x1 identity: the intersection average
            # reduces exactly to w (already masked) — skip the mixing pass
            # ('einsum_noopt' keeps it, as the §Perf before-measurement)
            pass
        elif gossip in ("einsum", "einsum_bf16", "einsum_noopt"):
            acc_dt = jnp.bfloat16 if gossip == "einsum_bf16" else jnp.float32
            params = masked_gossip_stacked(params, masks, adjacency,
                                           reduction="einsum",
                                           accum_dtype=acc_dt)
        elif gossip == "ppermute":
            from repro.launch.gossip_opt import ppermute_gossip
            params = ppermute_gossip(params, masks, plan)

        def total_loss(ps):
            losses, _ = jax.vmap(lambda p, b: api.train_loss(p, b))(ps, batch)
            return jnp.sum(losses), losses

        (_, losses), grads = jax.value_and_grad(total_loss, has_aux=True)(params)

        def upd(w, g, m):
            mf = m.astype(jnp.float32)
            wf = w.astype(jnp.float32)
            gf = g.astype(jnp.float32)
            return ((wf - lr * (gf + wd * wf) * mf) * mf).astype(w.dtype)

        params = jax.tree.map(upd, params, grads, masks)
        return params, losses

    return train_step


def make_mask_update_step(api: ModelAPI, plan: ScalePlan, density: float = 0.5):
    """Once-per-round mask search (Alg. 2) as one SPMD program.

    Per client: dense gradient on one batch, then the threshold-based
    stacked prune/regrow of ``repro.scale.stacked_prune_regrow_threshold``
    (kth order statistics via sort — identical semantics to
    kernels/ops.prune_regrow, up to ties).  Layer budgets are static
    (``density`` x numel), so the program is shape-static and lowers like
    the train step.  Practical for <=30B-param archs (the sort is
    O(n log n) per leaf); jamba-scale masks would use a sampled-quantile
    threshold instead (documented in DESIGN.md).
    """
    from repro.scale.stacked import stacked_prune_regrow_threshold

    def mask_update(params, masks, batch, prune_rate):
        def dense_grad(p, b):
            return jax.grad(lambda q: api.train_loss(q, b)[0])(p)

        grads = jax.vmap(dense_grad)(params, batch)
        new_masks, new_params = stacked_prune_regrow_threshold(
            params, masks, grads, prune_rate, density)
        return new_params, new_masks

    return mask_update


def make_prefill_step(api: ModelAPI, plan: ScalePlan):
    def prefill_step(params, batch, cache):
        return jax.vmap(api.prefill)(params, batch, cache)

    return prefill_step


def make_decode_step(api: ModelAPI, plan: ScalePlan):
    def decode_step(params, batch, cache):
        tokens = batch["tokens"]
        pos = batch["pos"]
        logits, cache = jax.vmap(api.decode)(params, tokens, pos, cache)
        next_tok = jnp.argmax(logits[..., -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly + lowering
# ---------------------------------------------------------------------------


def state_shardings(api: ModelAPI, plan: ScalePlan):
    params_spec = abstract_params(api, plan)
    mesh = plan.mesh
    p_sh = tree_param_shardings(params_spec, mesh, plan.fsdp2d)
    m_sh = p_sh  # masks mirror their parameters
    return params_spec, p_sh, m_sh


def lower_train(api: ModelAPI, plan: ScalePlan, gossip: str = "einsum"):
    mesh = plan.mesh
    params_spec, p_sh, m_sh = state_shardings(api, plan)
    masks_spec = abstract_masks(params_spec)
    batch_spec_tree = input_specs(api, plan)
    b_sh = tree_batch_shardings(batch_spec_tree, mesh, plan.fsdp2d)
    adj = adjacency_spec(plan)
    repl = NamedSharding(mesh, P())
    k_sh = NamedSharding(mesh, P())
    step = make_train_step(api, plan, gossip)
    from repro.sharding import use_mesh_rules
    with use_mesh_rules(mesh):
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, m_sh, b_sh, repl, repl),
            out_shardings=(p_sh, k_sh),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(params_spec, masks_spec, batch_spec_tree, adj,
                               jax.ShapeDtypeStruct((), jnp.float32))
    return lowered


def lower_serve(api: ModelAPI, plan: ScalePlan):
    mesh = plan.mesh
    params_spec, p_sh, _ = state_shardings(api, plan)
    cache_spec_tree = abstract_cache(api, plan)
    c_sh = tree_cache_shardings(cache_spec_tree, mesh, plan.seq_data,
                                fsdp2d=plan.fsdp2d)
    batch_spec_tree = input_specs(api, plan)
    b_sh = tree_batch_shardings(batch_spec_tree, mesh, plan.fsdp2d)
    from repro.sharding import use_mesh_rules
    overrides = {"kv_seq": ("data",)} if plan.seq_data else {"kv_seq": ()}
    with use_mesh_rules(mesh, overrides):
        if plan.shape.mode == "prefill":
            step = make_prefill_step(api, plan)
            logits_sh = NamedSharding(mesh, P())
            jitted = jax.jit(step,
                             in_shardings=(p_sh, b_sh, c_sh),
                             out_shardings=(logits_sh, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_spec, batch_spec_tree, cache_spec_tree)
        else:
            step = make_decode_step(api, plan)
            tok_sh = NamedSharding(mesh, P())
            jitted = jax.jit(step,
                             in_shardings=(p_sh, b_sh, c_sh),
                             out_shardings=(tok_sh, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_spec, batch_spec_tree, cache_spec_tree)
    return lowered


def lower_for(arch: ModelConfig, shape: InputShape, mesh: Mesh,
              gossip: str = "einsum", dtype=jnp.bfloat16, remat: bool = True,
              unroll: bool = False, remat_policy: str = "full"):
    """Entry point used by dryrun.py: returns (plan, lowered).

    unroll=True unrolls the layer scans so ``cost_analysis()`` counts every
    block (XLA costs a while-loop body once); used for the roofline pass.
    """
    plan = plan_for(arch, shape, mesh, dtype)
    api = bind(arch, remat=remat, unroll=unroll, remat_policy=remat_policy)
    if shape.mode == "train":
        return plan, lower_train(api, plan, gossip)
    return plan, lower_serve(api, plan)
