"""Static fleet-health dashboard rendered from a run archive or trace.

One self-contained HTML file — inline CSS, inline SVG sparklines, zero
JavaScript and zero network fetches — so the artifact a CI job uploads
(or an operator scps off a box) opens anywhere and renders identically
forever.  Everything on the page is *derived* from the run's durable
artifacts via ``repro.obs``:

* fleet rollups (busiest node, stragglers, retransmit rates, SSP
  staleness, store hit ratio) come from ``obs.health`` over the archived
  trace spans;
* sparklines come from the archived ``snapshot_series()`` doc;
* latency/transfer percentiles come from the archived ``LogHistogram``
  sketches;
* the phase table comes from ``obs.export.phase_summary``.

Modes::

    # render a dashboard from a run archive (launch/train.py --run-dir)
    PYTHONPATH=src python -m repro.launch.dash render \
        --run-dir runs/sim-20260808-... -o dash.html

    # or straight from a bare Perfetto trace (launch/train.py --trace)
    PYTHONPATH=src python -m repro.launch.dash render \
        --trace BENCH_trace.json -o dash.html --check

    # cross-run diff: the two newest gate runs in BENCH_history.jsonl
    PYTHONPATH=src python -m repro.launch.dash diff \
        --history BENCH_history.jsonl -o diff.html

``--check`` validates the rendered artifact (structure + required
sections) and, when the span buffer is complete, reconciles the page's
busiest-node/retransmit numbers exactly against the archived
``sim.links`` counters — the same exactness contract
``tests/test_obs_health.py`` pins; ``make obs-smoke`` runs this.
"""
from __future__ import annotations

import argparse
import html
import json
import math
from typing import Optional, Sequence

from repro.obs import (
    HealthThresholds,
    LogHistogram,
    RunArchive,
    TimeSeries,
    diff_runs,
    fleet_health,
    phase_summary,
    read_history,
    spans_from_trace_doc,
)

# ---------------------------------------------------------------------------
# design tokens (reference palette; status colors are reserved for state
# and always ship with an icon + label, never color alone)
# ---------------------------------------------------------------------------

_CSS = """
:root {
  --surface: #fcfcfb; --ink: #0b0b0b; --ink-2: #52514e; --ink-3: #898781;
  --grid: #e1e0d9; --series: #2a78d6;
  --good: #0ca30c; --warning: #fab219; --serious: #ec835a;
  --critical: #d03b3b;
}
@media (prefers-color-scheme: dark) {
  :root {
    --surface: #1a1a19; --ink: #ffffff; --ink-2: #c3c2b7; --ink-3: #898781;
    --grid: #2c2c2a; --series: #3987e5;
  }
}
[data-theme="light"] {
  --surface: #fcfcfb; --ink: #0b0b0b; --ink-2: #52514e; --ink-3: #898781;
  --grid: #e1e0d9; --series: #2a78d6;
}
[data-theme="dark"] {
  --surface: #1a1a19; --ink: #ffffff; --ink-2: #c3c2b7; --ink-3: #898781;
  --grid: #2c2c2a; --series: #3987e5;
}
html { background: var(--surface); }
body {
  font-family: system-ui, -apple-system, sans-serif;
  color: var(--ink); background: var(--surface);
  margin: 2rem auto; max-width: 72rem; padding: 0 1rem;
  font-size: 14px; line-height: 1.45;
}
h1 { font-size: 1.35rem; margin: 0 0 .25rem; }
h2 { font-size: 1.02rem; margin: 2rem 0 .5rem; }
.sub { color: var(--ink-2); margin: 0 0 1rem; }
.meta { color: var(--ink-3); font-size: .85rem; }
table { border-collapse: collapse; width: 100%; margin: .5rem 0 1rem; }
th {
  text-align: left; color: var(--ink-2); font-weight: 600;
  border-bottom: 1px solid var(--grid); padding: .3rem .6rem .3rem 0;
}
td {
  border-bottom: 1px solid var(--grid); padding: .3rem .6rem .3rem 0;
  font-variant-numeric: tabular-nums;
}
td.num, th.num { text-align: right; }
.cards { display: flex; flex-wrap: wrap; gap: 1rem; }
.card {
  border: 1px solid var(--grid); border-radius: 6px;
  padding: .7rem .9rem; min-width: 15rem;
}
.card .name { color: var(--ink-2); font-size: .85rem; }
.card .big {
  font-size: 1.3rem; font-variant-numeric: tabular-nums; margin: .1rem 0;
}
.spark polyline { stroke: var(--series); fill: none; stroke-width: 2; }
.spark .dot { fill: var(--series); }
.spark .base { stroke: var(--grid); stroke-width: 1; }
.status { font-weight: 600; white-space: nowrap; }
.status.good { color: var(--good); }
.status.warning { color: var(--warning); }
.status.serious { color: var(--serious); }
.status.critical { color: var(--critical); }
.delta-up { color: var(--serious); font-weight: 600; }
.delta-down { color: var(--good); font-weight: 600; }
"""

#: status severities always render icon + label (never color alone)
_STATUS_ICON = {"good": "●", "warning": "▲",
                "serious": "◆", "critical": "✖"}


def _esc(x) -> str:
    return html.escape(str(x))


def _fmt(v, nd: int = 3) -> str:
    """Human number: trims float noise, keeps ints exact."""
    if v is None:
        return "–"
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        if not math.isfinite(v):
            return str(v)
        if v == int(v) and abs(v) < 1e15:
            return f"{int(v):,}"
        return f"{v:,.{nd}f}"
    if isinstance(v, int):
        return f"{v:,}"
    return _esc(v)


def _status(severity: str) -> str:
    icon = _STATUS_ICON.get(severity, "●")
    return (f'<span class="status {_esc(severity)}">{icon}'
            f' {_esc(severity)}</span>')


def _table(headers: Sequence[str], rows: Sequence[Sequence],
           numeric_from: int = 1) -> str:
    """Rows render escaped unless a cell is pre-marked safe by wrapping
    it in a one-element tuple (already-escaped HTML)."""
    num_cls = ' class="num"'
    th = "".join(
        f"<th{num_cls if i >= numeric_from else ''}>{_esc(h)}</th>"
        for i, h in enumerate(headers))
    body = []
    for row in rows:
        tds = []
        for i, cell in enumerate(row):
            safe = isinstance(cell, tuple)
            text = cell[0] if safe else _fmt(cell)
            cls = ' class="num"' if i >= numeric_from else ""
            tds.append(f"<td{cls}>{text}</td>")
        body.append("<tr>" + "".join(tds) + "</tr>")
    return (f"<table><thead><tr>{th}</tr></thead>"
            f"<tbody>{''.join(body)}</tbody></table>")


# ---------------------------------------------------------------------------
# inline SVG sparkline (single series — the card title names it, so no
# legend; native <title> tooltip keeps the page JS-free)
# ---------------------------------------------------------------------------

def _sparkline(points: Sequence[tuple], w: int = 220, h: int = 44) -> str:
    pts = [(float(t), float(v)) for t, v in points]
    if len(pts) < 2:
        return '<div class="meta">not enough samples</div>'
    t0, t1 = pts[0][0], pts[-1][0]
    vs = [v for _, v in pts]
    v0, v1 = min(vs), max(vs)
    tspan = (t1 - t0) or 1.0
    vspan = (v1 - v0) or 1.0
    pad = 4
    coords = " ".join(
        f"{pad + (t - t0) / tspan * (w - 2 * pad):.1f},"
        f"{h - pad - (v - v0) / vspan * (h - 2 * pad):.1f}"
        for t, v in pts)
    lx, ly = coords.rsplit(" ", 1)[-1].split(",")
    tooltip = (f"{len(pts)} samples, min {_fmt(v0)}, max {_fmt(v1)}, "
               f"last {_fmt(pts[-1][1])}")
    return (
        f'<svg class="spark" width="{w}" height="{h}" '
        f'viewBox="0 0 {w} {h}" role="img">'
        f"<title>{_esc(tooltip)}</title>"
        f'<line class="base" x1="{pad}" y1="{h - pad}" '
        f'x2="{w - pad}" y2="{h - pad}"/>'
        f'<polyline points="{coords}"/>'
        f'<circle class="dot" cx="{lx}" cy="{ly}" r="3"/></svg>')


def _series_cards(series_doc: dict) -> str:
    cards = []
    for key in sorted(series_doc.get("series", {})):
        d = series_doc["series"][key]
        pts = d.get("points", [])
        last = pts[-1][1] if pts else None
        kind = d.get("kind", "gauge")
        cards.append(
            '<div class="card">'
            f'<div class="name">{_esc(key)} '
            f'<span class="meta">({_esc(kind)}, {_esc(d.get("clock"))} '
            f"clock)</span></div>"
            f'<div class="big">{_fmt(last)}</div>'
            f"{_sparkline(pts)}</div>")
    if not cards:
        return '<p class="meta">no series in this archive</p>'
    return f'<div class="cards">{"".join(cards)}</div>'


def _histogram_table(series_doc: dict) -> str:
    rows = []
    for key in sorted(series_doc.get("histograms", {})):
        h = LogHistogram.from_dict(series_doc["histograms"][key])
        rows.append([key, h.count, _fmt(h.mean), _fmt(h.quantile(0.5)),
                     _fmt(h.quantile(0.9)), _fmt(h.quantile(0.99)),
                     _fmt(h.max if h.count else None)])
    if not rows:
        return '<p class="meta">no histograms in this archive</p>'
    return _table(["sketch", "count", "mean", "p50", "p90", "p99", "max"],
                  rows)


# ---------------------------------------------------------------------------
# dashboard sections
# ---------------------------------------------------------------------------

def _health_section(events) -> str:
    if not events:
        return (f'<p>{_status("good")} '
                "no health thresholds tripped</p>")
    rows = [[(_status(ev.severity),), ev.kind, (_esc(ev.message),),
             _fmt(ev.value), _fmt(ev.threshold)] for ev in events]
    return _table(["status", "rule", "detail", "value", "threshold"],
                  rows, numeric_from=3)


def _comm_section(comm: dict) -> str:
    if not comm["n_transfers"]:
        return '<p class="meta">no transfer spans in this run</p>'
    head = _table(
        ["metric", "value"],
        [["busiest node",
          f"node {comm['busiest_node']} "
          f"({_fmt(comm['busiest_node_mb'])} MB)"],
         ["mean per-node MB", _fmt(comm["mean_node_mb"])],
         ["total MB (values)", _fmt(comm["total_mb"])],
         ["transfers", comm["n_transfers"]],
         ["retransmits", comm["n_retransmits"]],
         ["retransmit rate", f"{comm['retransmit_rate']:.2%}"],
         ["retransmitted MB", _fmt(comm["retrans_mb"])]])
    top = _table(["node", "busiest-direction MB"],
                 [[f"node {k}", _fmt(mb)] for k, mb in comm["top_nodes"]])
    links = ""
    if comm["n_retransmits"]:
        links = ("<h3>worst links by retransmit rate</h3>"
                 + _table(["link", "retransmit rate"],
                          [[link, f"{r:.2%}"]
                           for link, r in comm["worst_links"] if r > 0]))
    xh = comm["transfer_s"]
    xfer = _table(
        ["transfer seconds", "count", "p50", "p90", "p99"],
        [["(from spans)", xh.count, _fmt(xh.quantile(0.5)),
          _fmt(xh.quantile(0.9)), _fmt(xh.quantile(0.99))]])
    return head + "<h3>top nodes</h3>" + top + links + xfer


def _straggler_section(strag: dict) -> str:
    if not strag["n_clients"]:
        return '<p class="meta">no compute spans in this run</p>'
    rows = [[f"client {k}", _fmt(s),
             _fmt(s / strag["mean_compute_s"], 2)
             if strag["mean_compute_s"] else "–"]
            for k, s in strag["top_stragglers"]]
    return _table(["client", "compute s", "x mean"], rows)


def _staleness_section(stale: dict) -> str:
    if not stale["n_waits"]:
        return '<p class="meta">no ssp.wait spans (synchronous run)</p>'
    h = stale["wait_s"]
    return _table(
        ["SSP waits", "total s", "p50 s", "p99 s"],
        [[stale["n_waits"], _fmt(stale["total_wait_s"]),
          _fmt(h.quantile(0.5)), _fmt(stale["p99_wait_s"])]])


def _uplink_section(up: dict) -> str:
    if not up["busy_s"]:
        return '<p class="meta">no uplink.busy spans (parallel links)</p>'
    rows = [[f"node {k}", _fmt(s), f"{up['utilization'][k]:.1%}"]
            for k, s in up["top_uplinks"]]
    note = ('<p class="meta">fair-share uplink: sharing is exact within '
            "one push batch; batches queue FIFO behind a busy uplink "
            "(see docs/observability.md)</p>")
    return _table(["sender", "busy s", "utilization"], rows) + note


def _store_section(store: Optional[dict]) -> str:
    if not store or store["hits"] + store["misses"] == 0:
        return '<p class="meta">no store activity in this run</p>'
    return _table(
        ["hits", "misses", "evictions", "hit ratio", "resident",
         "bytes at rest"],
        [[store["hits"], store["misses"], store["evictions"],
          f"{store['hit_ratio']:.1%}", store["resident"],
          store["bytes_at_rest"]]], numeric_from=0)


def _density_section(dens: Optional[dict]) -> str:
    if not dens or not dens["n"]:
        return ""
    body = _table(
        ["rounds", "max |drift|", "final |drift|", "final measured",
         "final target"],
        [[dens["n"], _fmt(dens["max_drift"]), _fmt(dens["final_drift"]),
          _fmt(dens["final_measured"]), _fmt(dens["final_target"])]],
        numeric_from=0)
    return "<h2>density vs anneal schedule</h2>" + body


def _phase_section(ph: dict) -> str:
    if not ph:
        return '<p class="meta">no spans to summarize</p>'
    rows = [[name, d["count"], _fmt(d["total_s"]), _fmt(d["mean_s"], 4),
             _fmt(d["max_s"], 4)]
            for name, d in sorted(ph.items(),
                                  key=lambda kv: -kv[1]["total_s"])]
    return _table(["phase", "count", "total s", "mean s", "max s"], rows)


def _counters_section(counters: dict) -> str:
    if not counters:
        return '<p class="meta">no counters in this archive</p>'
    rows = [[k, _fmt(v)] for k, v in sorted(counters.items())]
    return _table(["counter", "value"], rows)


def _page(title: str, subtitle: str, body: str) -> str:
    return (
        "<!doctype html>\n<html lang=\"en\"><head>"
        '<meta charset="utf-8">'
        '<meta name="viewport" content="width=device-width,initial-scale=1">'
        f"<title>{_esc(title)}</title>"
        f"<style>{_CSS}</style></head><body>"
        f"<h1>{_esc(title)}</h1>"
        f'<p class="sub">{subtitle}</p>'
        f"{body}"
        '<p class="meta">generated by repro.launch.dash — '
        "self-contained, no scripts, no network</p>"
        "</body></html>\n")


def _density_pair(series_doc: dict):
    sd = series_doc.get("series", {})
    m = sd.get("fl.engine/density_measured")
    t = sd.get("fl.engine/density_target")
    if m is None or t is None:
        return None
    return (TimeSeries.from_dict(m), TimeSeries.from_dict(t))


def render_dashboard(archive: Optional[RunArchive] = None,
                     trace_doc: Optional[dict] = None,
                     thresholds: Optional[HealthThresholds] = None) -> str:
    """The dashboard HTML for a run archive, or for a bare trace document
    (whose ``otherData.counters`` snapshot stands in for the archive's
    ``counters.json``; series cards then render empty)."""
    if archive is None and trace_doc is None:
        raise ValueError("need a RunArchive or a trace document")
    manifest = archive.manifest() if archive is not None else None
    if trace_doc is None:
        trace_doc = archive.trace()
    series_doc = archive.series() if archive is not None else {}
    counters = (archive.counters() if archive is not None else
                (trace_doc or {}).get("otherData", {}).get("counters", {}))

    spans = spans_from_trace_doc(trace_doc) if trace_doc else []
    dropped = int((trace_doc or {}).get("otherData", {})
                  .get("droppedSpans", 0))
    roll, events = fleet_health(
        spans, counters=counters, thresholds=thresholds,
        density=_density_pair(series_doc), dropped_spans=dropped)

    if manifest is not None:
        title = f"run {manifest.run_id}"
        sub = (f"{_esc(manifest.kind)} · {_esc(manifest.created_iso)} · "
               f"git {_esc(manifest.git_sha)} · seed "
               f"{_esc(manifest.seed)} · jax "
               f"{_esc(manifest.versions.get('jax', '–'))}")
    else:
        title = "trace dashboard"
        sub = (f"{len(spans)} spans · "
               f"mode {_esc((trace_doc or {}).get('otherData', {}).get('mode', '–'))}")

    body = [
        "<h2>fleet health</h2>", _health_section(events),
        "<h2>communication</h2>", _comm_section(roll["comm"]),
        "<h2>stragglers</h2>", _straggler_section(roll["stragglers"]),
        "<h2>SSP staleness</h2>", _staleness_section(roll["staleness"]),
        "<h2>uplinks</h2>", _uplink_section(roll["uplinks"]),
        "<h2>model store</h2>", _store_section(roll.get("store")),
        _density_section(roll.get("density")),
        "<h2>time series</h2>", _series_cards(series_doc),
        "<h2>latency sketches</h2>", _histogram_table(series_doc),
        "<h2>phases</h2>", _phase_section(phase_summary(spans)),
        "<h2>counters</h2>", _counters_section(counters),
    ]
    return _page(title, sub, "".join(body))


# ---------------------------------------------------------------------------
# diff mode (cross-run regression attribution, rendered)
# ---------------------------------------------------------------------------

def _delta_cell(delta: float, suffix: str = "") -> tuple:
    """Regressed (slower/bigger) vs improved is *state*: status colors
    with an arrow icon + signed number, never color alone."""
    if delta > 0:
        return (f'<span class="delta-up">▲ +{_fmt(delta)}{suffix}'
                "</span>",)
    return (f'<span class="delta-down">▼ {_fmt(delta)}{suffix}'
            "</span>",)


def render_diff(old: dict, new: dict, old_label: str, new_label: str,
                top_k: int = 5) -> str:
    d = diff_runs(old, new, top_k=top_k)
    ph_rows = [[p["phase"], _fmt(p["old_s"]), _fmt(p["new_s"]),
                _delta_cell(p["delta_s"], " s"),
                "inf" if math.isinf(p["ratio"]) else _fmt(p["ratio"], 2)]
               for p in d["phases"]]
    ct_rows = [[c["counter"], _fmt(c["old"]), _fmt(c["new"]),
                _delta_cell(c["delta"]), f"{c['rel']:.1%}"]
               for c in d["counters"]]
    body = [
        "<h2>phase deltas (by |total s|)</h2>",
        _table(["phase", "old s", "new s", "delta", "ratio"], ph_rows)
        if ph_rows else '<p class="meta">no phase deltas</p>',
        "<h2>counter deltas (by relative change)</h2>",
        _table(["counter", "old", "new", "delta", "rel"], ct_rows)
        if ct_rows else '<p class="meta">no counter deltas</p>',
    ]
    return _page("run diff",
                 f"{_esc(old_label)} → {_esc(new_label)}",
                 "".join(body))


def _run_doc_from_archive(ar: RunArchive) -> dict:
    return {"phase_summary": ar.phase_summary(),
            "counters": ar.counters()}


# ---------------------------------------------------------------------------
# --check: validate the artifact + reconcile against counters
# ---------------------------------------------------------------------------

_REQUIRED_SECTIONS = ("fleet health", "communication", "phases", "counters")


def check_dashboard(page: str, trace_doc: Optional[dict],
                    counters: dict) -> list[str]:
    """Structural + reconciliation problems with a rendered dashboard;
    empty list means it passed.  Reconciliation (span-derived byte sums
    vs the ``sim.links`` counters) only applies when the trace is
    complete — a ring buffer that dropped spans under-counts by design
    and is reported on the page instead."""
    problems = []
    if not page.startswith("<!doctype html>"):
        problems.append("not an HTML document")
    if "<script" in page.lower():
        problems.append("dashboard must not contain scripts")
    for sec in _REQUIRED_SECTIONS:
        if f"<h2>{sec}</h2>" not in page:
            problems.append(f"missing section {sec!r}")
    if trace_doc is None:
        return problems
    dropped = int(trace_doc.get("otherData", {}).get("droppedSpans", 0))
    if dropped or "sim.links/bytes_values" not in counters:
        return problems
    from repro.obs import comm_rollup
    comm = comm_rollup(spans_from_trace_doc(trace_doc))
    pairs = [
        ("sim.links/bytes_values", sum(comm["up_bytes"].values())),
        ("sim.links/bytes_wire", sum(comm["up_wire_bytes"].values())),
        ("sim.links/n_retransmits", comm["n_retransmits"]),
        ("sim.links/transfers", comm["n_transfers"]),
    ]
    for key, derived in pairs:
        want = float(counters.get(key, 0.0))
        if float(derived) != want:
            problems.append(
                f"rollup {key} = {derived!r} does not reconcile with "
                f"counter {want!r}")
    return problems


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.dash")
    sub = ap.add_subparsers(dest="mode", required=True)

    r = sub.add_parser("render", help="dashboard from a run dir or trace")
    r.add_argument("--run-dir", default="", dest="run_dir")
    r.add_argument("--trace", default="",
                   help="bare Perfetto trace JSON (when no --run-dir)")
    r.add_argument("-o", "--out", default="dash.html")
    r.add_argument("--check", action="store_true",
                   help="validate the artifact and reconcile rollups "
                        "against counters; nonzero exit on failure")

    d = sub.add_parser("diff", help="cross-run regression attribution")
    d.add_argument("--history", default="",
                   help="BENCH_history.jsonl — diff the two newest runs")
    d.add_argument("--old", default="", help="older run dir")
    d.add_argument("--new", default="", help="newer run dir")
    d.add_argument("-o", "--out", default="diff.html")
    d.add_argument("--top-k", type=int, default=5, dest="top_k")

    args = ap.parse_args(argv)
    if args.mode == "render":
        if not args.run_dir and not args.trace:
            ap.error("render needs --run-dir or --trace")
        archive = trace_doc = None
        if args.run_dir:
            archive = RunArchive(args.run_dir)
            if not archive.exists:
                ap.error(f"{args.run_dir} is not a run archive "
                         "(no manifest.json)")
            trace_doc = archive.trace()
            counters = archive.counters()
        else:
            with open(args.trace) as f:
                trace_doc = json.load(f)
            counters = trace_doc.get("otherData", {}).get("counters", {})
        page = render_dashboard(archive=archive, trace_doc=trace_doc)
        with open(args.out, "w") as f:
            f.write(page)
        print(f"wrote {args.out} ({len(page)} bytes)")
        if args.check:
            problems = check_dashboard(page, trace_doc, counters)
            if problems:
                for p in problems:
                    print(f"CHECK FAIL: {p}")
                return 1
            print("check ok: structure valid, rollups reconcile")
        return 0

    # diff
    if args.history:
        runs = read_history(args.history, event="run")
        if len(runs) < 2:
            ap.error(f"{args.history} has {len(runs)} run lines; "
                     "need >= 2 to diff")
        old, new = runs[-2], runs[-1]
        old_label = f"{old.get('git_sha', '?')} @ {old.get('iso', '?')}"
        new_label = f"{new.get('git_sha', '?')} @ {new.get('iso', '?')}"
    elif args.old and args.new:
        old = _run_doc_from_archive(RunArchive(args.old))
        new = _run_doc_from_archive(RunArchive(args.new))
        old_label, new_label = args.old, args.new
    else:
        ap.error("diff needs --history or both --old and --new")
    page = render_diff(old, new, old_label, new_label, top_k=args.top_k)
    with open(args.out, "w") as f:
        f.write(page)
    print(f"wrote {args.out} ({len(page)} bytes)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
