"""Production mesh construction (functions only — importing this module
never touches jax device state; see MULTI-POD DRY-RUN step 1)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x; explicit-Auto is optional before it
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods of
    256 as (pod=2, data=16, model=16); the 'pod' axis carries pod-level
    DisPFL clients (DESIGN.md §3 cross-pod gossip)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pods: int = 0) -> Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    >= data*model*max(pods,1) set before jax initializes)."""
    if pods:
        return _make_mesh((pods, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))


def client_capacity(mesh: Mesh) -> int:
    """Max stacked clients the mesh hosts (product of client axes)."""
    cap = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        cap *= mesh.shape["pod"]
    return cap
