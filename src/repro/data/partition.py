"""Non-IID partitioners (paper §4.1 / App. B.1).

* ``dirichlet_partition`` — per class, split its sample indices across the K
  clients with proportions ~ Dir(alpha) (Hsu et al. 2019).  alpha=0.3 for
  CIFAR-10-like, 0.2 for CIFAR-100-like tasks in the paper.
* ``pathological_partition`` — each client holds ``classes_per_client``
  random classes (2 for CIFAR-10, 10 for CIFAR-100, 20 for Tiny-ImageNet).
* ``matched_test_indices`` — per-client test sets with the *same label
  proportions* as the client's training split (the paper's personalized
  evaluation protocol; total test size fixed per client).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for k, part in enumerate(np.split(idx, cuts)):
                idx_per_client[k].extend(part.tolist())
        sizes = [len(v) for v in idx_per_client]
        if min(sizes) >= min_size:
            break
    return [np.array(sorted(v)) for v in idx_per_client]


def pathological_partition(labels: np.ndarray, n_clients: int,
                           classes_per_client: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    # assign classes to clients (each class appears on roughly equal #clients)
    assignments: list[list[int]] = [[] for _ in range(n_clients)]
    pool = []
    while len(pool) < n_clients * classes_per_client:
        perm = rng.permutation(n_classes).tolist()
        pool.extend(perm)
    for k in range(n_clients):
        take = []
        for c in pool:
            if len(take) == classes_per_client:
                break
            if c not in take:
                take.append(c)
        for c in take:
            pool.remove(c)
        assignments[k] = take
    # split each class's samples evenly among the clients holding it
    holders: dict[int, list[int]] = {c: [] for c in range(n_classes)}
    for k, cs in enumerate(assignments):
        for c in cs:
            holders[c].append(k)
    idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        ks = holders[c]
        if not ks:
            continue
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        for k, part in zip(ks, np.array_split(idx, len(ks))):
            idx_per_client[k].extend(part.tolist())
    return [np.array(sorted(v)) for v in idx_per_client]


def label_distribution(labels: np.ndarray, idx: np.ndarray, n_classes: int) -> np.ndarray:
    counts = np.bincount(labels[idx], minlength=n_classes).astype(np.float64)
    return counts / max(counts.sum(), 1)


def matched_test_indices(test_labels: np.ndarray, train_dist: np.ndarray,
                         n_test: int, seed: int = 0) -> np.ndarray:
    """Sample a per-client test set matching the client's label distribution."""
    rng = np.random.default_rng(seed)
    n_classes = len(train_dist)
    counts = np.floor(train_dist * n_test).astype(int)
    # distribute the remainder to the largest-proportion classes
    rem = n_test - counts.sum()
    order = np.argsort(-train_dist)
    for i in range(rem):
        counts[order[i % n_classes]] += 1
    out = []
    for c in range(n_classes):
        if counts[c] == 0:
            continue
        pool = np.where(test_labels == c)[0]
        take = rng.choice(pool, size=counts[c], replace=len(pool) < counts[c])
        out.extend(take.tolist())
    return np.array(sorted(out))
