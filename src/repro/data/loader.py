"""Per-client data containers + federated dataset assembly."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.partition import (
    dirichlet_partition,
    label_distribution,
    matched_test_indices,
    pathological_partition,
)
from repro.data.synthetic import Dataset, make_image_classification


@dataclasses.dataclass
class ClientData:
    train_x: np.ndarray
    train_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    label_dist: np.ndarray

    @property
    def n_train(self) -> int:
        return len(self.train_y)

    def epoch_batches(self, rng: np.random.Generator, batch_size: int):
        """One shuffled epoch of (x, y) batches (last partial batch kept)."""
        order = rng.permutation(self.n_train)
        for i in range(0, self.n_train, batch_size):
            sel = order[i: i + batch_size]
            yield self.train_x[sel], self.train_y[sel]

    def sample_batch(self, rng: np.random.Generator, batch_size: int):
        sel = rng.integers(0, self.n_train, size=min(batch_size, self.n_train))
        return self.train_x[sel], self.train_y[sel]


def build_federated_image_task(
    seed: int,
    n_clients: int,
    partition: str = "dirichlet",          # 'dirichlet' | 'pathological'
    alpha: float = 0.3,
    classes_per_client: int = 2,
    n_classes: int = 10,
    n_train_per_class: int = 100,
    n_test_per_class: int = 40,
    n_test_per_client: int = 40,
    hw: int = 16,
    noise: float = 0.8,
) -> tuple[list[ClientData], Dataset]:
    """Returns (clients, full train dataset).  Test sets are matched to each
    client's training label distribution (paper App. B.1)."""
    train, test = make_image_classification(
        seed, n_classes, n_train_per_class, n_test_per_class, hw, noise=noise)
    if partition == "dirichlet":
        parts = dirichlet_partition(train.y, n_clients, alpha, seed)
    elif partition == "pathological":
        parts = pathological_partition(train.y, n_clients, classes_per_client, seed)
    else:
        raise ValueError(partition)
    clients = []
    for k, idx in enumerate(parts):
        dist = label_distribution(train.y, idx, n_classes)
        tidx = matched_test_indices(test.y, dist, n_test_per_client, seed + 17 * k)
        clients.append(ClientData(
            train_x=train.x[idx], train_y=train.y[idx],
            test_x=test.x[tidx], test_y=test.y[tidx],
            label_dist=dist))
    return clients, train
