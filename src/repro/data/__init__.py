from repro.data.loader import ClientData, build_federated_image_task  # noqa: F401
from repro.data.synthetic import Dataset, make_image_classification, make_lm_corpus  # noqa: F401
