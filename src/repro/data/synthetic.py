"""Synthetic datasets.

CIFAR-scale image classification cannot ship in this offline container, so
the accuracy experiments use a controllable synthetic image task with the
same *statistical structure* the paper exploits: many classes, per-class
visual templates, label-skewed non-IID partitions.  Personalization helps
exactly as in the paper because each client sees a narrow label slice.

``make_image_classification`` draws one smooth random template per class and
adds i.i.d. Gaussian pixel noise; difficulty is controlled by the
noise/template ratio.  ``make_lm_corpus`` builds an order-1 Markov token
stream per latent "domain" for the LM examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dataset:
    x: np.ndarray          # (N, H, W, C) float32 or (N, S) int32 for LM
    y: np.ndarray          # (N,) int labels or (N, S) next tokens
    n_classes: int


def _smooth_template(rng, hw: int, c: int) -> np.ndarray:
    """Low-frequency random image in [-1, 1]."""
    base = rng.normal(size=(4, 4, c))
    # bilinear upsample to (hw, hw)
    idx = np.linspace(0, 3, hw)
    x0 = np.floor(idx).astype(int)
    x1 = np.minimum(x0 + 1, 3)
    f = (idx - x0)[:, None]
    rows = base[x0] * (1 - f)[..., None] + base[x1] * f[..., None]
    g = (idx - x0)[None, :, None]
    out = rows[:, x0] * (1 - g) + rows[:, x1] * g
    return out / (np.abs(out).max() + 1e-8)


def make_image_classification(
    seed: int,
    n_classes: int = 10,
    n_train_per_class: int = 100,
    n_test_per_class: int = 40,
    hw: int = 16,
    channels: int = 3,
    noise: float = 0.8,
) -> tuple[Dataset, Dataset]:
    rng = np.random.default_rng(seed)
    templates = np.stack([_smooth_template(rng, hw, channels)
                          for _ in range(n_classes)])

    def draw(n_per):
        xs, ys = [], []
        for c in range(n_classes):
            imgs = templates[c][None] + noise * rng.normal(
                size=(n_per, hw, hw, channels))
            xs.append(imgs.astype(np.float32))
            ys.append(np.full((n_per,), c, np.int32))
        return Dataset(np.concatenate(xs), np.concatenate(ys), n_classes)

    return draw(n_train_per_class), draw(n_test_per_class)


def make_lm_corpus(
    seed: int,
    vocab: int = 256,
    n_domains: int = 4,
    tokens_per_domain: int = 65536,
    temperature: float = 1.5,
) -> list[np.ndarray]:
    """One Markov-chain token stream per domain (per-client domains make the
    LM task non-IID)."""
    rng = np.random.default_rng(seed)
    streams = []
    for _ in range(n_domains):
        logits = rng.normal(size=(vocab, vocab)) * temperature
        probs = np.exp(logits - logits.max(1, keepdims=True))
        probs /= probs.sum(1, keepdims=True)
        toks = np.empty((tokens_per_domain,), np.int32)
        t = rng.integers(vocab)
        for i in range(tokens_per_domain):
            t = rng.choice(vocab, p=probs[t])
            toks[i] = t
        streams.append(toks)
    return streams
