"""Pallas TPU kernel: fused bitmap-expand + accumulate for packed payloads.

The packed-gossip hot path repeats, per received neighbor payload,

    num += alpha * scatter(values at bitmap support)
    den += bitmap

The naive route densifies the payload (materialize the scattered tensor in
HBM, then add).  This kernel fuses the expansion into the accumulation: a
grid step loads one coordinate block of the accumulators, the matching
bitmap words, and a window of the contiguous value vector; bits are
expanded in VMEM, the block's values are gathered by an in-register prefix
sum, and the updated accumulator block is written back in place
(``input_output_aliases``) — one HBM round-trip per block, no dense
intermediate per neighbor.

Index plumbing: coordinate ``c`` of the block holds value
``offsets[block] + popcount(bits before c in the block)`` — ``offsets`` is
the host-precomputed exclusive prefix of per-block popcounts, so blocks are
independent and the grid is embarrassingly parallel.

Layout: 2D ``(1, N)`` arrays (TPU wants >= 2D); ``block_n`` coordinates per
grid step (multiple of 128 lanes and of the 32-bit word size).  ``values``
is padded by one block so a window load never overruns.  ``interpret``
defaults to True (this container is CPU-only); the jnp oracle is
``repro.kernels.ref.packed_accum_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024  # coords per grid step: 8 sublane rows of 128 lanes, 32 words


def _packed_accum_kernel(num_ref, den_ref, words_ref, values_ref,
                         offsets_ref, alpha_ref, num_out, den_out,
                         *, block_n: int, block_dim: int = 0):
    words = words_ref[0, :]                       # (block_n // 32,) uint32
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (words.shape[0], 32), dimension=1)
    bits = ((words[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    mask = bits.reshape(1, block_n).astype(jnp.float32)
    # local value index per coordinate: offset + #set bits before it
    # (int32 cumsum: exact for any nnz, unlike a float prefix sum)
    pos = jnp.cumsum(bits.reshape(-1)) - 1
    idx = jnp.maximum(pos + offsets_ref[0, pl.program_id(block_dim)], 0)
    vals = values_ref[0, :].astype(jnp.float32)
    contrib = (jnp.where(mask.reshape(-1) > 0, jnp.take(vals, idx), 0.0)
               .reshape(1, block_n))
    alpha = alpha_ref[0, 0].astype(jnp.float32)
    num_out[...] = (num_ref[...].astype(jnp.float32)
                    + alpha * contrib).astype(num_out.dtype)
    den_out[...] = (den_ref[...].astype(jnp.float32)
                    + mask).astype(den_out.dtype)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_n"))
def packed_accum_flat(num: jax.Array, den: jax.Array, words: jax.Array,
                      values: jax.Array, offsets: jax.Array,
                      alpha: jax.Array, interpret: bool = True,
                      block_n: int = BLOCK_N):
    """num, den: (N,) f32 with N a multiple of ``block_n``; words:
    (N // 32,) uint32; values: (nnz + block_n,) zero-padded; offsets:
    (N // block_n,) int32 exclusive prefix of per-block popcounts; alpha:
    () scalar.  Returns the updated (num, den), accumulated in place."""
    n = num.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    bw = block_n // 32
    n_blocks = grid[0]
    nv = values.shape[0]
    num2, den2 = pl.pallas_call(
        functools.partial(_packed_accum_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, bw), lambda i: (0, i)),
            pl.BlockSpec((1, nv), lambda i: (0, 0)),
            pl.BlockSpec((1, n_blocks), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), num.dtype),
            jax.ShapeDtypeStruct((1, n), den.dtype),
        ],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(num[None, :], den[None, :], words[None, :], values[None, :],
      offsets[None, :], jnp.asarray(alpha, jnp.float32).reshape(1, 1))
    return num2[0], den2[0]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_n"))
def packed_accum_rows(num: jax.Array, den: jax.Array, words: jax.Array,
                      values: jax.Array, offsets: jax.Array,
                      alpha: jax.Array, interpret: bool = True,
                      block_n: int = BLOCK_N):
    """Client-stacked form of ``packed_accum_flat``: fold K packed payloads
    into K accumulator rows in one launch.

    num, den: (K, N) f32 with N a multiple of ``block_n``; words:
    (K, N // 32) uint32 bitmaps; values: (K, max_nnz + block_n) per-client
    value rows (left-aligned, zero right-padded so a window load never
    overruns); offsets: (K, N // block_n) int32 exclusive prefixes of
    per-block popcounts *per client*; alpha: () scalar shared.

    The grid is (K, N // block_n) — the client dim maps to grid rows, so
    the same VMEM-resident kernel body serves both layouts (this is the
    stacked fold ``repro.scale.fold_stacked`` launches with
    ``backend="pallas_rows"``).  Accumulates in place via
    ``input_output_aliases`` exactly like the flat form.
    """
    k, n = num.shape
    assert n % block_n == 0, (n, block_n)
    grid = (k, n // block_n)
    bw = block_n // 32
    n_blocks = grid[1]
    nv = values.shape[1]
    num2, den2 = pl.pallas_call(
        functools.partial(_packed_accum_kernel, block_n=block_n,
                          block_dim=1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda r, i: (r, i)),
            pl.BlockSpec((1, block_n), lambda r, i: (r, i)),
            pl.BlockSpec((1, bw), lambda r, i: (r, i)),
            pl.BlockSpec((1, nv), lambda r, i: (r, 0)),
            pl.BlockSpec((1, n_blocks), lambda r, i: (r, 0)),
            pl.BlockSpec((1, 1), lambda r, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda r, i: (r, i)),
            pl.BlockSpec((1, block_n), lambda r, i: (r, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), num.dtype),
            jax.ShapeDtypeStruct((k, n), den.dtype),
        ],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(num, den, words, values, offsets,
      jnp.asarray(alpha, jnp.float32).reshape(1, 1))
    return num2, den2
