"""Jitted public wrappers around the Pallas kernels.

These handle arbitrary shapes (padding/reshaping to tile-aligned layouts),
threshold computation for prune/regrow, and pytree-level convenience APIs.
``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the kernels are written for the TPU
lowering: MXU-aligned tiles, scalar prefetch, VMEM scratch).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.gossip_avg import gossip_avg_flat
from repro.kernels.masked_matmul import (
    batched_masked_matmul as _batched_masked_matmul_tiled,
    masked_matmul as _masked_matmul_tiled,
)
from repro.kernels.prune_regrow import prune_regrow_flat

PyTree = Any


# ---------------------------------------------------------------------------
# gossip average
# ---------------------------------------------------------------------------


def gossip_avg(w_list: list[jax.Array], m_list: list[jax.Array],
               own_mask: jax.Array, interpret: bool = True) -> jax.Array:
    """Intersection-weighted average of J same-shape tensors (self first)."""
    shape = own_mask.shape
    w_stack = jnp.stack([w.reshape(-1) for w in w_list])
    m_stack = jnp.stack([m.reshape(-1) for m in m_list])
    out = gossip_avg_flat(w_stack, m_stack, own_mask.reshape(-1),
                          interpret=interpret)
    return out.reshape(shape)


def gossip_avg_tree(params_list: list[PyTree], masks_list: list[PyTree],
                    own_mask: PyTree, interpret: bool = True) -> PyTree:
    """Pytree-level gossip (self must be params_list[0]/masks_list[0])."""
    flat = [jax.tree.leaves(p) for p in params_list]
    flat_m = [jax.tree.leaves(m) for m in masks_list]
    own_leaves, treedef = jax.tree.flatten(own_mask)
    out = []
    for i, own in enumerate(own_leaves):
        out.append(gossip_avg([f[i] for f in flat], [f[i] for f in flat_m],
                              own, interpret=interpret))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# block-sparse masked matmul
# ---------------------------------------------------------------------------


def masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = True) -> jax.Array:
    """y = x @ (w ⊙ mask) with zero-block skipping; pads to tile multiples."""
    m_dim, k_dim = x.shape
    k2, n_dim = w.shape
    assert k_dim == k2
    pm, pk, pn = (-m_dim) % bm, (-k_dim) % bk, (-n_dim) % bn
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    mp = jnp.pad(mask, ((0, pk), (0, pn)))
    y = _masked_matmul_tiled(xp, wp, mp, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return y[:m_dim, :n_dim]


def batched_masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array,
                          bm: int = 128, bn: int = 128, bk: int = 128,
                          interpret: bool = True) -> jax.Array:
    """y[u] = x[u] @ (w[u] ⊙ mask[u]) in one launch — the multi-tenant
    serving matmul (repro.serve).  Pads M/K/N to tile multiples; the user
    dim U is a grid dimension, never padded."""
    u_dim, m_dim, k_dim = x.shape
    u2, k2, n_dim = w.shape
    assert (u_dim, k_dim) == (u2, k2), ((u_dim, k_dim), (u2, k2))
    pm, pk, pn = (-m_dim) % bm, (-k_dim) % bk, (-n_dim) % bn
    xp = jnp.pad(x, ((0, 0), (0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, 0), (0, pk), (0, pn)))
    mp = jnp.pad(mask, ((0, 0), (0, pk), (0, pn)))
    y = _batched_masked_matmul_tiled(xp, wp, mp, bm=bm, bn=bn, bk=bk,
                                     interpret=interpret)
    return y[:, :m_dim, :n_dim]


def block_occupancy(mask: jax.Array, bk: int = 128, bn: int = 128) -> float:
    """Fraction of (bk, bn) weight tiles that are non-empty — the *compute*
    density the TPU actually sees (DESIGN.md §3: ERK/RigL concentrate layer
    density, so this tracks but upper-bounds coordinate density)."""
    from repro.kernels.masked_matmul import block_mask_from_mask
    k, n = mask.shape
    pk, pn = (-k) % bk, (-n) % bn
    mp = jnp.pad(mask, ((0, pk), (0, pn)))
    bm_ = block_mask_from_mask(mp, bk, bn)
    return float(jnp.mean(bm_.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# prune + regrow
# ---------------------------------------------------------------------------


def prune_regrow(w: jax.Array, g: jax.Array, m: jax.Array,
                 prune_rate: float, interpret: bool = True):
    """Threshold-based Alg. 2 apply for one layer.

    Thresholds are derived from the exact counts (kth order statistics), so
    up to ties this matches core.evolve.evolve_mask_layer.
    Returns (new_mask, new_weights).
    """
    wf = w.reshape(-1)
    gf = g.reshape(-1)
    mf = m.reshape(-1)
    n_active = jnp.sum(mf > 0)
    n_prune = jnp.ceil(prune_rate * n_active).astype(jnp.int32)
    n_keep = (n_active - n_prune).astype(jnp.int32)

    keep_scores = jnp.where(mf > 0, jnp.abs(wf.astype(jnp.float32)), -jnp.inf)
    sorted_keep = jnp.sort(keep_scores)[::-1]
    w_thresh = sorted_keep[jnp.maximum(n_keep - 1, 0)]

    grow_scores = jnp.where(mf > 0, -jnp.inf, jnp.abs(gf.astype(jnp.float32)))
    sorted_grow = jnp.sort(grow_scores)[::-1]
    g_thresh = sorted_grow[jnp.maximum(n_prune - 1, 0)]

    new_m, new_w = prune_regrow_flat(wf, gf, mf, w_thresh, g_thresh,
                                     interpret=interpret)
    return new_m.reshape(m.shape).astype(m.dtype), new_w.reshape(w.shape)
