"""Pallas TPU kernel: block-sparse masked matmul  y = x @ (w ⊙ m).

This is the TPU-native realization of DisPFL's sparse-compute saving
(DESIGN.md §3): the MXU has no unstructured-sparsity path, so the
coordinate mask is summarized into a (K/bk, N/bn) *block mask*; tiles whose
block is empty are skipped entirely via ``@pl.when`` on a scalar-prefetched
SMEM mask — the MXU never sees them.  Non-empty tiles multiply the
elementwise-masked weights, so the result equals the dense reference
exactly (``ref.masked_matmul_ref``).

Grid: (M/bm, N/bn, K/bk), K innermost; a VMEM f32 scratch accumulates
across K and flushes at the last K step.

``batched_masked_matmul`` is the multi-tenant serving form (repro.serve):
a leading *user-major* grid dimension serves U personalized (w, m) pairs in
ONE launch — the per-user block masks ride the same scalar prefetch, so a
user whose mask leaves a tile empty skips it while other users still
compute theirs.  This batches the matmul kernel exactly the way
``packed_accum_rows`` batched the accumulator kernel: same kernel body,
one more grid dimension mapping users to grid rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 128


def _mm_kernel(bmask_ref, x_ref, w_ref, m_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    live = bmask_ref[k, j] != 0

    @pl.when(live)
    def _accum():
        x = x_ref[...]
        w = (w_ref[...] * m_ref[...].astype(w_ref.dtype))
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def block_mask_from_mask(mask: jax.Array, bk: int, bn: int) -> jax.Array:
    """(K, N) coordinate mask -> (K/bk, N/bn) int32 block occupancy."""
    k, n = mask.shape
    mb = mask.reshape(k // bk, bk, n // bn, bn)
    return (jnp.sum(mb != 0, axis=(1, 3)) > 0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array,
                  bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                  interpret: bool = True) -> jax.Array:
    """x: (M, K); w, mask: (K, N).  Shapes must tile evenly (wrapper in
    ops.py pads arbitrary shapes)."""
    m_dim, k_dim = x.shape
    _, n_dim = w.shape
    assert m_dim % bm == 0 and k_dim % bk == 0 and n_dim % bn == 0, (
        f"shape ({m_dim},{k_dim})x({k_dim},{n_dim}) not divisible by "
        f"({bm},{bk},{bn})")
    n_k = k_dim // bk
    bmask = block_mask_from_mask(mask, bk, bn)
    grid = (m_dim // bm, n_dim // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j)),
                pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), x.dtype),
        interpret=interpret,
    )(bmask, x, w, mask)


# ---------------------------------------------------------------------------
# user-batched form: U personalized (w, m) pairs in one launch (repro.serve)
# ---------------------------------------------------------------------------


def _bmm_kernel(bmask_ref, x_ref, w_ref, m_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = pl.program_id(0)
    j = pl.program_id(2)
    live = bmask_ref[u, k, j] != 0

    @pl.when(live)
    def _accum():
        x = x_ref[0]
        w = (w_ref[0] * m_ref[0].astype(w_ref.dtype))
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def batched_block_mask(mask: jax.Array, bk: int, bn: int) -> jax.Array:
    """(U, K, N) coordinate masks -> (U, K/bk, N/bn) int32 block occupancy."""
    u, k, n = mask.shape
    mb = mask.reshape(u, k // bk, bk, n // bn, bn)
    return (jnp.sum(mb != 0, axis=(2, 4)) > 0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def batched_masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array,
                          bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                          bk: int = DEFAULT_BK,
                          interpret: bool = True) -> jax.Array:
    """y[u] = x[u] @ (w[u] ⊙ m[u]) for every user u, one device launch.

    x: (U, M, K); w, mask: (U, K, N).  Shapes must tile evenly (the wrapper
    in ops.py pads arbitrary shapes).  Grid is (U, M/bm, N/bn, K/bk) — the
    user dim maps to grid rows, per-user block masks are scalar-prefetched,
    and the same ``@pl.when`` tile-skipping applies per user.
    """
    u_dim, m_dim, k_dim = x.shape
    u2, _, n_dim = w.shape
    assert u_dim == u2, (u_dim, u2)
    assert m_dim % bm == 0 and k_dim % bk == 0 and n_dim % bn == 0, (
        f"shape ({u_dim},{m_dim},{k_dim})x({u_dim},{k_dim},{n_dim}) not "
        f"divisible by ({bm},{bk},{bn})")
    n_k = k_dim // bk
    bmask = batched_block_mask(mask, bk, bn)
    grid = (u_dim, m_dim // bm, n_dim // bn, n_k)
    return pl.pallas_call(
        functools.partial(_bmm_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda u, i, j, k, *_: (u, i, k)),
                pl.BlockSpec((1, bk, bn), lambda u, i, j, k, *_: (u, k, j)),
                pl.BlockSpec((1, bk, bn), lambda u, i, j, k, *_: (u, k, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda u, i, j, k, *_: (u, i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((u_dim, m_dim, n_dim), x.dtype),
        interpret=interpret,
    )(bmask, x, w, mask)
