"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_avg_ref(w_stack: jax.Array, m_stack: jax.Array,
                   own_mask: jax.Array) -> jax.Array:
    """w_stack, m_stack: (J, N); own_mask: (N,)."""
    num = jnp.sum(w_stack.astype(jnp.float32), axis=0)
    den = jnp.maximum(jnp.sum(m_stack.astype(jnp.float32), axis=0), 1.0)
    return ((num / den) * own_mask.astype(jnp.float32)).astype(w_stack.dtype)


def masked_matmul_ref(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    return (x @ (w * mask.astype(w.dtype))).astype(x.dtype)


def batched_masked_matmul_ref(x: jax.Array, w: jax.Array,
                              mask: jax.Array) -> jax.Array:
    """Per-user oracle for the user-batched kernel: x (U, M, K); w, mask
    (U, K, N) -> (U, M, N), each user against its own masked weights."""
    return jax.vmap(masked_matmul_ref)(x, w, mask)


def packed_accum_ref(num: jax.Array, den: jax.Array, flags: jax.Array,
                     values: jax.Array, alpha: float = 1.0):
    """Oracle for kernels.packed_accum: num += alpha * scatter(values at
    flags), den += flags.  flags: (N,) bool; values: (nnz,) in flag order."""
    flags = flags.reshape(-1)
    pos = jnp.cumsum(flags.astype(jnp.int32)) - 1
    vals = jnp.take(values.astype(jnp.float32), jnp.maximum(pos, 0))
    contrib = jnp.where(flags, vals, 0.0)
    return (num + jnp.float32(alpha) * contrib,
            den + flags.astype(jnp.float32))


def prune_regrow_ref(w: jax.Array, g: jax.Array, m: jax.Array,
                     w_thresh, g_thresh):
    wf = w.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    keep = (m > 0) & (jnp.abs(wf) >= w_thresh)
    grown = (m <= 0) & (jnp.abs(gf) >= g_thresh) & (jnp.abs(gf) > 0)
    new_m = (keep | grown).astype(m.dtype)
    new_w = (wf * keep.astype(jnp.float32)).astype(w.dtype)
    return new_m, new_w
