"""Pallas TPU kernel: fused threshold prune + gradient regrow (Alg. 2 apply).

The top-k *selection* (finding the per-layer magnitude threshold for pruning
and the gradient threshold for regrowth) is a tiny reduction done outside in
jnp (``ops.prune_regrow``); this kernel fuses the expensive elementwise pass
over the full weight/grad/mask tensors:

    keep   = mask==1 & |w| >= w_thresh
    grown  = mask==0 & |g| >= g_thresh
    new_m  = keep | grown
    new_w  = w * keep          (regrown coords re-enter at 0, paper §3.2)

Tie handling: threshold semantics may keep/grow a few more coordinates than
the exact-count argsort in ``core.evolve`` when values are exactly equal at
the threshold; tests compare against the threshold oracle in ``ref.py`` and
separately check the count drift against the exact version.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _pr_kernel(w_ref, g_ref, m_ref, th_ref, new_m_ref, new_w_ref):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    w_th = th_ref[0, 0]
    g_th = th_ref[0, 1]
    keep = (m > 0) & (jnp.abs(w) >= w_th)
    # zero-gradient coords never regrow (guards the all-ties-at-zero case)
    grown = (m <= 0) & (jnp.abs(g) >= g_th) & (jnp.abs(g) > 0)
    new_m = keep | grown
    new_m_ref[...] = new_m.astype(new_m_ref.dtype)
    new_w_ref[...] = (w * keep.astype(jnp.float32)).astype(new_w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def prune_regrow_flat(w: jax.Array, g: jax.Array, m: jax.Array,
                      w_thresh: jax.Array, g_thresh: jax.Array,
                      interpret: bool = True, block: int = BLOCK):
    """All inputs (N,); thresholds scalars.  Returns (new_mask, new_weights)."""
    n = w.shape[0]
    pad = (-n) % block
    if pad:
        w = jnp.pad(w, (0, pad))
        g = jnp.pad(g, (0, pad))
        m = jnp.pad(m, (0, pad))
    n_pad = n + pad
    th = jnp.stack([w_thresh, g_thresh]).astype(jnp.float32)[None, :]
    new_m, new_w = pl.pallas_call(
        _pr_kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_pad), m.dtype),
            jax.ShapeDtypeStruct((1, n_pad), w.dtype),
        ],
        interpret=interpret,
    )(w[None, :], g[None, :], m[None, :], th)
    return new_m[0, :n], new_w[0, :n]
