"""Pallas TPU kernel: fused intersection-weighted gossip average.

Computes, for one client k with J received models (self included):

    out = (sum_j W[j]) / max(sum_j M[j], 1) * m_own

in a single pass: the stacked neighbor tensors stream HBM->VMEM tile by
tile and the reduction, divide and re-mask fuse in VMEM, avoiding the two
HBM round-trips (numerator and denominator materialization) of the naive
implementation.

Layout: inputs are flattened to (J, N) with N padded to a multiple of the
lane tile; the grid walks N in ``block_n`` chunks, each block loading the
full J (neighbor counts are small: degree <= 10 busiest-node bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024  # lanes per grid step (multiple of 128)


def _gossip_kernel(w_ref, m_ref, own_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)       # (J, block_n)
    m = m_ref[...].astype(jnp.float32)
    own = own_ref[...].astype(jnp.float32)   # (1, block_n)
    num = jnp.sum(w, axis=0, keepdims=True)
    den = jnp.maximum(jnp.sum(m, axis=0, keepdims=True), 1.0)
    out_ref[...] = ((num / den) * own).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def gossip_avg_flat(w_stack: jax.Array, m_stack: jax.Array, own_mask: jax.Array,
                    interpret: bool = True, block_n: int = BLOCK_N) -> jax.Array:
    """w_stack, m_stack: (J, N); own_mask: (N,).  Returns (N,)."""
    j, n = w_stack.shape
    pad = (-n) % block_n
    if pad:
        w_stack = jnp.pad(w_stack, ((0, 0), (0, pad)))
        m_stack = jnp.pad(m_stack, ((0, 0), (0, pad)))
        own_mask = jnp.pad(own_mask, (0, pad))
    n_pad = n + pad
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((j, block_n), lambda i: (0, i)),
            pl.BlockSpec((j, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), w_stack.dtype),
        interpret=interpret,
    )(w_stack, m_stack, own_mask[None, :])
    return out[0, :n]
