"""Simulation reporting: deployment-facing numbers from measured transfers.

``build_report`` turns a run's ``LinkStats`` + accuracy trace into the
quantities the paper only gestures at: virtual wall-clock to a target
accuracy, the busiest node's upload/download timeline, per-link utilization
and total measured bytes-on-wire.  ``MetricsStream`` is the tiny JSON-lines
emitter shared by the simulator CLI and ``launch/serve.py`` live metrics —
one JSON object per line, streamed as the run progresses rather than dumped
at the end.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from typing import IO, Optional, Sequence

import numpy as np

from repro.sim.links import MB, LinkStats


class MetricsStream:
    """Append one JSON object per line to a file or stdout, flushing each
    line so consumers see metrics live.

    ``append=True`` opens real files in append mode — a run resumed from a
    checkpoint keeps the lines streamed before the cut instead of
    clobbering them.  ``header=True`` prefixes the stream with one
    ``{"event": "schema", "version": N}`` record (the JSONL schema version
    lives in ``repro.obs.export``).  ``close`` only closes handles this
    stream opened — never stdout, even if ``sys.stdout`` was rebound
    between open and close — and the stream is a context manager."""

    def __init__(self, path: str = "-", append: bool = False,
                 header: bool = False):
        self.path = path
        self.append = bool(append)
        self.header = bool(header)
        self._fh: Optional[IO] = None
        self._owns = False          # True iff we opened (and must close) it
        self._header_written = False

    def _handle(self) -> IO:
        if self._fh is None:
            if self.path in ("-", ""):
                self._fh = sys.stdout
                self._owns = False
            else:
                import os
                d = os.path.dirname(os.path.abspath(self.path))
                os.makedirs(d, exist_ok=True)
                self._fh = open(self.path, "a" if self.append else "w")
                self._owns = True
        return self._fh

    def emit(self, record: dict) -> None:
        fh = self._handle()
        if self.header and not self._header_written:
            self._header_written = True
            from repro.obs import JSONL_SCHEMA_VERSION
            fh.write(json.dumps({"event": "schema",
                                 "version": JSONL_SCHEMA_VERSION}) + "\n")
        fh.write(json.dumps(record) + "\n")
        fh.flush()

    def close(self) -> None:
        if self._fh is not None and self._owns:
            self._fh.close()
        self._fh = None
        self._owns = False

    def __enter__(self) -> "MetricsStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass
class SimReport:
    mode: str
    sim_wall_s: float                       # total virtual seconds
    total_mb: float                         # measured, value-bytes
    total_wire_mb: float                    # + mask bitmaps
    retrans_mb: float                       # value-MB spent on retransmits
    n_retransmits: int                      # retransmitted attempts
    lost_messages: int                      # never delivered (async loss)
    busiest_node: int
    busiest_node_mb: float                  # max(up, down) convention
    busiest_up_mb: float
    busiest_down_mb: float
    time_to_target_s: dict                  # target acc -> virtual s (or -1)
    busiest_mb_at_target: dict              # target acc -> busiest-node MB
    link_utilization_mean: float            # over used edges
    link_utilization_max: float
    n_transfers: int
    acc_trace: list                         # [(virtual s, acc), ...]
    busiest_timeline: list                  # [(virtual s, up MB, down MB), ...]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["acc_trace"] = [(round(t, 3), round(a, 4)) for t, a in self.acc_trace]
        d["busiest_timeline"] = [
            (round(t, 3), round(u, 3), round(dn, 3))
            for t, u, dn in self.busiest_timeline]
        return d

    def row(self) -> dict:
        """Compact benchmark row (no timelines)."""
        return {
            "mode": self.mode,
            "sim_wall_s": round(self.sim_wall_s, 2),
            "busiest_MB": round(self.busiest_node_mb, 2),
            "total_MB": round(self.total_mb, 2),
            "retrans_MB": round(self.retrans_mb, 3),
            "lost_messages": self.lost_messages,
            "time_to_target_s": {str(k): round(v, 2)
                                 for k, v in self.time_to_target_s.items()},
            "busiest_MB_at_target": {str(k): round(v, 2)
                                     for k, v in self.busiest_mb_at_target.items()},
            "link_util_mean": round(self.link_utilization_mean, 4),
        }


def time_to_target(acc_trace: Sequence[tuple[float, float]],
                   target: float) -> float:
    """First virtual time the accuracy trace reaches ``target`` (-1: never)."""
    for t, acc in acc_trace:
        if acc >= target:
            return float(t)
    return -1.0


def build_report(mode: str, stats: LinkStats,
                 acc_trace: Sequence[tuple[float, float]],
                 sim_wall_s: float,
                 targets: Sequence[float] = ()) -> SimReport:
    node, busiest_mb = stats.busiest_node()
    util = stats.utilization(sim_wall_s)
    used = util[stats.edge_bytes > 0]
    ttt, mb_at = {}, {}
    for tgt in targets:
        t_hit = time_to_target(acc_trace, tgt)
        ttt[tgt] = t_hit
        mb_at[tgt] = stats.busiest_mb_until(t_hit) if t_hit >= 0 else -1.0
    return SimReport(
        mode=mode,
        sim_wall_s=float(sim_wall_s),
        total_mb=stats.total_mb,
        total_wire_mb=stats.total_wire_mb,
        retrans_mb=stats.retrans_mb,
        n_retransmits=stats.n_retransmits,
        lost_messages=stats.n_lost,
        busiest_node=node,
        busiest_node_mb=busiest_mb,
        busiest_up_mb=float(stats.up[node]) * MB,
        busiest_down_mb=float(stats.down[node]) * MB,
        time_to_target_s=ttt,
        busiest_mb_at_target=mb_at,
        link_utilization_mean=float(used.mean()) if used.size else 0.0,
        link_utilization_max=float(used.max()) if used.size else 0.0,
        n_transfers=len(stats.transfers),
        acc_trace=list(acc_trace),
        busiest_timeline=stats.node_timeline(node))
