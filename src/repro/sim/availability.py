"""Client up/down schedules — ONE failure model for engine and simulator.

The Bernoulli draws live in ``repro.core.topology.bernoulli_alive`` and are
keyed by (seed, slot): the round engine's ``cfg.drop_prob`` path, the fig-6
dropping benchmark and the event simulator all read the *same* alive sets
for the same (seed, slot) pairs, so "the dropping experiment" means one
thing everywhere.

Slots are communication rounds in the synchronous engine; the asynchronous
engine advances a client's slot on every activation attempt (a down client
retries one mean-round later against its next slot).

Availability models are *stateless*: ``alive(slot)`` is a pure function of
(seed, slot), so nothing here needs checkpointing — the simulator's slot
counters (``down_count`` per client) live in ``SimEngine``'s event-loop
state and are serialized by ``SimEngine.save``, which is what makes a
resumed fault-injection run replay the exact same up/down schedule.
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import apply_availability, bernoulli_alive

__all__ = [
    "Availability", "AlwaysUp", "BernoulliAvailability", "TraceAvailability",
    "apply_availability", "bernoulli_alive", "dropping_trace",
]


class Availability:
    """Base: every client is always up."""

    def __init__(self, n_clients: int):
        self.n_clients = n_clients

    def alive(self, slot: int) -> np.ndarray:
        return np.ones(self.n_clients, dtype=bool)

    def up(self, k: int, slot: int) -> bool:
        return bool(self.alive(slot)[k])

    @property
    def always_up(self) -> bool:
        return type(self) is Availability or isinstance(self, AlwaysUp)


class AlwaysUp(Availability):
    pass


class BernoulliAvailability(Availability):
    """i.i.d. per-slot drops — bit-identical to ``cfg.drop_prob`` in the
    round engine (both call ``topology.bernoulli_alive``)."""

    def __init__(self, n_clients: int, drop_prob: float, seed: int = 0):
        super().__init__(n_clients)
        self.drop_prob = float(drop_prob)
        self.seed = int(seed)

    def alive(self, slot: int) -> np.ndarray:
        return bernoulli_alive(self.n_clients, slot, self.drop_prob, self.seed)


class TraceAvailability(Availability):
    """Explicit (slots, clients) boolean trace, cycled when the run is
    longer than the trace (for replaying measured availability logs)."""

    def __init__(self, trace: np.ndarray):
        trace = np.asarray(trace, dtype=bool)
        if trace.ndim != 2 or trace.shape[0] == 0:
            raise ValueError("trace must be a non-empty (slots, clients) array")
        super().__init__(trace.shape[1])
        self.trace = trace

    def alive(self, slot: int) -> np.ndarray:
        return self.trace[slot % len(self.trace)]

    @classmethod
    def from_bernoulli(cls, n_clients: int, slots: int, drop_prob: float,
                       seed: int = 0) -> "TraceAvailability":
        """Materialize the Bernoulli model into an explicit trace (identical
        draws — useful for inspecting or editing a dropping scenario)."""
        return cls(np.stack([
            bernoulli_alive(n_clients, s, drop_prob, seed)
            for s in range(slots)]))


def dropping_trace(n_clients: int, rounds: int, drop_prob: float,
                   seed: int = 0) -> TraceAvailability:
    """The fig-6 (App. B.6) client-dropping scenario as an explicit trace."""
    return TraceAvailability.from_bernoulli(n_clients, rounds, drop_prob, seed)
