"""Discrete-event substrate: event queue, virtual clock, compute model.

The simulator is a classic event loop: events carry a virtual timestamp,
the queue pops them in (time, insertion) order, and the clock only moves
forward.  Ties break on insertion sequence, which makes every run fully
deterministic — there is no wall-clock or OS scheduling anywhere in the
virtual timeline.

``ComputeModel`` converts analytic per-round training FLOPs (from
``repro.core.accounting``) into virtual seconds via per-client effective
FLOP/s, which is how heterogeneous device speeds (the paper's "varying
computation complexities") enter the timeline.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Iterable, Iterator, Optional

import numpy as np

# event kinds
WAKE = "wake"          # a client is ready to start its next local round
ARRIVAL = "arrival"    # a neighbor's model message finished its transfer
DONE = "done"          # a client's local compute for one round finished


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int                      # insertion order; deterministic tie-break
    kind: str
    data: dict = dataclasses.field(default_factory=dict)


class EventQueue:
    """Min-heap of events ordered by (time, insertion sequence)."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str, **data: Any) -> Event:
        ev = Event(float(time), next(self._seq), kind, data)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    # -- checkpointing -----------------------------------------------------
    def pending(self) -> list[Event]:
        """Pending events in pop order (non-destructive) — what a
        checkpoint must persist for the tie-breaks to survive a resume."""
        return [item[2] for item in sorted(self._heap)]

    def restore(self, events: Iterable[Event]) -> None:
        """Rebuild the queue from checkpointed events, preserving each
        event's original insertion sequence so (time, seq) ordering — and
        therefore every tie-break — is bit-identical after resume."""
        self._heap = []
        max_seq = -1
        for ev in events:
            heapq.heappush(self._heap, (ev.time, ev.seq, ev))
            max_seq = max(max_seq, ev.seq)
        self._seq = itertools.count(max_seq + 1)

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


class VirtualClock:
    """Monotone virtual time in seconds."""

    def __init__(self) -> None:
        self.now = 0.0

    def advance_to(self, t: float) -> None:
        if t < self.now - 1e-12:
            raise ValueError(f"clock moved backwards: {self.now} -> {t}")
        self.now = max(self.now, float(t))


def hetero_speeds(n_clients: int, levels: tuple = (0.2, 0.4, 0.6, 0.8, 1.0),
                  seed: int = 0) -> np.ndarray:
    """Capacity levels cycled over clients and shuffled by ``seed``."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 271828]))
    return rng.permutation(
        np.array([levels[k % len(levels)] for k in range(n_clients)]))


class ComputeModel:
    """Per-client effective training throughput.

    ``local_time(k, flops)`` = virtual seconds client k needs for a local
    phase costing ``flops`` — ``flops / (flops_per_s * speed[k])``.  Speed
    multipliers model device heterogeneity (a 0.2x client is 5x slower than
    a 1.0x one); they are the simulator-side counterpart of the paper's
    heterogeneous-capacity experiments.
    """

    def __init__(self, flops_per_s: float = 5e12,
                 speeds: Optional[np.ndarray] = None, n_clients: int = 0):
        if speeds is None:
            speeds = np.ones(n_clients)
        self.flops_per_s = float(flops_per_s)
        self.speeds = np.asarray(speeds, dtype=float)
        if np.any(self.speeds <= 0):
            raise ValueError("compute speeds must be positive")

    @classmethod
    def uniform(cls, n_clients: int, flops_per_s: float = 5e12) -> "ComputeModel":
        return cls(flops_per_s, np.ones(n_clients))

    @classmethod
    def heterogeneous(cls, n_clients: int, flops_per_s: float = 5e12,
                      levels: tuple = (0.2, 0.4, 0.6, 0.8, 1.0),
                      seed: int = 0) -> "ComputeModel":
        """Cycle the capacity levels over clients, shuffled by ``seed`` so the
        slow clients are not always the low indices."""
        return cls(flops_per_s, hetero_speeds(n_clients, levels, seed))

    @classmethod
    def paced(cls, n_clients: int, flops_round: float, round_s: float = 1.0,
              speeds: Optional[np.ndarray] = None) -> "ComputeModel":
        """Anchor the timescale: a speed-1.0 client finishes one local round
        (costing ``flops_round`` FLOPs) in ``round_s`` virtual seconds.
        Useful with toy tasks whose absolute FLOPs would otherwise be
        ridiculously small next to realistic link latencies."""
        return cls(flops_round / round_s, speeds, n_clients)

    def local_time(self, k: int, flops: float) -> float:
        return float(flops) / (self.flops_per_s * self.speeds[k])

    def mean_round_s(self, flops: float) -> float:
        return float(np.mean([self.local_time(k, flops)
                              for k in range(len(self.speeds))]))
