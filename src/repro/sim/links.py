"""Per-edge link models and measured bytes-on-wire.

``LinkModel`` maps a message size to a transfer time per directed edge
(latency + bytes / bandwidth).  ``LinkStats`` records every transfer the
simulator actually performs — sender, receiver, and the payload's size
*measured from what is actually shipped*: messages are ``repro.sparse``
packed trees and ``measure_payload`` sizes them with the wire codec
(``codec.encoded_nbytes``, bitmap and frame header included), so
busiest-node traffic and per-link utilization are measured quantities, not
analytic assumptions.  The codec frame is an exact function of (nnz,
coords, itemsize), which keeps measured totals bit-commensurable with
``core.accounting.decentralized_comm`` (the property test in
``tests/test_sim.py`` asserts exactly that).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.accounting import message_bytes
from repro.sparse import PackedSparse, codec
from repro.utils.tree import tree_nnz, tree_size

MB = 1e-6  # decimal MB, matching the paper's tables


def measure_payload(payload: dict) -> tuple[float, int]:
    """(value bytes, wire bytes) of one message payload.

    Packed payloads (the default ``StrategyBase.snapshot_message``) are
    sized exactly: value bytes from the held values' own itemsize, wire
    bytes from ``codec.encoded_nbytes`` of the frame the link would carry.
    Dense ``{"params", "mask"}`` payloads fall back to the analytic
    ``accounting.message_bytes`` from the mask's nnz.
    """
    packed = payload.get("packed")
    if packed is not None:
        import jax

        # metadata only (nnz * itemsize) — no device-to-host copy
        nbytes = sum(
            p.nnz * np.dtype(p.values.dtype).itemsize
            for p in jax.tree.leaves(
                packed, is_leaf=lambda x: isinstance(x, PackedSparse)))
        return float(nbytes), codec.encoded_nbytes(packed)
    params = payload["params"]
    nnz = (tree_nnz(payload["mask"]) if payload.get("mask") is not None
           else tree_size(params))
    coords = tree_size(params)
    return (message_bytes(nnz),
            int(message_bytes(nnz, coords, with_bitmap=True)))


class LinkModel:
    """Directed per-edge bandwidth/latency: time = latency + bytes * 8 / bw."""

    def __init__(self, bandwidth_mbps: np.ndarray | float,
                 latency_s: np.ndarray | float = 0.01, n_clients: int = 0):
        if np.isscalar(bandwidth_mbps):
            bandwidth_mbps = np.full((n_clients, n_clients), float(bandwidth_mbps))
        if np.isscalar(latency_s):
            latency_s = np.full_like(np.asarray(bandwidth_mbps, float),
                                     float(latency_s))
        self.bw_mbps = np.asarray(bandwidth_mbps, dtype=float)
        self.latency_s = np.asarray(latency_s, dtype=float)
        if np.any(self.bw_mbps <= 0):
            raise ValueError("bandwidth must be positive")

    @classmethod
    def uniform(cls, n_clients: int, mbps: float = 100.0,
                latency_ms: float = 10.0) -> "LinkModel":
        return cls(mbps, latency_ms / 1e3, n_clients)

    @classmethod
    def skewed(cls, n_clients: int, mbps: float = 100.0, skew: float = 10.0,
               slow_frac: float = 0.5, latency_ms: float = 10.0,
               seed: int = 0) -> "LinkModel":
        """A ``slow_frac`` subset of clients sits behind ``skew``x slower
        links (any edge touching a slow client): the bandwidth-heterogeneity
        regime where async gossip should beat the synchronous barrier."""
        rng = np.random.default_rng(np.random.SeedSequence([seed, 314159]))
        slow = rng.permutation(n_clients) < int(round(slow_frac * n_clients))
        bw = np.full((n_clients, n_clients), mbps)
        bw[slow, :] = mbps / skew
        bw[:, slow] = mbps / skew
        return cls(bw, latency_ms / 1e3)

    def transfer_time(self, n_bytes: float, src: int, dst: int) -> float:
        return float(self.latency_s[src, dst]
                     + n_bytes * 8.0 / (self.bw_mbps[src, dst] * 1e6))


@dataclasses.dataclass
class Transfer:
    t_start: float
    t_end: float
    src: int
    dst: int
    bytes_values: float     # 4B-per-value payload (the paper's headline unit)
    bytes_wire: float       # payload + mask bitmap (what the link carries)


class LinkStats:
    """Accumulates every simulated transfer.

    Totals use the paper's value-bytes convention (comparable to
    ``decentralized_comm``); ``*_wire`` adds the mask bitmap.  ``transfers``
    keeps the full timeline for per-link utilization and the busiest-node
    upload/download trajectories in ``repro.sim.report``.
    """

    def __init__(self, n_clients: int):
        self.n = n_clients
        self.up = np.zeros(n_clients)        # value-bytes uploaded per node
        self.down = np.zeros(n_clients)
        self.up_wire = np.zeros(n_clients)
        self.down_wire = np.zeros(n_clients)
        self.edge_bytes = np.zeros((n_clients, n_clients))   # [dst, src]
        self.edge_busy_s = np.zeros((n_clients, n_clients))
        self.transfers: list[Transfer] = []

    def record(self, src: int, dst: int, bytes_values: float,
               bytes_wire: float, t_start: float, t_end: float) -> None:
        self.up[src] += bytes_values
        self.down[dst] += bytes_values
        self.up_wire[src] += bytes_wire
        self.down_wire[dst] += bytes_wire
        self.edge_bytes[dst, src] += bytes_values
        self.edge_busy_s[dst, src] += max(0.0, t_end - t_start)
        self.transfers.append(Transfer(t_start, t_end, src, dst,
                                       bytes_values, bytes_wire))

    # -- aggregates --------------------------------------------------------
    @property
    def total_mb(self) -> float:
        return float(self.up.sum()) * MB

    @property
    def total_wire_mb(self) -> float:
        return float(self.up_wire.sum()) * MB

    def per_node_mb(self) -> np.ndarray:
        """Paper convention: each node's traffic is its busiest direction."""
        return np.maximum(self.up, self.down) * MB

    def busiest_node(self) -> tuple[int, float]:
        per = self.per_node_mb()
        k = int(np.argmax(per))
        return k, float(per[k])

    def snapshot(self) -> dict:
        return {"up": self.up.copy(), "down": self.down.copy(),
                "up_wire": self.up_wire.copy(),
                "down_wire": self.down_wire.copy()}

    def busiest_mb_until(self, t: float) -> float:
        """Busiest node's value-MB counting only transfers finished by t."""
        up = np.zeros(self.n)
        down = np.zeros(self.n)
        for tr in self.transfers:
            if tr.t_end <= t:
                up[tr.src] += tr.bytes_values
                down[tr.dst] += tr.bytes_values
        return float(np.maximum(up, down).max()) * MB

    def node_timeline(self, k: int) -> list[tuple[float, float, float]]:
        """(t, cumulative up MB, cumulative down MB) at each transfer end
        involving node k — the busiest-node upload/download timeline."""
        out, up, down = [], 0.0, 0.0
        for tr in sorted(self.transfers, key=lambda r: (r.t_end, r.src, r.dst)):
            if tr.src != k and tr.dst != k:
                continue
            if tr.src == k:
                up += tr.bytes_values
            if tr.dst == k:
                down += tr.bytes_values
            out.append((tr.t_end, up * MB, down * MB))
        return out

    def utilization(self, span_s: float) -> np.ndarray:
        """Per-edge busy fraction over the run (capped at 1.0)."""
        if span_s <= 0:
            return np.zeros_like(self.edge_busy_s)
        return np.minimum(self.edge_busy_s / span_s, 1.0)
