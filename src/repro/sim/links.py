"""Per-edge link models, fault models, and measured bytes-on-wire.

``LinkModel`` maps a message size to a transfer time per directed edge
(latency + bytes / bandwidth), optionally modulated by a time-varying
``BandwidthTrace``.  ``UplinkScheduler`` serializes a sender's concurrent
transfers on its shared uplink (FIFO or processor-sharing fair-share)
instead of letting every edge run in parallel — which is what changes
busiest-node timelines, the paper's key metric.  ``LossModel`` drops
messages per-link with a derived-rng Bernoulli draw and schedules
timeout/retransmit attempts; every attempt's bytes are counted on the wire.

``LinkStats`` records every transfer the simulator actually performs —
sender, receiver, and the payload's size *measured from what is actually
shipped*: messages are ``repro.sparse`` packed trees and ``measure_payload``
sizes them with the wire codec (``codec.encoded_nbytes``, bitmap and frame
header included), so busiest-node traffic and per-link utilization are
measured quantities, not analytic assumptions.  The codec frame is an exact
function of (nnz, coords, itemsize), which keeps measured totals
bit-commensurable with ``core.accounting.decentralized_comm`` (the property
test in ``tests/test_sim.py`` asserts exactly that).

Everything stateful here (``UplinkScheduler.free_at``, the ``LinkStats``
accumulators and transfer log) exposes ``state_dict``/``load_state`` so
``SimEngine.save``/``restore`` can round-trip a mid-run simulation
bit-identically; the ``LossModel`` itself is stateless — every drop draw is
a pure function of (seed, src, dst, message tag, attempt).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import numpy as np

from repro.core.accounting import message_bytes
from repro.obs import CounterSet, SeriesSet
from repro.sparse import PackedSparse, codec
from repro.utils.tree import tree_nnz, tree_size

MB = 1e-6  # decimal MB, matching the paper's tables

# SeedSequence sub-stream tag for per-(src, dst, message, attempt) drop
# draws — disjoint from the engine's training streams and the topology's
# AVAIL/GOSSIP streams so loss never perturbs training randomness.
LOSS_STREAM = 65537

UPLINK_MODES = ("parallel", "fifo", "fair")


def measure_payload(payload: dict) -> tuple[float, int]:
    """(value bytes, wire bytes) of one message payload.

    Packed payloads (the default ``StrategyBase.snapshot_message``) are
    sized exactly: value bytes from the held values' own itemsize, wire
    bytes from ``codec.encoded_nbytes`` of the frame the link would carry.
    Dense ``{"params", "mask"}`` payloads fall back to the analytic
    ``accounting.message_bytes`` from the mask's nnz.
    """
    packed = payload.get("packed")
    if packed is not None:
        import jax

        # metadata only (nnz * itemsize) — no device-to-host copy
        nbytes = sum(
            p.nnz * np.dtype(p.values.dtype).itemsize
            for p in jax.tree.leaves(
                packed, is_leaf=lambda x: isinstance(x, PackedSparse)))
        return float(nbytes), codec.encoded_nbytes(packed)
    params = payload["params"]
    nnz = (tree_nnz(payload["mask"]) if payload.get("mask") is not None
           else tree_size(params))
    coords = tree_size(params)
    return (message_bytes(nnz),
            int(message_bytes(nnz, coords, with_bitmap=True)))


class BandwidthTrace:
    """Piecewise-constant time-varying bandwidth multipliers.

    ``scale_at(t, k)`` is the factor applied to every link whose *sender* is
    ``k`` at virtual time ``t``: ``times`` are ascending breakpoints,
    ``scales`` holds either one global multiplier per breakpoint (shape
    ``(T,)``) or one per client (``(T, n_clients)``); the last value holds
    forever (step function, no interpolation).  A transfer is priced at the
    bandwidth in force when it *starts* — rates do not change mid-transfer,
    which keeps every (start, end) pair an exact closed form.
    """

    def __init__(self, times: Sequence[float], scales: np.ndarray):
        self.times = np.asarray(times, dtype=float)
        self.scales = np.asarray(scales, dtype=float)
        if self.times.ndim != 1 or self.times.size == 0:
            raise ValueError("trace times must be a non-empty 1-D sequence")
        if np.any(np.diff(self.times) < 0):
            raise ValueError("trace times must be ascending")
        if self.scales.shape[0] != self.times.size:
            raise ValueError("one scale row per breakpoint required")
        if np.any(self.scales <= 0):
            raise ValueError("bandwidth scales must be positive")

    def scale_at(self, t: float, k: int) -> float:
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        i = max(i, 0)
        row = self.scales[i]
        return float(row if row.ndim == 0 else row[k])

    @classmethod
    def from_json(cls, path: str) -> "BandwidthTrace":
        """Load ``{"times": [...], "scale": [...]}`` (scale entries either
        scalars or per-client lists) — the ``--bandwidth-trace`` file."""
        with open(path) as f:
            d = json.load(f)
        return cls(d["times"], np.asarray(d["scale"], dtype=float))


class LinkModel:
    """Directed per-edge bandwidth/latency: time = latency + bytes * 8 / bw.

    ``trace`` (a ``BandwidthTrace``) scales the sender's outgoing bandwidth
    as a function of virtual time — trace-driven link schedules.
    """

    def __init__(self, bandwidth_mbps: np.ndarray | float,
                 latency_s: np.ndarray | float = 0.01, n_clients: int = 0,
                 trace: Optional[BandwidthTrace] = None):
        if np.isscalar(bandwidth_mbps):
            bandwidth_mbps = np.full((n_clients, n_clients), float(bandwidth_mbps))
        if np.isscalar(latency_s):
            latency_s = np.full_like(np.asarray(bandwidth_mbps, float),
                                     float(latency_s))
        self.bw_mbps = np.asarray(bandwidth_mbps, dtype=float)
        self.latency_s = np.asarray(latency_s, dtype=float)
        self.trace = trace
        if np.any(self.bw_mbps <= 0):
            raise ValueError("bandwidth must be positive")

    @classmethod
    def uniform(cls, n_clients: int, mbps: float = 100.0,
                latency_ms: float = 10.0,
                trace: Optional[BandwidthTrace] = None) -> "LinkModel":
        return cls(mbps, latency_ms / 1e3, n_clients, trace=trace)

    @classmethod
    def skewed(cls, n_clients: int, mbps: float = 100.0, skew: float = 10.0,
               slow_frac: float = 0.5, latency_ms: float = 10.0,
               seed: int = 0,
               trace: Optional[BandwidthTrace] = None) -> "LinkModel":
        """A ``slow_frac`` subset of clients sits behind ``skew``x slower
        links (any edge touching a slow client): the bandwidth-heterogeneity
        regime where async gossip should beat the synchronous barrier."""
        rng = np.random.default_rng(np.random.SeedSequence([seed, 314159]))
        slow = rng.permutation(n_clients) < int(round(slow_frac * n_clients))
        bw = np.full((n_clients, n_clients), mbps)
        bw[slow, :] = mbps / skew
        bw[:, slow] = mbps / skew
        return cls(bw, latency_ms / 1e3, trace=trace)

    def serialization_time(self, n_bytes: float, src: int, dst: int,
                           t: float = 0.0) -> float:
        """Seconds the *uplink* is occupied putting the frame on the wire
        (excludes propagation latency) at the bandwidth in force at ``t``."""
        bw = self.bw_mbps[src, dst]
        if self.trace is not None:
            bw *= self.trace.scale_at(t, src)
        return float(n_bytes * 8.0 / (bw * 1e6))

    def transfer_time(self, n_bytes: float, src: int, dst: int,
                      t: float = 0.0) -> float:
        return (self.serialization_time(n_bytes, src, dst, t)
                + float(self.latency_s[src, dst]))


class UplinkScheduler:
    """Serializes a sender's transfers on its shared uplink.

    Modes (the uplink discipline):

    * ``parallel`` — v1 behaviour: every edge transfers independently; a
      sender pushing to ``degree`` receivers occupies ``degree`` full-rate
      uplinks at once (physically optimistic — kept as the idealized
      baseline).
    * ``fifo`` — transfers serialize in scheduling order: each job starts
      when the uplink frees, occupies it for its serialization time, and
      arrives one propagation latency later.
    * ``fair`` — a batch of jobs submitted together shares the uplink
      processor-sharing style: with serialization times ``s_1 <= ... <= s_n``
      (full rate), job i completes at ``f_i = f_{i-1} + (s_i - s_{i-1}) *
      (n - i + 1)`` after the batch start (everyone finishes no earlier than
      under FIFO; equal-size jobs all finish together at ``n * s``).
      Batches queue FIFO behind whatever the uplink is still serving.

    ``free_at[src]`` (the busy-until bookkeeping) is the only state; jobs
    are served in *scheduling* order — a retransmit scheduled eagerly for a
    future timeout occupies its slot when the simulator reaches it, which
    keeps the whole schedule a pure deterministic function of the run.
    """

    def __init__(self, n_clients: int, mode: str = "parallel"):
        if mode not in UPLINK_MODES:
            raise ValueError(f"uplink mode must be one of {UPLINK_MODES}, "
                             f"got {mode!r}")
        self.mode = mode
        self.free_at = np.zeros(n_clients)

    def schedule(self, links: LinkModel, src: int,
                 jobs: Sequence[tuple[int, float]],
                 t_request: float) -> list[tuple[float, float]]:
        """Place ``jobs`` = [(dst, n_bytes), ...] on ``src``'s uplink from
        ``t_request``; returns one (t_start, t_arrival) pair per job."""
        if not jobs:
            return []
        if self.mode == "parallel":
            return [(t_request,
                     t_request + links.transfer_time(nb, src, dst, t_request))
                    for dst, nb in jobs]
        t0 = max(t_request, float(self.free_at[src]))
        out: list[tuple[float, float]] = []
        if self.mode == "fifo":
            t = t0
            for dst, nb in jobs:
                s = links.serialization_time(nb, src, dst, t)
                out.append((t, t + s + float(links.latency_s[src, dst])))
                t += s
            self.free_at[src] = t
            return out
        # fair: exact processor sharing of the batch from t0
        ser = [links.serialization_time(nb, src, dst, t0) for dst, nb in jobs]
        order = np.argsort(ser, kind="stable")
        finish = np.zeros(len(jobs))
        f_prev, s_prev = 0.0, 0.0
        for rank, i in enumerate(order):
            f_prev += (ser[i] - s_prev) * (len(jobs) - rank)
            s_prev = ser[i]
            finish[i] = f_prev
        for (dst, _nb), f in zip(jobs, finish):
            out.append((t0, t0 + f + float(links.latency_s[src, dst])))
        self.free_at[src] = t0 + float(finish.max())
        return out

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        return {"free_at": self.free_at.copy()}

    def load_state(self, d: dict) -> None:
        self.free_at = np.asarray(d["free_at"], dtype=float).copy()


class LossModel:
    """Per-link Bernoulli message loss with timeout/retransmit.

    A message is retransmitted until it survives the drop draw or
    ``max_retries`` resends are exhausted; every attempt's bytes go on the
    wire (``LinkStats`` counts them).  The sender detects a loss by silence:
    attempt ``i+1`` is scheduled ``timeout_s`` after attempt ``i`` finished
    serializing.  Draws derive from ``(seed, src, dst, tag, LOSS_STREAM)``
    where ``tag`` identifies the message (round in sync mode, the sender's
    published version in async mode), so the loss pattern is a pure function
    of the run — independent of event ordering and bit-reproducible on
    resume.
    """

    def __init__(self, loss_prob: float, timeout_s: float = 1.0,
                 max_retries: int = 10, seed: int = 0):
        if not 0.0 <= loss_prob < 1.0:
            raise ValueError("loss_prob must be in [0, 1)")
        if timeout_s <= 0:
            raise ValueError("retransmit timeout must be positive")
        self.loss_prob = float(loss_prob)
        self.timeout_s = float(timeout_s)
        self.max_retries = int(max_retries)
        self.seed = int(seed)

    def attempts(self, src: int, dst: int, tag: int) -> tuple[int, bool]:
        """(number of transmissions, delivered?) for one message."""
        if self.loss_prob == 0.0:
            return 1, True
        rng = np.random.default_rng(np.random.SeedSequence(
            [self.seed, src, dst, tag, LOSS_STREAM]))
        draws = rng.random(self.max_retries + 1) >= self.loss_prob
        hit = np.flatnonzero(draws)
        if hit.size:
            return int(hit[0]) + 1, True
        return self.max_retries + 1, False


@dataclasses.dataclass
class Transfer:
    t_start: float
    t_end: float
    src: int
    dst: int
    bytes_values: float     # 4B-per-value payload (the paper's headline unit)
    bytes_wire: float       # payload + mask bitmap (what the link carries)
    attempt: int = 0        # 0 = first transmission, >0 = retransmit


class LinkStats:
    """Accumulates every simulated transfer.

    Totals use the paper's value-bytes convention (comparable to
    ``decentralized_comm``); ``*_wire`` adds the mask bitmap.  Retransmitted
    attempts count into the totals (the link really carried them) *and*
    into the ``retrans_*`` overlays, so reports can quote the loss-induced
    overhead separately.  ``transfers`` keeps the full timeline for
    per-link utilization and the busiest-node upload/download trajectories
    in ``repro.sim.report``.
    """

    def __init__(self, n_clients: int):
        self.n = n_clients
        self.up = np.zeros(n_clients)        # value-bytes uploaded per node
        self.down = np.zeros(n_clients)
        self.up_wire = np.zeros(n_clients)
        self.down_wire = np.zeros(n_clients)
        self.retrans_up = np.zeros(n_clients)       # value-bytes, attempts > 0
        self.retrans_up_wire = np.zeros(n_clients)
        self.edge_bytes = np.zeros((n_clients, n_clients))   # [dst, src]
        self.edge_busy_s = np.zeros((n_clients, n_clients))
        self.n_retransmits = 0
        self.n_lost = 0                      # messages never delivered
        self.transfers: list[Transfer] = []
        # gauges mirror the checkpointed accumulators (single source of
        # truth stays here), so snapshot_counters() reconciles exactly with
        # the virtual-clock transfer spans in an exported trace
        self.obs = CounterSet("sim.links")
        self.obs.gauge("transfers", fn=lambda: len(self.transfers))
        self.obs.gauge("n_retransmits", fn=lambda: self.n_retransmits)
        self.obs.gauge("n_lost", fn=lambda: self.n_lost)
        self.obs.gauge("bytes_values", fn=lambda: float(self.up.sum()))
        self.obs.gauge("bytes_wire", fn=lambda: float(self.up_wire.sum()))
        # obs layer 2: bounded-memory sketches of transfer durations/sizes
        # (error-bounded quantiles without walking the transfers list);
        # the checkpointed transfers list stays the source of truth and the
        # sketches are rebuilt from it on load_state
        self._init_sketches()

    def _init_sketches(self) -> None:
        self.series = SeriesSet("sim.links")
        self._h_xfer_s = self.series.histogram("transfer_s")
        self._h_xfer_bytes = self.series.histogram("transfer_wire_bytes")
        for tr in self.transfers:
            self._h_xfer_s.add(max(0.0, tr.t_end - tr.t_start))
            self._h_xfer_bytes.add(tr.bytes_wire)

    def transfer_time_quantile(self, q: float) -> float:
        """Error-bounded (alpha=1%) transfer-duration quantile in seconds."""
        return self._h_xfer_s.quantile(q)

    def record(self, src: int, dst: int, bytes_values: float,
               bytes_wire: float, t_start: float, t_end: float,
               attempt: int = 0) -> None:
        self._h_xfer_s.add(max(0.0, t_end - t_start))
        self._h_xfer_bytes.add(bytes_wire)
        self.up[src] += bytes_values
        self.down[dst] += bytes_values
        self.up_wire[src] += bytes_wire
        self.down_wire[dst] += bytes_wire
        if attempt > 0:
            self.retrans_up[src] += bytes_values
            self.retrans_up_wire[src] += bytes_wire
            self.n_retransmits += 1
        self.edge_bytes[dst, src] += bytes_values
        self.edge_busy_s[dst, src] += max(0.0, t_end - t_start)
        self.transfers.append(Transfer(t_start, t_end, src, dst,
                                       bytes_values, bytes_wire, attempt))

    def record_lost(self, src: int, dst: int) -> None:
        """A message exhausted its retransmit budget and was never
        delivered (its attempts were still ``record``-ed)."""
        self.n_lost += 1

    # -- aggregates --------------------------------------------------------
    @property
    def total_mb(self) -> float:
        return float(self.up.sum()) * MB

    @property
    def total_wire_mb(self) -> float:
        return float(self.up_wire.sum()) * MB

    @property
    def retrans_mb(self) -> float:
        return float(self.retrans_up.sum()) * MB

    def per_node_mb(self) -> np.ndarray:
        """Paper convention: each node's traffic is its busiest direction."""
        return np.maximum(self.up, self.down) * MB

    def busiest_node(self) -> tuple[int, float]:
        per = self.per_node_mb()
        k = int(np.argmax(per))
        return k, float(per[k])

    def snapshot(self) -> dict:
        return {"up": self.up.copy(), "down": self.down.copy(),
                "up_wire": self.up_wire.copy(),
                "down_wire": self.down_wire.copy()}

    def busiest_mb_until(self, t: float) -> float:
        """Busiest node's value-MB counting only transfers finished by t."""
        up = np.zeros(self.n)
        down = np.zeros(self.n)
        for tr in self.transfers:
            if tr.t_end <= t:
                up[tr.src] += tr.bytes_values
                down[tr.dst] += tr.bytes_values
        return float(np.maximum(up, down).max()) * MB

    def node_timeline(self, k: int) -> list[tuple[float, float, float]]:
        """(t, cumulative up MB, cumulative down MB) at each transfer end
        involving node k — the busiest-node upload/download timeline."""
        out, up, down = [], 0.0, 0.0
        for tr in sorted(self.transfers, key=lambda r: (r.t_end, r.src, r.dst)):
            if tr.src != k and tr.dst != k:
                continue
            if tr.src == k:
                up += tr.bytes_values
            if tr.dst == k:
                down += tr.bytes_values
            out.append((tr.t_end, up * MB, down * MB))
        return out

    def utilization(self, span_s: float) -> np.ndarray:
        """Per-edge busy fraction over the run (capped at 1.0)."""
        if span_s <= 0:
            return np.zeros_like(self.edge_busy_s)
        return np.minimum(self.edge_busy_s / span_s, 1.0)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        """Flat-array form for ``repro.checkpoint`` (exact round trip)."""
        tr = np.array(
            [[t.t_start, t.t_end, t.src, t.dst,
              t.bytes_values, t.bytes_wire, t.attempt]
             for t in self.transfers], dtype=np.float64).reshape(-1, 7)
        return {
            "up": self.up.copy(), "down": self.down.copy(),
            "up_wire": self.up_wire.copy(), "down_wire": self.down_wire.copy(),
            "retrans_up": self.retrans_up.copy(),
            "retrans_up_wire": self.retrans_up_wire.copy(),
            "edge_bytes": self.edge_bytes.copy(),
            "edge_busy_s": self.edge_busy_s.copy(),
            "counters": np.asarray([self.n_retransmits, self.n_lost],
                                   dtype=np.int64),
            "transfers": tr,
        }

    def load_state(self, d: dict) -> None:
        for name in ("up", "down", "up_wire", "down_wire",
                     "retrans_up", "retrans_up_wire",
                     "edge_bytes", "edge_busy_s"):
            setattr(self, name, np.asarray(d[name], dtype=float).copy())
        counters = np.asarray(d["counters"], dtype=np.int64)
        self.n_retransmits, self.n_lost = int(counters[0]), int(counters[1])
        self.transfers = [
            Transfer(float(r[0]), float(r[1]), int(r[2]), int(r[3]),
                     float(r[4]), float(r[5]), int(r[6]))
            for r in np.asarray(d["transfers"], dtype=np.float64)]
        self._init_sketches()
