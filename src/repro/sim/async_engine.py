"""Event-driven simulator for decentralized sparse training.

``SimEngine`` drives the *existing* ``Strategy`` hook classes (no strategy
changes) through a discrete-event timeline with per-edge link models
(``sim.links``), per-client compute speeds (``sim.events.ComputeModel``) and
client up/down schedules (``sim.availability``).  Two modes:

* ``mode="sync"`` — the synchronous barrier protocol.  State evolution is
  *bit-identical* to ``RoundEngine`` (it runs the exact same round body via
  the engine's ``_run_one_round``); the simulator only adds a virtual
  timeline on top: per-round duration = slowest client's compute + its
  slowest transfer, every mix-phase message measured on the wire from the
  sender's current mask nnz.

* ``mode="async"`` — staleness-aware asynchronous push-gossip.  Each client
  runs its own local-round clock: wake, mix whatever neighbor payloads have
  *arrived* by now via the per-client ``Strategy.mix_one`` hook (O(degree)
  packed folds for the decentralized strategies, generic O(K) swap
  fallback otherwise), train for ``flops / (flops_per_s * speed_k)``
  virtual seconds, push the updated *packed* sparse model to ``degree``
  sampled receivers (transfer time from the link model, payload sized by
  the wire codec), sleep until the sends are scheduled, repeat.  ``staleness >= 0`` enforces the
  bounded-staleness (stale-synchronous-parallel) protocol: no client may run
  more than ``staleness`` rounds ahead of the slowest, and messages older
  than the bound are not mixed; ``staleness < 0`` is fully asynchronous.
  ``staleness=0`` degenerates to a barrier.

Worked example::

    from repro.fl import FLConfig, make_cnn_task, make_strategy
    from repro.data import build_federated_image_task
    from repro.sim import ComputeModel, LinkModel, SimEngine

    clients, _ = build_federated_image_task(0, n_clients=8)
    task = make_cnn_task("smallcnn")
    cfg = FLConfig(n_clients=8, rounds=20, degree=3)
    eng = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=2,
                    links=LinkModel.skewed(8, mbps=100, skew=10),
                    compute=ComputeModel.heterogeneous(8))
    for m in eng.rounds():          # SimRoundMetrics: acc + virtual time
        print(m.round, m.acc_mean, m.sim_time_s)
    print(eng.report().to_dict())   # wall-clock-to-target, busiest node, ...

Determinism: all training randomness is derived per (seed, local round,
client) exactly as in ``RoundEngine``; event ties break on insertion order;
there is no wall-clock anywhere in the virtual timeline — a simulation is a
pure function of (strategy, data, cfg, links, compute, availability).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.accounting import edge_message_bytes
from repro.core.evolve import cosine_prune_rate
from repro.core.topology import directed_out_neighbors, make_adjacency
from repro.fl.base import evaluate_clients
from repro.fl.engine import (
    RoundCtx,
    RoundEngine,
    RoundMetrics,
    StrategyBase,
)
from repro.sim.availability import AlwaysUp, Availability
from repro.sim.events import (
    ARRIVAL,
    DONE,
    WAKE,
    ComputeModel,
    EventQueue,
    VirtualClock,
)
from repro.sim.links import MB, LinkModel, LinkStats, measure_payload
from repro.sim.report import SimReport, build_report


@dataclasses.dataclass
class SimRoundMetrics(RoundMetrics):
    """RoundMetrics + the virtual timeline (JSONL-streams through the same
    callback protocol — ``to_dict`` inherits)."""
    sim_time_s: float = 0.0          # virtual clock after this round
    sim_round_s: float = 0.0         # this round's virtual duration
    measured_total_mb: float = 0.0   # cumulative measured bytes-on-wire
    busiest_up_mb: float = 0.0       # cumulative, busiest node convention
    busiest_down_mb: float = 0.0
    min_round: int = 0               # async: slowest / fastest client rounds
    max_round: int = 0


@dataclasses.dataclass
class _Message:
    """A published model.  ``version`` counts completed rounds: the model a
    sender publishes after finishing round t has version t+1, so a receiver
    at round t mixing a version-t model sees lag 0 — exactly the freshness
    the synchronous protocol provides (mix at round t uses end-of-round-t-1
    models).  The staleness bound filters on this lag."""
    version: int
    payload: dict       # StrategyBase.snapshot_message


class SimEngine(RoundEngine):
    """Discrete-event wrapper around the Strategy hook protocol."""

    def __init__(self, strategy: StrategyBase, task, clients, cfg,
                 callbacks: Sequence = (), local_exec: str = "auto",
                 mode: str = "sync", staleness: int = 0,
                 links: Optional[LinkModel] = None,
                 compute: Optional[ComputeModel] = None,
                 availability: Optional[Availability] = None,
                 round_s: Optional[float] = None,
                 compute_speeds: Optional[np.ndarray] = None,
                 max_down_retries: int = 100):
        if mode not in ("sync", "async"):
            raise ValueError(f"mode must be sync|async, got {mode}")
        super().__init__(strategy, task, clients, cfg,
                         callbacks=callbacks, local_exec=local_exec)
        n = len(clients)
        self.mode = mode
        self.staleness = int(staleness)
        #: async: consecutive down-slot retries before a client is declared
        #: dead (stops participating and no longer bounds SSP progress)
        self.max_down_retries = int(max_down_retries)
        self.links = links or LinkModel.uniform(n)
        self.availability = availability or AlwaysUp(n)
        if compute is None:
            if round_s is not None:
                # anchor the timescale: a speed-1.0 client does one local
                # round (at this strategy's analytic FLOPs) in round_s
                compute = ComputeModel.paced(
                    n, self.round_flops_estimate(), round_s,
                    speeds=compute_speeds)
            elif compute_speeds is not None:
                compute = ComputeModel(speeds=compute_speeds)
            else:
                compute = ComputeModel.uniform(n)
        self.compute = compute
        self.clock = VirtualClock()
        self.stats = LinkStats(n)
        self.acc_trace: list[tuple[float, float]] = []   # (virtual s, acc)
        # async invariant observability (tested in tests/test_sim.py)
        self.observed_spread = 0          # max t_k - min(t) at execution
        self.observed_mix_lag = 0         # max version lag actually mixed
        self.mixed_messages = 0           # neighbor models mixed over the run
        self._pending_edges = None        # sync: this round's message sizes

    # ------------------------------------------------------------------
    # shared
    # ------------------------------------------------------------------
    @property
    def sim_time(self) -> float:
        return self.clock.now

    def round_flops_estimate(self) -> float:
        """Analytic per-client FLOPs of one local round (round 0)."""
        ctx = self._make_ctx(0)
        return float(self.strategy.round_flops(self.state, ctx).per_round_flops)

    def restore(self, path: str):
        # engine checkpoints carry no virtual clock / link stats / accuracy
        # trace, so a resumed simulation would silently report wrong
        # deployment numbers — refuse rather than mislead
        raise NotImplementedError(
            "SimEngine does not support checkpoint resume (the virtual "
            "timeline is not checkpointed); rerun the simulation or resume "
            "with RoundEngine")

    def report(self, targets: Sequence[float] = ()) -> SimReport:
        return build_report(self.mode, self.stats, self.acc_trace,
                            self.clock.now, targets)

    def _make_ctx(self, t: int, alive: Optional[np.ndarray] = None) -> RoundCtx:
        if alive is None and not self.availability.always_up:
            alive = self.availability.alive(t)
        return super()._make_ctx(t, alive=alive)

    # ------------------------------------------------------------------
    # sync mode: RoundEngine semantics + a virtual timeline
    # ------------------------------------------------------------------
    def _pre_round(self, ctx: RoundCtx) -> None:
        # capture what the mix phase transmits: the pre-mix masks' nnz on the
        # current adjacency (measured, not assumed).  Strategies that don't
        # gossip over the adjacency (server-based / local-only) move no
        # P2P bytes, so their timeline is compute-only
        if not self.strategy.decentralized:
            self._pending_edges = None
            return
        strat, state = self.strategy, self.state
        nnz = [strat.message_nnz(state, k) for k in range(len(self.clients))]
        coords = strat.message_coords(state, 0)
        self._pending_edges = (
            edge_message_bytes(ctx.adjacency, nnz),
            edge_message_bytes(ctx.adjacency, nnz, coords, with_bitmap=True))

    def _finish_metrics(self, ctx: RoundCtx, metrics: RoundMetrics) -> RoundMetrics:
        edges = self._pending_edges
        self._pending_edges = None
        t0 = self.clock.now
        compute_s = np.array([
            self.compute.local_time(k, metrics.flops_round)
            for k in range(len(self.clients))])
        send_end = np.zeros(len(self.clients))
        if edges is not None:
            edges_v, edges_w = edges
            for dst, src in zip(*np.nonzero(edges_v)):
                start = t0 + compute_s[src]
                end = start + self.links.transfer_time(
                    edges_w[dst, src], src, dst)
                self.stats.record(src, dst, edges_v[dst, src],
                                  edges_w[dst, src], start, end)
                send_end[src] = max(send_end[src], end - t0)
        dur = float(np.maximum(compute_s, send_end).max()) if len(compute_s) else 0.0
        self.clock.advance_to(t0 + dur)
        if metrics.acc_mean is not None:
            self.acc_trace.append((self.clock.now, metrics.acc_mean))
        up, down = self.stats.up * MB, self.stats.down * MB
        return SimRoundMetrics(
            **dataclasses.asdict(metrics),
            sim_time_s=self.clock.now, sim_round_s=dur,
            measured_total_mb=self.stats.total_mb,
            busiest_up_mb=float(up.max()), busiest_down_mb=float(down.max()),
            min_round=ctx.t + 1, max_round=ctx.t + 1)

    # ------------------------------------------------------------------
    # async mode
    # ------------------------------------------------------------------
    def rounds(self):
        if self.mode == "sync":
            yield from super().rounds()
            return
        yield from self._async_rounds()

    def _mix_one(self, k: int, senders: dict[int, _Message], ctx: RoundCtx) -> None:
        """Mix client k against arrived payloads via ``Strategy.mix_one``.

        Decentralized strategies implement it as O(degree) packed folds
        (``repro.sparse.ops``); the ``StrategyBase`` fallback swaps the
        payloads in, runs the full ``mix`` on an adjacency whose only
        non-identity row is k's, and restores — correct for any strategy,
        but O(K) tree work per activation.
        """
        self.strategy.mix_one(
            self.state, k, {j: m.payload for j, m in senders.items()}, ctx)

    def _async_rounds(self):
        cfg = self.cfg
        strat = self.strategy
        n = len(self.clients)
        if self._next_round != 0:
            raise NotImplementedError(
                "async simulation does not support checkpoint resume")
        if not strat.decentralized:
            # a non-gossip mix would read live peer state instead of what
            # arrived over the simulated links — every reported number would
            # be fiction, so refuse
            raise ValueError(
                f"async simulation requires a decentralized strategy whose "
                f"mix gossips over ctx.adjacency; '{strat.name}' is not "
                f"(strategy.decentralized is False)")
        if not isinstance(self.state.get("params"), list):
            raise ValueError(
                f"async simulation requires per-client state['params'] lists "
                f"(strategy '{strat.name}' has none)")

        q = EventQueue()
        inbox: list[dict[int, _Message]] = [dict() for _ in range(n)]
        t_local = np.zeros(n, dtype=int)
        down_count = np.zeros(n, dtype=int)    # total down slots (slot offset)
        down_streak = np.zeros(n, dtype=int)   # consecutive down retries
        waiting: set[int] = set()
        done: set[int] = set()
        dead: set[int] = set()
        emitted = 0                      # global rounds yielded so far
        self._stop = False
        for k in range(n):
            q.push(0.0, WAKE, k=k)

        def live_floor() -> int:
            """Slowest *participating* client's completed rounds — dead
            clients (permanently unavailable) stop bounding progress.  With
            nobody left alive no further progress is possible, so the floor
            freezes at the rounds already emitted (the run ends partial
            rather than fabricating untrained rounds)."""
            alive_t = [int(t_local[i]) for i in range(n) if i not in dead]
            return min(alive_t) if alive_t else emitted

        def flops_at(t: int) -> float:
            ctx = self._make_ctx(int(t))
            return strat.round_flops(self.state, ctx).per_round_flops

        prev_snap = self.stats.snapshot()

        def emit_rounds():
            """Yield one SimRoundMetrics per newly completed global round
            (a round is complete once the slowest client passes it)."""
            nonlocal emitted, prev_snap
            floor = live_floor()
            out = []
            while emitted < floor:
                t = emitted
                ctx = self._make_ctx(t)
                comm_sn = self.stats.snapshot()
                win_up = comm_sn["up"] - prev_snap["up"]
                win_down = comm_sn["down"] - prev_snap["down"]
                win_up_w = comm_sn["up_wire"] - prev_snap["up_wire"]
                win_down_w = comm_sn["down_wire"] - prev_snap["down_wire"]
                prev_snap = comm_sn
                busiest = float(np.maximum(win_up, win_down).max()) * MB
                flops = strat.round_flops(self.state, ctx)
                self._comm["busiest_mb"].append(busiest)
                self._comm["avg_per_node_mb"].append(
                    float(np.maximum(win_up, win_down).mean()) * MB)
                self._comm["total_mb"].append(float(win_up.sum()) * MB)
                self._comm["busiest_mb_with_bitmap"].append(
                    float(np.maximum(win_up_w, win_down_w).max()) * MB)
                for key in self._flops:
                    self._flops[key].append(float(getattr(flops, key)))
                acc_mean = acc_std = None
                if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
                    accs = evaluate_clients(
                        self.task, strat.eval_params(self.state, ctx),
                        self.clients)
                    acc_mean = float(np.mean(accs))
                    acc_std = float(np.std(accs))
                    self._acc_history.append(acc_mean)
                    self._acc_stds.append(acc_std)
                    self._eval_rounds.append(t)
                    self.acc_trace.append((self.clock.now, acc_mean))
                up, down = self.stats.up * MB, self.stats.down * MB
                out.append(SimRoundMetrics(
                    round=t, lr=ctx.lr, prune_rate=ctx.prune_rate,
                    comm_busiest_mb=busiest,
                    comm_rows={"busiest_MB": round(busiest, 3)},
                    flops_round=flops.per_round_flops,
                    cum_flops=float(np.sum(self._flops["per_round_flops"])),
                    acc_mean=acc_mean, acc_std=acc_std, wall_s=0.0,
                    sim_time_s=self.clock.now, sim_round_s=0.0,
                    measured_total_mb=self.stats.total_mb,
                    busiest_up_mb=float(up.max()),
                    busiest_down_mb=float(down.max()),
                    min_round=int(t_local.min()),
                    max_round=int(t_local.max())))
                emitted += 1
                self._next_round = emitted
            return out

        while q and len(done) < n and not self._stop:
            ev = q.pop()
            self.clock.advance_to(ev.time)
            if ev.kind == ARRIVAL:
                k, src = ev.data["k"], ev.data["src"]
                msg = ev.data["msg"]
                cur = inbox[k].get(src)
                if cur is None or msg.version >= cur.version:
                    inbox[k][src] = msg
                if k in waiting:
                    waiting.discard(k)
                    q.push(ev.time, WAKE, k=k)
                continue

            if ev.kind == DONE:
                # a client's round completes at its compute-finish time: only
                # now does its local clock advance, unblocking SSP waiters
                # and (possibly) completing a global round
                k = ev.data["k"]
                t_local[k] += 1
                self._last_finish = max(getattr(self, "_last_finish", 0.0),
                                        ev.time)
                if t_local[k] >= cfg.rounds:
                    done.add(k)
                else:
                    q.push(ev.time, WAKE, k=k)
                if live_floor() > emitted:
                    for w in sorted(waiting):
                        q.push(ev.time, WAKE, k=w)
                    waiting.clear()
                    for m in emit_rounds():
                        for cb in self.callbacks:
                            cb.on_round_end(self, m)
                        yield m
                        if self._stop:
                            break
                continue

            k = ev.data["k"]
            if k in done:
                continue
            t_k = int(t_local[k])
            # bounded staleness (SSP): never run more than `staleness` rounds
            # ahead of the slowest participating client
            spread = t_k - live_floor()
            if self.staleness >= 0 and spread > self.staleness:
                waiting.add(k)
                continue
            # availability: a down client retries one mean-round later
            # against its next slot; after max_down_retries consecutive down
            # slots it is declared dead so it cannot stall the whole network
            if not self.availability.up(k, t_k + int(down_count[k])):
                down_count[k] += 1
                down_streak[k] += 1
                if down_streak[k] > self.max_down_retries:
                    dead.add(k)
                    done.add(k)
                    for w in sorted(waiting):
                        q.push(ev.time, WAKE, k=w)
                    waiting.clear()
                    for m in emit_rounds():
                        for cb in self.callbacks:
                            cb.on_round_end(self, m)
                        yield m
                        if self._stop:
                            break
                    continue
                retry = self.compute.mean_round_s(flops_at(t_k))
                q.push(ev.time + max(retry, 1e-9), WAKE, k=k)
                continue
            down_streak[k] = 0
            self.observed_spread = max(self.observed_spread, max(0, spread))

            # 1. mix what has arrived (respecting the staleness bound)
            senders = {
                j: m for j, m in inbox[k].items()
                if self.staleness < 0 or t_k - m.version <= self.staleness}
            for m in senders.values():
                self.observed_mix_lag = max(self.observed_mix_lag,
                                            max(0, t_k - m.version))
            self.mixed_messages += len(senders)
            a = np.eye(n)
            if senders:
                a[k, list(senders)] = 1.0
            ctx = RoundCtx(
                t=t_k, cfg=cfg, task=self.task, clients=self.clients,
                lr=cfg.lr_at(t_k),
                prune_rate=cosine_prune_rate(cfg.alpha0, t_k, cfg.rounds),
                adjacency=a)
            self._mix_one(k, senders, ctx)

            # 2. local phase + mask evolution (same hooks, same derived rng)
            self.run_local_phase(ctx, [k])
            strat.evolve(self.state, k, ctx)

            # 3. compute time, then push to sampled receivers.  The payload
            # is the packed message itself; its sizes are codec-measured
            # from what actually ships, not recomputed from nnz
            flops = strat.round_flops(self.state, ctx).per_round_flops
            finish = ev.time + self.compute.local_time(k, flops)
            payload = strat.snapshot_message(self.state, k)
            bytes_v, bytes_w = measure_payload(payload)
            msg = _Message(version=t_k + 1, payload=payload)
            for j in directed_out_neighbors(n, k, t_k, cfg.degree, cfg.seed):
                j = int(j)
                arrive = finish + self.links.transfer_time(bytes_w, k, j)
                self.stats.record(k, j, bytes_v, bytes_w, finish, arrive)
                q.push(arrive, ARRIVAL, k=j, src=k, msg=msg)

            # 4. the round completes (and the local clock advances) at the
            # compute-finish time, handled by the DONE event above
            q.push(finish, DONE, k=k)
        # the run ends when the last client finishes its compute, even if
        # some already-sent messages are still in flight
        self.clock.advance_to(max(getattr(self, "_last_finish", 0.0),
                                  self.clock.now))
        for m in emit_rounds():
            for cb in self.callbacks:
                cb.on_round_end(self, m)
            yield m
        for cb in self.callbacks:
            cb.on_run_end(self)
