"""Event-driven simulator for decentralized sparse training (fault-realistic).

``SimEngine`` drives the *existing* ``Strategy`` hook classes (no strategy
changes) through a discrete-event timeline with per-edge link models
(``sim.links``), per-client compute speeds (``sim.events.ComputeModel``) and
client up/down schedules (``sim.availability``).  Two modes:

* ``mode="sync"`` — the synchronous barrier protocol.  State evolution is
  *bit-identical* to ``RoundEngine`` (it runs the exact same round body via
  the engine's ``_run_one_round``); the simulator only adds a virtual
  timeline on top: per-round duration = slowest client's compute + its
  slowest transfer, every mix-phase message measured on the wire from the
  sender's current mask nnz.

* ``mode="async"`` — staleness-aware asynchronous push-gossip.  Each client
  runs its own local-round clock: wake, mix whatever neighbor payloads have
  *arrived* by now via the per-client ``Strategy.mix_one`` hook (O(degree)
  packed folds for the decentralized strategies, generic O(K) swap
  fallback otherwise), train for ``flops / (flops_per_s * speed_k)``
  virtual seconds, push the updated *packed* sparse model to ``degree``
  sampled receivers (transfer time from the link model, payload sized by
  the wire codec), sleep until the sends are scheduled, repeat.  ``staleness >= 0`` enforces the
  bounded-staleness (stale-synchronous-parallel) protocol: no client may run
  more than ``staleness`` rounds ahead of the slowest, and messages older
  than the bound are not mixed; ``staleness < 0`` is fully asynchronous.
  ``staleness=0`` degenerates to a barrier.

Fault realism (v2):

* **Shared uplinks** — ``uplink="fifo"`` / ``"fair"`` serializes a sender's
  concurrent transfers on one uplink (``sim.links.UplinkScheduler``)
  instead of running every edge in parallel, which stretches busiest-node
  timelines exactly where the paper's headline metric lives.
* **Message loss + retransmit** — a ``sim.links.LossModel`` drops messages
  per-link with derived-rng Bernoulli draws; the sender retransmits after a
  timeout and every attempt's bytes are measured on the wire.  In sync mode
  the barrier's transport is *reliable*: the drop draws only decide how
  many transmissions the timeline and byte counters record (state evolution
  stays bit-identical to ``RoundEngine``); in async mode a message that
  exhausts its retransmit budget is really lost — the receiver just never
  mixes it.
* **Trace-driven bandwidth** — a ``sim.links.BandwidthTrace`` on the
  ``LinkModel`` scales link rates over virtual time.
* **Checkpoint/resume** — ``save``/``restore`` round-trip the *complete*
  simulation through ``repro.checkpoint``: virtual clock, pending event
  queue (with in-flight packed payloads), per-client local clocks and
  inboxes, ``LinkStats``, uplink busy-until state and accuracy traces.  A
  run checkpointed at any round (sync) or any emitted round mid-event-loop
  (async) and resumed is bit-identical to the uninterrupted run — every
  tie-break survives because event insertion sequences are persisted, and
  all randomness (training, topology, loss) is derived per (seed, ...)
  rather than carried in generator objects.

Worked example::

    from repro.fl import FLConfig, make_cnn_task, make_strategy
    from repro.data import build_federated_image_task
    from repro.sim import ComputeModel, LinkModel, LossModel, SimEngine

    clients, _ = build_federated_image_task(0, n_clients=8)
    task = make_cnn_task("smallcnn")
    cfg = FLConfig(n_clients=8, rounds=20, degree=3)
    eng = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=2,
                    links=LinkModel.skewed(8, mbps=100, skew=10),
                    compute=ComputeModel.heterogeneous(8),
                    uplink="fifo", loss=LossModel(0.1, timeout_s=0.5))
    for m in eng.rounds():          # SimRoundMetrics: acc + virtual time
        print(m.round, m.acc_mean, m.sim_time_s)
    print(eng.report().to_dict())   # wall-clock-to-target, busiest node, ...

Determinism: all training randomness is derived per (seed, local round,
client) exactly as in ``RoundEngine``; event ties break on insertion order;
there is no wall-clock anywhere in the virtual timeline — a simulation is a
pure function of (strategy, data, cfg, links, compute, availability, loss).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.core.accounting import edge_message_bytes
from repro.core.evolve import cosine_prune_rate
from repro.core.topology import directed_out_neighbors, make_adjacency
from repro.fl.base import evaluate_clients
from repro.fl.engine import (
    RoundCtx,
    RoundEngine,
    RoundMetrics,
    StrategyBase,
    _pack,
    _unpack,
)
from repro.obs import VIRTUAL, SeriesSet, get_tracer
from repro.sim.availability import AlwaysUp, Availability
from repro.sim.events import (
    ARRIVAL,
    DONE,
    WAKE,
    ComputeModel,
    Event,
    EventQueue,
    VirtualClock,
)
from repro.sim.links import (
    MB,
    LinkModel,
    LinkStats,
    LossModel,
    UplinkScheduler,
    measure_payload,
)
from repro.sim.report import SimReport, build_report

_KIND_CODES = {WAKE: 0, ARRIVAL: 1, DONE: 2}
_CODE_KINDS = {v: k for k, v in _KIND_CODES.items()}
_MODE_CODES = {"sync": 0, "async": 1}
_SIM_CKPT_VERSION = 1


@dataclasses.dataclass
class SimRoundMetrics(RoundMetrics):
    """RoundMetrics + the virtual timeline (JSONL-streams through the same
    callback protocol — ``to_dict`` inherits)."""
    sim_time_s: float = 0.0          # virtual clock after this round
    sim_round_s: float = 0.0         # this round's virtual duration
    measured_total_mb: float = 0.0   # cumulative measured bytes-on-wire
    busiest_up_mb: float = 0.0       # cumulative, busiest node convention
    busiest_down_mb: float = 0.0
    min_round: int = 0               # async: slowest / fastest client rounds
    max_round: int = 0
    retrans_mb: float = 0.0          # cumulative retransmitted value-MB
    lost_messages: int = 0           # cumulative undelivered messages (async)


@dataclasses.dataclass
class _Message:
    """A published model.  ``version`` counts completed rounds: the model a
    sender publishes after finishing round t has version t+1, so a receiver
    at round t mixing a version-t model sees lag 0 — exactly the freshness
    the synchronous protocol provides (mix at round t uses end-of-round-t-1
    models).  The staleness bound filters on this lag."""
    version: int
    payload: dict       # StrategyBase.snapshot_message


@dataclasses.dataclass
class _AsyncState:
    """The complete mutable state of one asynchronous event loop — held on
    the engine (not in generator locals) so ``save`` can serialize a
    *mid-run* simulation and ``restore`` can resume it bit-identically."""
    q: EventQueue
    inbox: list                      # per client: {src: _Message}
    t_local: np.ndarray              # completed local rounds per client
    down_count: np.ndarray           # total down slots (slot offset)
    down_streak: np.ndarray          # consecutive down retries
    waiting: set                     # SSP-blocked clients
    done: set
    dead: set                        # exhausted max_down_retries
    emitted: int = 0                 # global rounds yielded so far
    last_finish: float = 0.0
    prev_snap: Optional[dict] = None # LinkStats snapshot at last emission


class SimEngine(RoundEngine):
    """Discrete-event wrapper around the Strategy hook protocol."""

    def __init__(self, strategy: StrategyBase, task, clients, cfg,
                 callbacks: Sequence = (), local_exec: str = "auto",
                 mode: str = "sync", staleness: int = 0,
                 links: Optional[LinkModel] = None,
                 compute: Optional[ComputeModel] = None,
                 availability: Optional[Availability] = None,
                 round_s: Optional[float] = None,
                 compute_speeds: Optional[np.ndarray] = None,
                 max_down_retries: int = 100,
                 uplink: str = "parallel",
                 loss: Optional[LossModel] = None):
        if mode not in ("sync", "async"):
            raise ValueError(f"mode must be sync|async, got {mode}")
        super().__init__(strategy, task, clients, cfg,
                         callbacks=callbacks, local_exec=local_exec)
        n = len(clients)
        self.mode = mode
        self.staleness = int(staleness)
        #: async: consecutive down-slot retries before a client is declared
        #: dead (stops participating and no longer bounds SSP progress)
        self.max_down_retries = int(max_down_retries)
        self.links = links or LinkModel.uniform(n)
        self.availability = availability or AlwaysUp(n)
        self.uplink = UplinkScheduler(n, uplink)
        self.loss = loss
        if compute is None:
            if round_s is not None:
                # anchor the timescale: a speed-1.0 client does one local
                # round (at this strategy's analytic FLOPs) in round_s
                compute = ComputeModel.paced(
                    n, self.round_flops_estimate(), round_s,
                    speeds=compute_speeds)
            elif compute_speeds is not None:
                compute = ComputeModel(speeds=compute_speeds)
            else:
                compute = ComputeModel.uniform(n)
        self.compute = compute
        self.clock = VirtualClock()
        self.stats = LinkStats(n)
        self.acc_trace: list[tuple[float, float]] = []   # (virtual s, acc)
        # obs layer 2: virtual-clock fleet series, sampled once per emitted
        # round (not checkpointed — LinkStats stays the source of truth)
        self.sim_series = SeriesSet("sim.engine")
        # async invariant observability (tested in tests/test_sim.py)
        self.observed_spread = 0          # max t_k - min(t) at execution
        self.observed_mix_lag = 0         # max version lag actually mixed
        self.mixed_messages = 0           # neighbor models mixed over the run
        self._pending_edges = None        # sync: this round's message sizes
        self._as: Optional[_AsyncState] = None   # async event-loop state
        # trace-only transient: virtual time each SSP-blocked client started
        # waiting (not checkpointed — resumed runs restart open waits)
        self._wait_since: dict[int, float] = {}

    # ------------------------------------------------------------------
    # shared
    # ------------------------------------------------------------------
    @property
    def sim_time(self) -> float:
        return self.clock.now

    def round_flops_estimate(self) -> float:
        """Analytic per-client FLOPs of one local round (round 0)."""
        ctx = self._make_ctx(0)
        return float(self.strategy.round_flops(self.state, ctx).per_round_flops)

    def report(self, targets: Sequence[float] = ()) -> SimReport:
        return build_report(self.mode, self.stats, self.acc_trace,
                            self.clock.now, targets)

    def _make_ctx(self, t: int, alive: Optional[np.ndarray] = None) -> RoundCtx:
        if alive is None and not self.availability.always_up:
            alive = self.availability.alive(t)
        return super()._make_ctx(t, alive=alive)

    # ------------------------------------------------------------------
    # transfers: shared uplink + loss/retransmit (both modes)
    # ------------------------------------------------------------------
    def _trace_xfer(self, src: int, dst: int, bytes_v: float, bytes_w: float,
                    t_start: float, t_end: float, attempt: int) -> None:
        """Mirror one ``LinkStats.record`` as virtual-clock trace spans —
        same floats, so trace spans reconcile with the transfer log
        bit-for-bit.  A per-edge span on ``link/src->dst`` plus, under a
        shared-uplink discipline, the serialization slot on ``uplink/src``
        (the arrival minus propagation latency is when the uplink frees)."""
        tr = get_tracer()
        if not tr.enabled:
            return
        tr.add_span("retransmit" if attempt else "transfer",
                    t_start, t_end, track=f"link/{src}->{dst}", clock=VIRTUAL,
                    src=src, dst=dst, bytes_values=bytes_v,
                    bytes_wire=bytes_w, attempt=attempt)
        if self.uplink.mode != "parallel":
            tr.add_span("uplink.busy", t_start,
                        t_end - float(self.links.latency_s[src, dst]),
                        track=f"uplink/{src}", clock=VIRTUAL, dst=dst)

    def _transmit(self, src: int, jobs: list[tuple[int, float, float]],
                  t_request: float, tag: int,
                  reliable: bool) -> list[tuple[int, bool, float]]:
        """Put ``jobs`` = [(dst, value_bytes, wire_bytes), ...] on ``src``'s
        uplink at ``t_request``; apply the loss model per edge, scheduling
        each retransmit ``timeout_s`` after the previous attempt left the
        uplink.  Every attempt is recorded in ``LinkStats``.  Returns one
        (dst, delivered, t_last_arrival) per job; with ``reliable=True``
        (sync barrier) the final attempt always delivers."""
        slots = self.uplink.schedule(
            self.links, src, [(d, w) for d, _v, w in jobs], t_request)
        out = []
        for (dst, bytes_v, bytes_w), (t_start, t_end) in zip(jobs, slots):
            attempts, delivered = (self.loss.attempts(src, dst, tag)
                                   if self.loss is not None else (1, True))
            self.stats.record(src, dst, bytes_v, bytes_w, t_start, t_end,
                              attempt=0)
            self._trace_xfer(src, dst, bytes_v, bytes_w, t_start, t_end, 0)
            end = t_end
            for a in range(1, attempts):
                t_retry = (end - float(self.links.latency_s[src, dst])
                           + self.loss.timeout_s)
                (t2, e2), = self.uplink.schedule(
                    self.links, src, [(dst, bytes_w)], t_retry)
                self.stats.record(src, dst, bytes_v, bytes_w, t2, e2,
                                  attempt=a)
                self._trace_xfer(src, dst, bytes_v, bytes_w, t2, e2, a)
                end = e2
            if reliable:
                delivered = True
            if not delivered:
                self.stats.record_lost(src, dst)
            out.append((dst, delivered, end))
        return out

    def _sample_sim_series(self) -> None:
        """One virtual-clock sample of the fleet series.  The cumulative
        counter-kind byte samples reconcile exactly with the ``sim.links``
        gauges in ``snapshot_counters()`` (same accumulators)."""
        t = self.clock.now
        ss = self.sim_series
        ss.series("busiest_mb", clock=VIRTUAL).observe(
            t, float(np.maximum(self.stats.up, self.stats.down).max()) * MB)
        ss.series("bytes_values", clock=VIRTUAL, kind="counter").observe(
            t, float(self.stats.up.sum()))
        ss.series("bytes_wire", clock=VIRTUAL, kind="counter").observe(
            t, float(self.stats.up_wire.sum()))
        ss.series("n_retransmits", clock=VIRTUAL, kind="counter").observe(
            t, float(self.stats.n_retransmits))

    def _end_waits(self, ks, t_now: float) -> None:
        """Close ``ssp.wait`` spans for clients unblocked at ``t_now``."""
        tr = get_tracer()
        for k in ks:
            t0 = self._wait_since.pop(int(k), None)
            if t0 is not None and tr.enabled:
                tr.add_span("ssp.wait", t0, t_now, track=f"client/{int(k)}",
                            clock=VIRTUAL)

    # ------------------------------------------------------------------
    # checkpoint / resume
    # ------------------------------------------------------------------
    def _checkpoint_payload(self) -> dict:
        payload = super()._checkpoint_payload()
        sim = {
            "version": np.asarray(_SIM_CKPT_VERSION, np.int64),
            "mode": np.asarray(_MODE_CODES[self.mode], np.int64),
            "clock_now": np.asarray(self.clock.now, np.float64),
            "acc_trace": np.asarray(self.acc_trace,
                                    np.float64).reshape(-1, 2),
            "observed": np.asarray(
                [self.observed_spread, self.observed_mix_lag,
                 self.mixed_messages], np.int64),
            "uplink": self.uplink.state_dict(),
            "stats": self.stats.state_dict(),
        }
        if self._as is not None:
            sim["async"] = self._pack_async_state(self._as)
        payload["sim"] = sim
        return payload

    def _restore_payload(self, payload: dict) -> None:
        if "sim" not in payload:
            raise ValueError(
                "not a SimEngine checkpoint (no virtual timeline inside); "
                "resume it with RoundEngine, or re-save through SimEngine")
        super()._restore_payload(payload)
        sim = payload["sim"]
        ck_mode = int(sim["mode"])
        if ck_mode != _MODE_CODES[self.mode]:
            names = {v: k for k, v in _MODE_CODES.items()}
            raise ValueError(
                f"checkpoint was written by a mode={names[ck_mode]!r} "
                f"simulation; this engine is mode={self.mode!r}")
        self.clock = VirtualClock()
        self.clock.advance_to(float(sim["clock_now"]))
        trace = np.asarray(sim["acc_trace"], dtype=np.float64).reshape(-1, 2)
        self.acc_trace = [(float(t), float(a)) for t, a in trace]
        obs = np.asarray(sim["observed"], dtype=np.int64)
        self.observed_spread = int(obs[0])
        self.observed_mix_lag = int(obs[1])
        self.mixed_messages = int(obs[2])
        self.uplink.load_state(sim["uplink"])
        self.stats.load_state(sim["stats"])
        if self.mode == "async":
            if "async" not in sim:
                raise ValueError(
                    "async checkpoint is missing its event-loop state")
            self._as = self._unpack_async_state(sim["async"])

    def _pack_async_state(self, st: _AsyncState) -> dict:
        from repro.checkpoint import encode_packed
        n = len(self.clients)
        events = st.q.pending()
        # one push shares a single payload object across up to `degree`
        # ARRIVAL events and inbox slots — serialize each unique payload
        # once (pool index by object identity) instead of per occurrence
        pool: dict = {}
        pool_ids: dict[int, int] = {}

        def payload_ref(payload: dict) -> int:
            idx = pool_ids.get(id(payload))
            if idx is None:
                idx = len(pool_ids)
                pool_ids[id(payload)] = idx
                pool[f"{idx:06d}"] = _pack(encode_packed(payload))
            return idx

        ev = {
            "time": np.asarray([e.time for e in events], np.float64),
            "seq": np.asarray([e.seq for e in events], np.int64),
            "kind": np.asarray([_KIND_CODES[e.kind] for e in events],
                               np.int64),
            "k": np.asarray([e.data["k"] for e in events], np.int64),
            "src": np.asarray([e.data.get("src", -1) for e in events],
                              np.int64),
            "msg_version": np.asarray(
                [e.data["msg"].version if "msg" in e.data else -1
                 for e in events], np.int64),
            "msg_payload": np.asarray(
                [payload_ref(e.data["msg"].payload) if "msg" in e.data
                 else -1 for e in events], np.int64),
        }
        inbox = {}
        for k in range(n):
            slot = {}
            for j, msg in st.inbox[k].items():
                slot[f"{j:04d}"] = {
                    "v": np.asarray(msg.version, np.int64),
                    "pid": np.asarray(payload_ref(msg.payload), np.int64),
                }
            inbox[f"{k:04d}"] = slot
        flags = np.zeros((3, n), dtype=bool)
        for row, group in enumerate((st.waiting, st.done, st.dead)):
            for k in group:
                flags[row, k] = True
        return {
            "events": ev,
            "payloads": pool,
            "inbox": inbox,
            "t_local": st.t_local.astype(np.int64),
            "down_count": st.down_count.astype(np.int64),
            "down_streak": st.down_streak.astype(np.int64),
            "flags": flags,
            "emitted": np.asarray(st.emitted, np.int64),
            "last_finish": np.asarray(st.last_finish, np.float64),
            "prev_snap": {k: np.asarray(v, np.float64)
                          for k, v in (st.prev_snap or {}).items()},
        }

    def _unpack_async_state(self, d: dict) -> _AsyncState:
        from repro.checkpoint import decode_packed
        n = len(self.clients)
        ev = d["events"]
        times = np.asarray(ev["time"], np.float64)
        seqs = np.asarray(ev["seq"], np.int64)
        kinds = np.asarray(ev["kind"], np.int64)
        ks = np.asarray(ev["k"], np.int64)
        srcs = np.asarray(ev["src"], np.int64)
        versions = np.asarray(ev["msg_version"], np.int64)
        pids = np.asarray(ev["msg_payload"], np.int64)
        # decode the payload pool once; every referencing event/inbox slot
        # shares the decoded object, exactly like the live broadcast did
        pool = {int(key): decode_packed(_unpack(tree))
                for key, tree in d.get("payloads", {}).items()}
        events = []
        for i in range(len(times)):
            data = {"k": int(ks[i])}
            if int(kinds[i]) == _KIND_CODES[ARRIVAL]:
                data["src"] = int(srcs[i])
                data["msg"] = _Message(version=int(versions[i]),
                                       payload=pool[int(pids[i])])
            events.append(Event(float(times[i]), int(seqs[i]),
                                _CODE_KINDS[int(kinds[i])], data))
        q = EventQueue()
        q.restore(events)
        inbox: list[dict[int, _Message]] = [dict() for _ in range(n)]
        for k_key, slot in d.get("inbox", {}).items():
            for j_key, msg in slot.items():
                inbox[int(k_key)][int(j_key)] = _Message(
                    version=int(msg["v"]),
                    payload=pool[int(msg["pid"])])
        flags = np.asarray(d["flags"], dtype=bool)
        snap = {k: np.asarray(v, np.float64)
                for k, v in d.get("prev_snap", {}).items()}
        return _AsyncState(
            q=q, inbox=inbox,
            t_local=np.asarray(d["t_local"], np.int64).copy(),
            down_count=np.asarray(d["down_count"], np.int64).copy(),
            down_streak=np.asarray(d["down_streak"], np.int64).copy(),
            waiting=set(np.flatnonzero(flags[0]).tolist()),
            done=set(np.flatnonzero(flags[1]).tolist()),
            dead=set(np.flatnonzero(flags[2]).tolist()),
            emitted=int(d["emitted"]),
            last_finish=float(d["last_finish"]),
            prev_snap=snap or None)

    # ------------------------------------------------------------------
    # sync mode: RoundEngine semantics + a virtual timeline
    # ------------------------------------------------------------------
    def _pre_round(self, ctx: RoundCtx) -> None:
        # capture what the mix phase transmits: the pre-mix masks' nnz on the
        # current adjacency (measured, not assumed).  Strategies that don't
        # gossip over the adjacency (server-based / local-only) move no
        # P2P bytes, so their timeline is compute-only
        if not self.strategy.decentralized:
            self._pending_edges = None
            return
        strat, state = self.strategy, self.state
        nnz = [strat.message_nnz(state, k) for k in range(len(self.clients))]
        coords = strat.message_coords(state, 0)
        self._pending_edges = (
            edge_message_bytes(ctx.adjacency, nnz),
            edge_message_bytes(ctx.adjacency, nnz, coords, with_bitmap=True))

    def _finish_metrics(self, ctx: RoundCtx, metrics: RoundMetrics) -> RoundMetrics:
        edges = self._pending_edges
        self._pending_edges = None
        t0 = self.clock.now
        n = len(self.clients)
        compute_s = np.array([
            self.compute.local_time(k, metrics.flops_round)
            for k in range(n)])
        dur = float(compute_s.max()) if n else 0.0
        tr = get_tracer()
        if tr.enabled:
            for k in range(n):
                tr.add_span("compute", t0, t0 + float(compute_s[k]),
                            track=f"client/{k}", clock=VIRTUAL, round=ctx.t)
        if edges is not None:
            edges_v, edges_w = edges
            for src in range(n):
                dsts = np.flatnonzero(edges_v[:, src])
                if dsts.size == 0:
                    continue
                jobs = [(int(d), float(edges_v[d, src]),
                         float(edges_w[d, src])) for d in dsts]
                # the barrier waits for every model to arrive — the round
                # ends at the last arrival (retransmits included; sync
                # transport is reliable, so state matches RoundEngine)
                for _dst, _ok, end in self._transmit(
                        src, jobs, t0 + compute_s[src], ctx.t, reliable=True):
                    dur = max(dur, end - t0)
        self.clock.advance_to(t0 + dur)
        if metrics.acc_mean is not None:
            self.acc_trace.append((self.clock.now, metrics.acc_mean))
        self._sample_sim_series()
        up, down = self.stats.up * MB, self.stats.down * MB
        return SimRoundMetrics(
            **dataclasses.asdict(metrics),
            sim_time_s=self.clock.now, sim_round_s=dur,
            measured_total_mb=self.stats.total_mb,
            busiest_up_mb=float(up.max()), busiest_down_mb=float(down.max()),
            min_round=ctx.t + 1, max_round=ctx.t + 1,
            retrans_mb=self.stats.retrans_mb,
            lost_messages=self.stats.n_lost)

    # ------------------------------------------------------------------
    # async mode
    # ------------------------------------------------------------------
    def rounds(self):
        if self.mode == "sync":
            yield from super().rounds()
            return
        yield from self._async_rounds()

    def _mix_one(self, k: int, senders: dict[int, _Message], ctx: RoundCtx) -> None:
        """Mix client k against arrived payloads via ``Strategy.mix_one``.

        Decentralized strategies implement it as O(degree) packed folds
        (``repro.sparse.ops``); the ``StrategyBase`` fallback swaps the
        payloads in, runs the full ``mix`` on an adjacency whose only
        non-identity row is k's, and restores — correct for any strategy,
        but O(K) tree work per activation.
        """
        self.strategy.mix_one(
            self.state, k, {j: m.payload for j, m in senders.items()}, ctx)

    def _fresh_async_state(self) -> _AsyncState:
        n = len(self.clients)
        st = _AsyncState(
            q=EventQueue(),
            inbox=[dict() for _ in range(n)],
            t_local=np.zeros(n, dtype=np.int64),
            down_count=np.zeros(n, dtype=np.int64),
            down_streak=np.zeros(n, dtype=np.int64),
            waiting=set(), done=set(), dead=set(),
            emitted=0, last_finish=0.0,
            prev_snap=self.stats.snapshot())
        for k in range(n):
            st.q.push(0.0, WAKE, k=k)
        return st

    def _live_floor(self, st: _AsyncState) -> int:
        """Slowest *participating* client's completed rounds — dead clients
        (permanently unavailable) stop bounding progress.  With nobody left
        alive no further progress is possible, so the floor freezes at the
        rounds already emitted (the run ends partial rather than
        fabricating untrained rounds)."""
        n = len(self.clients)
        alive_t = [int(st.t_local[i]) for i in range(n) if i not in st.dead]
        return min(alive_t) if alive_t else st.emitted

    def _emit_ready_rounds(self, st: _AsyncState) -> Iterator[SimRoundMetrics]:
        """Yield one SimRoundMetrics per newly completed global round (a
        round is complete once the slowest client passes it).  All counters
        — ``emitted``, ``prev_snap``, ``_next_round``, accuracy history —
        advance *before* each yield, so a checkpoint taken from a round's
        callback captures exactly "rounds <= t complete" and a resumed run
        re-emits any rounds still pending at the cut."""
        cfg = self.cfg
        strat = self.strategy
        while st.emitted < self._live_floor(st):
            t = st.emitted
            ctx = self._make_ctx(t)
            comm_sn = self.stats.snapshot()
            prev = st.prev_snap or {k: np.zeros_like(v)
                                    for k, v in comm_sn.items()}
            win_up = comm_sn["up"] - prev["up"]
            win_down = comm_sn["down"] - prev["down"]
            win_up_w = comm_sn["up_wire"] - prev["up_wire"]
            win_down_w = comm_sn["down_wire"] - prev["down_wire"]
            st.prev_snap = comm_sn
            busiest = float(np.maximum(win_up, win_down).max()) * MB
            flops = strat.round_flops(self.state, ctx)
            self._comm["busiest_mb"].append(busiest)
            self._comm["avg_per_node_mb"].append(
                float(np.maximum(win_up, win_down).mean()) * MB)
            self._comm["total_mb"].append(float(win_up.sum()) * MB)
            self._comm["busiest_mb_with_bitmap"].append(
                float(np.maximum(win_up_w, win_down_w).max()) * MB)
            for key in self._flops:
                self._flops[key].append(float(getattr(flops, key)))
            acc_mean = acc_std = None
            if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
                accs = evaluate_clients(
                    self.task, strat.eval_params(self.state, ctx),
                    self.clients)
                acc_mean = float(np.mean(accs))
                acc_std = float(np.std(accs))
                self._acc_history.append(acc_mean)
                self._acc_stds.append(acc_std)
                self._eval_rounds.append(t)
                self.acc_trace.append((self.clock.now, acc_mean))
            up, down = self.stats.up * MB, self.stats.down * MB
            st.emitted += 1
            self._next_round = st.emitted
            self._sample_sim_series()
            metrics = SimRoundMetrics(
                round=t, lr=ctx.lr, prune_rate=ctx.prune_rate,
                comm_busiest_mb=busiest,
                comm_rows={"busiest_MB": round(busiest, 3)},
                flops_round=flops.per_round_flops,
                cum_flops=float(np.sum(self._flops["per_round_flops"])),
                acc_mean=acc_mean, acc_std=acc_std, wall_s=0.0,
                sim_time_s=self.clock.now, sim_round_s=0.0,
                measured_total_mb=self.stats.total_mb,
                busiest_up_mb=float(up.max()),
                busiest_down_mb=float(down.max()),
                min_round=int(st.t_local.min()),
                max_round=int(st.t_local.max()),
                retrans_mb=self.stats.retrans_mb,
                lost_messages=self.stats.n_lost)
            self._sample_series(metrics)
            yield metrics

    def _async_rounds(self):
        cfg = self.cfg
        strat = self.strategy
        n = len(self.clients)
        if not strat.decentralized:
            # a non-gossip mix would read live peer state instead of what
            # arrived over the simulated links — every reported number would
            # be fiction, so refuse
            raise ValueError(
                f"async simulation requires a decentralized strategy whose "
                f"mix gossips over ctx.adjacency; '{strat.name}' is not "
                f"(strategy.decentralized is False)")
        if not isinstance(self.state.get("params"), list):
            raise ValueError(
                f"async simulation requires per-client state['params'] lists "
                f"(strategy '{strat.name}' has none)")
        if self._as is None:
            if self._next_round != 0:
                raise ValueError(
                    "this engine was restored from a non-async checkpoint "
                    "or advanced outside the event loop; async resume needs "
                    "a SimEngine mode='async' checkpoint")
            self._as = self._fresh_async_state()
        st = self._as
        self._stop = False

        # extend-on-resume: a *finished* run restored with a larger
        # cfg.rounds re-arms its retired clients instead of silently ending
        # — each gets a fresh WAKE at the restored virtual clock (dead
        # clients stay dead; mid-run resume is untouched because a client
        # only retires once t_local reaches the old cfg.rounds)
        revived = sorted(k for k in st.done
                         if k not in st.dead
                         and int(st.t_local[k]) < cfg.rounds)
        for k in revived:
            st.done.discard(k)
            st.q.push(self.clock.now, WAKE, k=k)

        def flops_at(t: int) -> float:
            ctx = self._make_ctx(int(t))
            return strat.round_flops(self.state, ctx).per_round_flops

        # rounds already completed by the cut but not yet emitted at the
        # checkpoint (a DONE may complete several global rounds at once):
        # flush them first so the resumed stream is gapless
        for m in self._emit_ready_rounds(st):
            for cb in self.callbacks:
                cb.on_round_end(self, m)
            yield m
            if self._stop:
                break

        while st.q and len(st.done) < n and not self._stop:
            ev = st.q.pop()
            self.clock.advance_to(ev.time)
            if ev.kind == ARRIVAL:
                k, src = ev.data["k"], ev.data["src"]
                msg = ev.data["msg"]
                cur = st.inbox[k].get(src)
                if cur is None or msg.version >= cur.version:
                    st.inbox[k][src] = msg
                if k in st.waiting:
                    st.waiting.discard(k)
                    self._end_waits([k], ev.time)
                    st.q.push(ev.time, WAKE, k=k)
                continue

            if ev.kind == DONE:
                # a client's round completes at its compute-finish time: only
                # now does its local clock advance, unblocking SSP waiters
                # and (possibly) completing a global round
                k = ev.data["k"]
                st.t_local[k] += 1
                st.last_finish = max(st.last_finish, ev.time)
                if st.t_local[k] >= cfg.rounds:
                    st.done.add(k)
                else:
                    st.q.push(ev.time, WAKE, k=k)
                if self._live_floor(st) > st.emitted:
                    waiters = sorted(st.waiting)
                    for w in waiters:
                        st.q.push(ev.time, WAKE, k=w)
                    st.waiting.clear()
                    self._end_waits(waiters, ev.time)
                    for m in self._emit_ready_rounds(st):
                        for cb in self.callbacks:
                            cb.on_round_end(self, m)
                        yield m
                        if self._stop:
                            break
                continue

            k = ev.data["k"]
            if k in st.done:
                continue
            t_k = int(st.t_local[k])
            # bounded staleness (SSP): never run more than `staleness` rounds
            # ahead of the slowest participating client
            spread = t_k - self._live_floor(st)
            if self.staleness >= 0 and spread > self.staleness:
                st.waiting.add(k)
                self._wait_since.setdefault(k, ev.time)
                continue
            # availability: a down client retries one mean-round later
            # against its next slot; after max_down_retries consecutive down
            # slots it is declared dead so it cannot stall the whole network
            if not self.availability.up(k, t_k + int(st.down_count[k])):
                st.down_count[k] += 1
                st.down_streak[k] += 1
                if st.down_streak[k] > self.max_down_retries:
                    st.dead.add(k)
                    st.done.add(k)
                    waiters = sorted(st.waiting)
                    for w in waiters:
                        st.q.push(ev.time, WAKE, k=w)
                    st.waiting.clear()
                    self._end_waits(waiters, ev.time)
                    for m in self._emit_ready_rounds(st):
                        for cb in self.callbacks:
                            cb.on_round_end(self, m)
                        yield m
                        if self._stop:
                            break
                    continue
                retry = self.compute.mean_round_s(flops_at(t_k))
                st.q.push(ev.time + max(retry, 1e-9), WAKE, k=k)
                continue
            st.down_streak[k] = 0
            self.observed_spread = max(self.observed_spread, max(0, spread))

            # 1. mix what has arrived (respecting the staleness bound)
            senders = {
                j: m for j, m in st.inbox[k].items()
                if self.staleness < 0 or t_k - m.version <= self.staleness}
            for m in senders.values():
                self.observed_mix_lag = max(self.observed_mix_lag,
                                            max(0, t_k - m.version))
            self.mixed_messages += len(senders)
            a = np.eye(n)
            if senders:
                a[k, list(senders)] = 1.0
            ctx = RoundCtx(
                t=t_k, cfg=cfg, task=self.task, clients=self.clients,
                lr=cfg.lr_at(t_k),
                prune_rate=cosine_prune_rate(cfg.alpha0, t_k, cfg.rounds),
                adjacency=a)
            self._mix_one(k, senders, ctx)

            # 2. local phase + mask evolution (same hooks, same derived rng)
            self.run_local_phase(ctx, [k])
            strat.evolve(self.state, k, ctx)

            # 3. compute time, then push to sampled receivers.  The payload
            # is the packed message itself; its sizes are codec-measured
            # from what actually ships, not recomputed from nnz.  Sends
            # queue on the sender's shared uplink (unless uplink="parallel")
            # and may be dropped + retransmitted by the loss model; a
            # message that exhausts its budget never ARRIVEs
            flops = strat.round_flops(self.state, ctx).per_round_flops
            finish = ev.time + self.compute.local_time(k, flops)
            tr = get_tracer()
            if tr.enabled:
                tr.add_span("compute", ev.time, finish, track=f"client/{k}",
                            clock=VIRTUAL, round=t_k)
            payload = strat.snapshot_message(self.state, k)
            bytes_v, bytes_w = measure_payload(payload)
            msg = _Message(version=t_k + 1, payload=payload)
            receivers = directed_out_neighbors(n, k, t_k, cfg.degree, cfg.seed)
            jobs = [(int(j), bytes_v, float(bytes_w)) for j in receivers]
            for j, delivered, arrive in self._transmit(
                    k, jobs, finish, t_k + 1, reliable=False):
                if delivered:
                    st.q.push(arrive, ARRIVAL, k=j, src=k, msg=msg)

            # 4. the round completes (and the local clock advances) at the
            # compute-finish time, handled by the DONE event above
            st.q.push(finish, DONE, k=k)
        # the run ends when the last client finishes its compute, even if
        # some already-sent messages are still in flight
        self.clock.advance_to(max(st.last_finish, self.clock.now))
        self._end_waits(list(self._wait_since), self.clock.now)
        for m in self._emit_ready_rounds(st):
            for cb in self.callbacks:
                cb.on_round_end(self, m)
            yield m
        for cb in self.callbacks:
            cb.on_run_end(self)
