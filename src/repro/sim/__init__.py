"""repro.sim — event-driven asynchronous P2P network simulator.

Turns the round-loop reproduction into a system that can answer deployment
questions: how long does decentralized sparse training take on *real* links,
what does the busiest node actually upload/download, and when does
asynchronous gossip beat the synchronous barrier?

Modules
-------
``events``        event queue, virtual clock, per-client compute speeds
``links``         per-edge bandwidth/latency models (time-varying via
                  ``BandwidthTrace``), shared-uplink scheduling
                  (``UplinkScheduler``: parallel/fifo/fair), Bernoulli
                  message loss + retransmit (``LossModel``), and measured
                  bytes-on-wire (retransmitted bytes included)
``availability``  Bernoulli / trace-driven client up-down schedules (shared
                  with the fig-6 dropping experiment)
``async_engine``  ``SimEngine`` — drives the existing Strategy hooks in a
                  synchronous (bit-identical to ``RoundEngine``) or
                  staleness-bounded asynchronous regime; checkpoint/resume
                  of the *complete* simulation (clock, event queue,
                  in-flight payloads, link stats) is bit-identical to an
                  uninterrupted run in both modes
``report``        wall-clock-to-target, busiest-node timelines, per-link
                  utilization, retransmit overhead, JSON-lines streaming

See the ``async_engine`` module docstring for a worked example, and
``examples/async_gossip.py`` for a runnable one.
"""
from repro.sim.availability import (  # noqa: F401
    AlwaysUp,
    Availability,
    BernoulliAvailability,
    TraceAvailability,
    dropping_trace,
)
from repro.sim.events import (  # noqa: F401
    ComputeModel,
    Event,
    EventQueue,
    VirtualClock,
    hetero_speeds,
)
from repro.sim.links import (  # noqa: F401
    BandwidthTrace,
    LinkModel,
    LinkStats,
    LossModel,
    UplinkScheduler,
    measure_payload,
)
from repro.sim.async_engine import SimEngine, SimRoundMetrics  # noqa: F401
from repro.sim.report import MetricsStream, SimReport, build_report  # noqa: F401
