"""ScaleEngine vs the loop engine: rounds/s and per-round bytes vs K.

For each client count K the same dispfl workload runs through

* ``RoundEngine(local_exec="loop")`` — the per-client reference semantics,
* ``ScaleEngine`` — the whole round (gossip mix, local phase, mask
  evolution) as one jitted stacked program,

with one warm-up round excluded (jit compile) and the steady-state
seconds/round compared.  The per-round communication columns come from the
engine's own accounting (*analytic*, from the round adjacency and mask
nnz) and from the codec frame of a real packed message
(``ScaleEngine.snapshot_messages`` — *measured*), so the bytes are exact
deterministic functions of the seed and gate tightly.

Gate contract (benchmarks/baselines/scale_engine.json): the K=64 row's
``speedup_vs_loop`` must stay >= 4x (the repro.scale acceptance floor);
byte columns are exact-function-of-seed tight.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import timer


def _setup(k: int, fast: bool):
    from repro.data import build_federated_image_task
    from repro.fl import FLConfig, make_cnn_task

    clients, _ = build_federated_image_task(
        0, n_clients=k, partition="pathological", classes_per_client=2,
        n_train_per_class=64 if fast else 160,
        n_test_per_client=20, hw=16, noise=0.8)
    # one shared effective batch size (the stacked-program regime; the
    # loop engine runs the identical equalized shards for a fair A/B)
    n_min = min(c.n_train for c in clients)
    clients = [dataclasses.replace(c, train_x=c.train_x[:n_min],
                                   train_y=c.train_y[:n_min])
               for c in clients]
    task = make_cnn_task("smallcnn", 10, 16, width=8 if fast else 16)
    cfg = FLConfig(n_clients=k, rounds=3 if fast else 5,
                   local_epochs=2 if fast else 5, batch_size=32,
                   degree=min(10, k - 1), eval_every=10**6)
    return task, clients, cfg


def run(fast: bool = True) -> list[dict]:
    import jax

    from repro.fl import RoundEngine, make_strategy
    from repro.scale import ScaleEngine
    from repro.sparse import encoded_nbytes

    # measurement isolation: earlier modules (engine_vmap runs the same
    # loop local phase) leave warm jit caches that flatter whichever
    # engine reuses them — the A/B ratio must compile from cold
    jax.clear_caches()
    rows = []
    for k in ((16, 64) if fast else (16, 64, 128)):
        task, clients, cfg = _setup(k, fast)
        walls = {}
        accs = {}
        engines = {
            "loop": lambda: RoundEngine(make_strategy("dispfl"), task,
                                        clients, cfg, local_exec="loop"),
            "scale": lambda: ScaleEngine(make_strategy("dispfl"), task,
                                         clients, cfg),
        }
        byte_row = {}
        for label, build in engines.items():
            eng = build()
            it = eng.rounds()
            next(it)                    # warm-up round (jit compiles)
            with timer() as box:
                steady = sum(1 for _ in it)
            walls[label] = box["s"] / max(steady, 1)
            accs[label] = eng.result().final_acc
            if label == "scale":
                # measured: the codec frame each client would put on the
                # wire after the run; analytic: the engine's per-round
                # busiest-node accounting (mean over rounds)
                frames = [encoded_nbytes(m["packed"])
                          for m in eng.snapshot_messages()]
                res = eng.result()
                byte_row = {
                    "wire_bytes_per_msg": int(frames[0]),
                    "wire_bytes_max_msg": int(max(frames)),
                    "busiest_MB_per_round": round(res.comm_busiest_mb, 4),
                }
        rows.append({
            "name": f"scale_engine/dispfl_K{k}",
            "us_per_call": round(walls["scale"] * 1e6, 1),
            "loop_s_per_round": round(walls["loop"], 3),
            "scale_s_per_round": round(walls["scale"], 3),
            "speedup_vs_loop": round(walls["loop"] / walls["scale"], 2),
            "acc_loop": round(accs["loop"], 4),
            "acc_scale": round(accs["scale"], 4),
            "accs_agree": bool(abs(accs["loop"] - accs["scale"]) < 0.05),
            **byte_row,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(fast=True))
