"""Paper Fig 6 (App. B.6): robustness to random client dropping."""
from __future__ import annotations

import dataclasses

from benchmarks.common import fl_setup, timer


def run(fast: bool = True) -> list[dict]:
    from repro.fl import run_strategy

    rows = []
    task, clients, base = fl_setup(fast, "dirichlet")
    probs = (0.0, 0.5) if fast else (0.0, 0.2, 0.5, 0.8)
    accs = {}
    for p in probs:
        cfg = dataclasses.replace(base, topology="fc", drop_prob=p)
        with timer() as t:
            res = run_strategy("dispfl", task, clients, cfg)
        accs[p] = res.final_acc
        rows.append({"name": f"fig6/drop_{p}",
                     "us_per_call": round(t["s"] * 1e6 / max(cfg.rounds, 1)),
                     "acc": round(res.final_acc, 4)})
    # local baseline for reference (dropping can't hurt below local-only)
    res_local = run_strategy("local", task, clients, base)
    rows.append({"name": "fig6/local_baseline",
                 "acc": round(res_local.final_acc, 4)})
    rows.append({"name": "fig6/check/graceful_degradation",
                 "ok": accs[max(probs)] > 0.75 * accs[0.0]})
    return rows
