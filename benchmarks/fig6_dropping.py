"""Paper Fig 6 (App. B.6): robustness to random client dropping.

The dropping experiment now routes through ``repro.sim.availability`` — the
same Bernoulli failure model the event simulator uses (one draw per (seed,
round), shared via ``core.topology.bernoulli_alive``) — and runs inside the
simulator's synchronous mode, so every row also reports the measured
busiest-node traffic under dropping (dropped clients transfer nothing).

Note the seed code passed ``drop_prob`` with the fully-connected topology,
which silently ignored it; availability-driven dropping applies to every
topology kind.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import fl_setup, timer


def run(fast: bool = True) -> list[dict]:
    from repro.fl import make_strategy, run_strategy
    from repro.sim import BernoulliAvailability, SimEngine

    rows = []
    task, clients, base = fl_setup(fast, "dirichlet")
    probs = (0.0, 0.5) if fast else (0.0, 0.2, 0.5, 0.8)
    accs = {}
    for p in probs:
        cfg = dataclasses.replace(base, topology="fc")
        avail = BernoulliAvailability(cfg.n_clients, p, seed=cfg.seed)
        trace = [avail.alive(t).mean() for t in range(cfg.rounds)]
        eng = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                        mode="sync", availability=avail, round_s=1.0)
        with timer() as t:
            res = eng.run()
        accs[p] = res.final_acc
        rows.append({"name": f"fig6/drop_{p}",
                     "us_per_call": round(t["s"] * 1e6 / max(cfg.rounds, 1)),
                     "acc": round(res.final_acc, 4),
                     "alive_frac": round(sum(trace) / len(trace), 3),
                     "busiest_MB": round(eng.stats.busiest_node()[1], 2)})
    # local baseline for reference (dropping can't hurt below local-only)
    res_local = run_strategy("local", task, clients, base)
    rows.append({"name": "fig6/local_baseline",
                 "acc": round(res_local.final_acc, 4)})
    rows.append({"name": "fig6/check/graceful_degradation",
                 "ok": accs[max(probs)] > 0.75 * accs[0.0]})
    return rows
