"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) vs jnp oracle.

On CPU the interpreter is slower than XLA-fused jnp — the point here is the
derived quantities: bytes touched, block-sparse skip fraction, and the
FLOPs the MXU would skip on real hardware.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.masked_matmul import block_mask_from_mask


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    # gossip_avg
    j, n = 10, (1 << 18 if fast else 1 << 22)
    m = (jax.random.uniform(ks[0], (j, n)) < 0.5).astype(jnp.float32)
    w = jax.random.normal(ks[1], (j, n)) * m
    own = m[0]
    us_k = _time(lambda: ops.gossip_avg(list(w), list(m), own))
    us_r = _time(lambda: ref.gossip_avg_ref(w, m, own))
    rows.append({"name": "kernel/gossip_avg", "us_per_call": round(us_k),
                 "ref_us": round(us_r), "bytes_touched": int(w.nbytes * 2 + own.nbytes),
                 "neighbors": j})

    # masked matmul at three densities
    mdim, kdim, ndim = (256, 512, 512) if fast else (512, 2048, 2048)
    x = jax.random.normal(ks[2], (mdim, kdim), jnp.float32)
    wgt = jax.random.normal(ks[3], (kdim, ndim), jnp.float32)
    for density in (0.1, 0.5, 1.0):
        mask = (jax.random.uniform(ks[0], (kdim, ndim)) < density).astype(jnp.float32)
        bm = block_mask_from_mask(mask, 128, 128)
        occ = float(jnp.mean(bm.astype(jnp.float32)))
        us = _time(lambda mask=mask: ops.masked_matmul(x, wgt, mask))
        rows.append({
            "name": f"kernel/masked_matmul/density_{density}",
            "us_per_call": round(us),
            "block_occupancy": round(occ, 3),
            "mxu_flops_skipped_frac": round(1.0 - occ, 3),
            "dense_flops": 2 * mdim * kdim * ndim,
        })

    # prune_regrow
    n = 1 << 16
    mk = (jax.random.uniform(ks[0], (n,)) < 0.5).astype(jnp.float32)
    wv = jax.random.normal(ks[1], (n,)) * mk
    gv = jax.random.normal(ks[2], (n,))
    us = _time(lambda: ops.prune_regrow(wv, gv, mk, 0.3))
    rows.append({"name": "kernel/prune_regrow", "us_per_call": round(us),
                 "n": n})
    return rows
