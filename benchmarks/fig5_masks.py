"""Paper Fig 5: learned masks encode task similarity.

Clients are split into label-distribution groups; after DisPFL training, the
aligned Hamming distance between learned masks should be smaller within a
group than across groups, and anti-correlate with label cos-similarity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import timer


def _mask_flat(mask) -> np.ndarray:
    import jax
    return np.concatenate([np.asarray(x).reshape(-1)
                           for x in jax.tree.leaves(mask)])


def run(fast: bool = True) -> list[dict]:
    import jax

    from repro.core.evolve import cosine_prune_rate, evolve_masks, layer_nnz_budgets
    from repro.core.gossip import gossip_average_one
    from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
    from repro.core.topology import make_adjacency
    from repro.data import build_federated_image_task
    from repro.fl import FLConfig, make_cnn_task
    from repro.fl.base import local_sgd
    from repro.optim import SGDConfig

    n_groups, per_group = 4, (2 if fast else 5)
    k = n_groups * per_group
    # group g clients share a seed so their Dir(0.3) label dists coincide
    base_clients, _ = build_federated_image_task(
        0, n_clients=n_groups, partition="dirichlet", alpha=0.3,
        n_train_per_class=80, hw=16)
    rng = np.random.default_rng(0)
    clients = []
    groups = []
    for g in range(n_groups):
        for _ in range(per_group):
            clients.append(base_clients[g])
            groups.append(g)

    task = make_cnn_task("smallcnn", 10, 16, width=8)
    cfg = FLConfig(n_clients=k, rounds=3 if fast else 10, local_epochs=2,
                   batch_size=32, degree=3)
    opt = SGDConfig(weight_decay=cfg.weight_decay)

    keys = jax.random.split(jax.random.PRNGKey(0), 2 * k)
    params = [task.init_fn(keys[i]) for i in range(k)]
    masks = [init_mask(keys[k + i], params[i], cfg.density) for i in range(k)]
    densities = erk_densities_for_params(params[0], cfg.density)
    budgets = layer_nnz_budgets(params[0], densities)
    params = [apply_mask(p, m) for p, m in zip(params, masks)]

    with timer() as t:
        for r in range(cfg.rounds):
            a = make_adjacency("random", k, r, cfg.degree, cfg.seed)
            alpha = cosine_prune_rate(cfg.alpha0, r, cfg.rounds)
            new_p, new_m = [], []
            for i in range(k):
                nbrs = [j for j in range(k) if a[i, j] > 0 and j != i]
                w = gossip_average_one(params[i], masks[i],
                                       [params[j] for j in nbrs],
                                       [masks[j] for j in nbrs])
                c = clients[i]
                w = local_sgd(task, w, c.train_x, c.train_y, cfg.local_epochs,
                              cfg.batch_size, cfg.lr_at(r), opt, rng,
                              mask=masks[i])
                xb, yb = c.sample_batch(rng, cfg.batch_size)
                _, g_ = task.value_and_grad(w, xb, yb)
                m2, w = evolve_masks(w, masks[i], g_, alpha, budgets)
                new_p.append(w)
                new_m.append(m2)
            params, masks = new_p, new_m

    flats = [_mask_flat(m) for m in masks]
    dists = np.zeros((k, k))
    cos = np.zeros((k, k))
    for i in range(k):
        for j in range(k):
            dists[i, j] = np.mean(flats[i] != flats[j])
            a_, b_ = clients[i].label_dist, clients[j].label_dist
            cos[i, j] = float(a_ @ b_ / (np.linalg.norm(a_) * np.linalg.norm(b_) + 1e-12))

    same = [dists[i, j] for i in range(k) for j in range(k)
            if i != j and groups[i] == groups[j]]
    diff = [dists[i, j] for i in range(k) for j in range(k)
            if groups[i] != groups[j]]
    iu = np.triu_indices(k, 1)
    corr = float(np.corrcoef(dists[iu], cos[iu])[0, 1])
    return [{
        "name": "fig5/mask_similarity",
        "us_per_call": round(t["s"] * 1e6),
        "hamming_same_group": round(float(np.mean(same)), 4),
        "hamming_diff_group": round(float(np.mean(diff)), 4),
        "corr_hamming_vs_cos_sim": round(corr, 4),
        "ok_same_lt_diff": float(np.mean(same)) < float(np.mean(diff)),
        "ok_anticorrelated": corr < 0,
    }]
