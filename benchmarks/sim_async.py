"""Sync vs async decentralized training on simulated networks.

Three deployment scenarios — uniform links, 10x bandwidth skew, 30% client
dropout — each run through ``repro.sim.SimEngine`` twice: the synchronous
barrier protocol (bit-identical state evolution to ``RoundEngine``) and
staleness-bounded asynchronous gossip.  Both runs use heterogeneous compute
speeds (0.2x..1.0x), which is where the barrier hurts: the round clock is
the slowest client.  Reported per scenario: virtual wall-clock to the common
target accuracy (the best accuracy both protocols reach), busiest-node MB
accumulated by that time, and end-of-run totals.
"""
from __future__ import annotations

from benchmarks.common import fl_setup, timer


def _scenarios(k: int, seed: int):
    from repro.sim import AlwaysUp, BernoulliAvailability, LinkModel

    return [
        ("uniform", LinkModel.uniform(k, mbps=100), AlwaysUp(k)),
        ("skew10x", LinkModel.skewed(k, mbps=100, skew=10, seed=seed),
         AlwaysUp(k)),
        ("drop30", LinkModel.uniform(k, mbps=100),
         BernoulliAvailability(k, 0.3, seed=seed)),
    ]


def run(fast: bool = True) -> list[dict]:
    from repro.fl import make_strategy
    from repro.sim import SimEngine, hetero_speeds
    from repro.sim.report import time_to_target

    task, clients, cfg = fl_setup(fast, "dirichlet")
    k = cfg.n_clients
    speeds = hetero_speeds(k, seed=cfg.seed)
    rows = []
    for name, links, avail in _scenarios(k, cfg.seed):
        runs = {}
        for mode, staleness in (("sync", 0), ("async", 2)):
            eng = SimEngine(
                make_strategy("dispfl"), task, clients, cfg,
                mode=mode, staleness=staleness, links=links,
                availability=avail, round_s=1.0, compute_speeds=speeds)
            with timer() as t:
                eng.run()
            runs[mode] = (eng, t["s"])
        sync_eng, async_eng = runs["sync"][0], runs["async"][0]
        # common target: the best accuracy BOTH protocols reach (epsilon
        # below the min-of-maxes so float rounding can't overshoot it)
        target = min(max(a for _, a in e.acc_trace)
                     for e in (sync_eng, async_eng)) - 1e-9
        for mode in ("sync", "async"):
            eng, wall = runs[mode]
            hit = time_to_target(eng.acc_trace, target)
            rows.append({
                "name": f"sim_async/{name}/{mode}",
                "us_per_call": round(wall * 1e6 / max(cfg.rounds, 1)),
                "target_acc": round(target, 4),
                "sim_s_to_target": round(hit, 2),
                "busiest_MB_at_target": round(
                    eng.stats.busiest_mb_until(hit), 3) if hit >= 0 else -1,
                "sim_wall_s": round(eng.sim_time, 2),
                "busiest_MB_total": round(eng.stats.busiest_node()[1], 3),
                "total_MB": round(eng.stats.total_mb, 3),
            })
        t_sync = time_to_target(sync_eng.acc_trace, target)
        t_async = time_to_target(async_eng.acc_trace, target)
        rows.append({
            "name": f"sim_async/{name}/check",
            "target_reached_both": t_sync >= 0 and t_async >= 0,
            "async_speedup_x": round(t_sync / t_async, 2)
            if t_sync >= 0 and t_async > 0 else -1,
        })
    return rows
