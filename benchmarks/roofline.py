"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and reports the three roofline terms + bottleneck per (arch x shape x mesh).

Run ``PYTHONPATH=src python -m repro.launch.dryrun --both-meshes`` first to
(re)generate artifacts; this benchmark only aggregates (compiling 60+
combinations inside benchmarks.run would take an hour on CPU).
"""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run(fast: bool = True) -> list[dict]:
    del fast
    rows = []
    files = sorted(glob.glob(os.path.join(ART_DIR, "*.json")))
    if not files:
        return [{"name": "roofline/missing",
                 "note": "run `python -m repro.launch.dryrun --both-meshes` first"}]
    n_ok = n_skip = n_fail = 0
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        tag = rec.get("tag", os.path.basename(path)[:-5])
        if rec.get("status") == "skipped":
            n_skip += 1
            rows.append({"name": f"roofline/{tag}", "status": "skipped",
                         "reason": rec.get("reason", "")[:60]})
            continue
        if rec.get("status") != "ok":
            n_fail += 1
            rows.append({"name": f"roofline/{tag}", "status": "FAILED"})
            continue
        n_ok += 1
        r = rec["roofline"]
        rows.append({
            "name": f"roofline/{tag}",
            "us_per_call": round(rec.get("compile_s", 0) * 1e6),
            "clients": rec.get("n_clients"),
            "compute_ms": r["compute_ms"],
            "memory_ms": r["memory_ms"],
            "collective_ms": r["collective_ms"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_ratio"],
            "mfu_bound": r["mfu_bound"],
        })
    rows.append({"name": "roofline/summary", "ok": n_ok, "skipped": n_skip,
                 "failed": n_fail})
    return rows
