"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and reports the three roofline terms + bottleneck per (arch x shape x mesh).

Run ``PYTHONPATH=src python -m repro.launch.dryrun --both-meshes`` first to
(re)generate artifacts; this benchmark only aggregates (compiling 60+
combinations inside benchmarks.run would take an hour on CPU).

A second section pairs the analytic model with *measured* span timings from
``repro.obs``: a tiny instrumented DisPFL round run feeds
``launch.roofline.measured_phase_rows`` so the report shows predicted ms
(analytic FLOPs / bytes priced on the reference chip) next to observed ms
per engine phase.  These rows are informational — host wall-clock on a CPU
dev box is nowhere near the reference roof, and ``check_regression`` does
not gate them.
"""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _measured_rows(fast: bool) -> list[dict]:
    """Predicted-vs-observed phase rows from one instrumented engine run."""
    from benchmarks.engine_vmap import _setup
    from repro.fl import RoundEngine, make_strategy
    from repro.launch.roofline import measured_phase_rows
    from repro.obs import get_tracer, phase_summary

    task, clients, cfg = _setup(8, True)
    eng = RoundEngine(make_strategy("dispfl"), task, clients, cfg)
    tr = get_tracer()
    owned = not tr.enabled      # reuse a run-level --trace capture if armed
    if owned:
        tr.enable(mode="full")
    mark = max((s.seq for s in tr.spans()), default=-1)
    try:
        res = eng.run()
        engine_spans = [s for s in tr.spans(track="engine") if s.seq > mark]
        summary = phase_summary(engine_spans)
    finally:
        if owned:
            tr.disable()
            tr.clear()
    # analytic cost of ONE call of each phase: local = per-client round
    # FLOPs x K (every client trains each round), mix = the round's total
    # on-wire bytes (decimal MB, matching the paper's comm tables)
    analytic = {
        "round.local": (res.flops_per_round * cfg.n_clients, "flops"),
        "round.mix": (res.comm_rows["total_MB"] * 1e6, "bytes"),
    }
    rows = []
    for r in measured_phase_rows(summary, analytic):
        rows.append({"name": f"roofline/measured_{r.pop('phase')}", **r})
    return rows


def run(fast: bool = True) -> list[dict]:
    rows = []
    files = sorted(glob.glob(os.path.join(ART_DIR, "*.json")))
    if not files:
        return _measured_rows(fast) + [
            {"name": "roofline/missing",
             "note": "run `python -m repro.launch.dryrun --both-meshes` first"}]
    n_ok = n_skip = n_fail = 0
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        tag = rec.get("tag", os.path.basename(path)[:-5])
        if rec.get("status") == "skipped":
            n_skip += 1
            rows.append({"name": f"roofline/{tag}", "status": "skipped",
                         "reason": rec.get("reason", "")[:60]})
            continue
        if rec.get("status") != "ok":
            n_fail += 1
            rows.append({"name": f"roofline/{tag}", "status": "FAILED"})
            continue
        n_ok += 1
        r = rec["roofline"]
        rows.append({
            "name": f"roofline/{tag}",
            "us_per_call": round(rec.get("compile_s", 0) * 1e6),
            "clients": rec.get("n_clients"),
            "compute_ms": r["compute_ms"],
            "memory_ms": r["memory_ms"],
            "collective_ms": r["collective_ms"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_ratio"],
            "mfu_bound": r["mfu_bound"],
        })
    rows.append({"name": "roofline/summary", "ok": n_ok, "skipped": n_skip,
                 "failed": n_fail})
    rows.extend(_measured_rows(fast))
    return rows
