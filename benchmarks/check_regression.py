"""Benchmark regression gate: fresh rows vs checked-in baselines.

    PYTHONPATH=src python -m benchmarks.check_regression [--update]
        [--only sparse_codec,...] [--out BENCH_latest.json]

Runs the gated benchmark modules (codec throughput, engine vmap speedup,
simulator fault physics), writes every fresh row to ``--out`` (the
``BENCH_*.json`` artifact CI uploads — the start of the perf trajectory),
and compares row-by-row against ``benchmarks/baselines/<module>.json``
under per-metric tolerance rules:

* *virtual* quantities (sim seconds, bytes, accuracies) are deterministic
  functions of the seed — tight relative tolerances catch real behaviour
  changes;
* *wall-clock* quantities (``*_us``, ``*_s_per_round``) vary by machine —
  only order-of-magnitude blowups fail;
* *floor* metrics (the vmap speedup) must stay above a fraction of
  baseline and an absolute floor;
* boolean sanity checks must match exactly.

``--update`` regenerates the baselines from the fresh run (commit the
diff deliberately — it is the new performance contract).  Exit status is
non-zero on any violation, which is what ``make bench-gate`` (run by the
full CI job) gates on.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")

#: modules under the gate (a subset of benchmarks.run.MODULES: the ones
#: whose rows are stable enough to be a contract)
MODULES = ["sparse_codec", "engine_vmap", "scale_engine", "sim_faults",
           "serve_bench"]

# metric -> rule.  kinds:
#   close      |new - base| <= atol + rtol * |base|
#   timing     new <= max_ratio * base (+1us grace) — machine-dependent
#   floor      new >= max(abs_floor, frac * base)
#   ceiling    new <= abs_ceiling (baseline-independent hard cap)
#   exact      new == base
_RULES: dict[str, dict] = {
    # codec: exact functions of (seed, density) — tight
    "wire_bytes": {"kind": "close", "rtol": 0.01, "atol": 0},
    "dense_wire_bytes": {"kind": "close", "rtol": 0.01, "atol": 0},
    "bytes_ratio": {"kind": "close", "rtol": 0.02, "atol": 0.01},
    "coords": {"kind": "exact"},
    "ratio_tracks_density": {"kind": "exact"},
    # engine: the vmap fast path must keep beating the loop
    "speedup": {"kind": "floor", "abs_floor": 1.1, "frac": 0.4},
    "acc_loop": {"kind": "close", "rtol": 0.2, "atol": 0.05},
    "acc_vmap": {"kind": "close", "rtol": 0.2, "atol": 0.05},
    # scale: the one-program stacked round must keep >=4x over the loop
    # engine (the repro.scale acceptance floor), bytes are exact functions
    # of (seed, density) and accuracies must agree across engines
    "speedup_vs_loop": {"kind": "floor", "abs_floor": 4.0, "frac": 0.4},
    "acc_scale": {"kind": "close", "rtol": 0.2, "atol": 0.05},
    "accs_agree": {"kind": "exact"},
    "wire_bytes_per_msg": {"kind": "close", "rtol": 0.01, "atol": 0},
    "wire_bytes_max_msg": {"kind": "close", "rtol": 0.01, "atol": 0},
    "busiest_MB_per_round": {"kind": "close", "rtol": 0.05, "atol": 0.01},
    # simulator: virtual, deterministic given the seed
    "sim_wall_s": {"kind": "close", "rtol": 0.25, "atol": 0.5},
    "sim_s_to_target": {"kind": "close", "rtol": 0.35, "atol": 1.0},
    "busiest_MB_total": {"kind": "close", "rtol": 0.25, "atol": 0.05},
    "busiest_MB_at_target": {"kind": "close", "rtol": 0.35, "atol": 0.05},
    "total_MB": {"kind": "close", "rtol": 0.25, "atol": 0.05},
    "retrans_MB": {"kind": "close", "rtol": 0.35, "atol": 0.05},
    "n_retransmits": {"kind": "close", "rtol": 0.35, "atol": 2},
    "lost_messages": {"kind": "close", "rtol": 0.5, "atol": 2},
    "final_acc": {"kind": "close", "rtol": 0.25, "atol": 0.05},
    "uplink_slowdown_x": {"kind": "close", "rtol": 0.25, "atol": 0.1},
    "lossy_retrans_MB": {"kind": "close", "rtol": 0.35, "atol": 0.05},
    "clean_retrans_MB": {"kind": "exact"},
    "same_trajectory": {"kind": "exact"},
    "fifo_stretches_clock": {"kind": "exact"},
    # serve: batched multi-tenant serving must keep >=2x over the per-user
    # dense loop (the repro.serve acceptance floor); storage ratios and
    # cache behaviour are deterministic functions of (seed, density);
    # raw requests/s are machine-dependent and intentionally ungated —
    # the speedup ratio is the machine-independent contract
    "speedup_vs_dense": {"kind": "floor", "abs_floor": 2.0, "frac": 0.4},
    "users": {"kind": "exact"},
    "density": {"kind": "exact"},
    "requests": {"kind": "exact"},
    "mean_batch": {"kind": "close", "rtol": 0.05, "atol": 0.5},
    "cache_hit_rate": {"kind": "close", "rtol": 0.0, "atol": 0.01},
    "bytes_at_rest": {"kind": "close", "rtol": 0.01, "atol": 0},
    "dense_bytes_at_rest": {"kind": "close", "rtol": 0.01, "atol": 0},
    "at_rest_ratio": {"kind": "close", "rtol": 0.02, "atol": 0.01},
    # wall-clock: machine noise — catch only blowups
    "us_per_call": {"kind": "timing", "max_ratio": 8.0},
    "p50_ms": {"kind": "timing", "max_ratio": 8.0},
    "p99_ms": {"kind": "timing", "max_ratio": 8.0},
    "dense_p50_ms": {"kind": "timing", "max_ratio": 8.0},
    "dense_p99_ms": {"kind": "timing", "max_ratio": 8.0},
    "pack_us": {"kind": "timing", "max_ratio": 8.0},
    "encode_decode_us": {"kind": "timing", "max_ratio": 8.0},
    "unpack_us": {"kind": "timing", "max_ratio": 8.0},
    "gossip_deg3_us": {"kind": "timing", "max_ratio": 8.0},
    "loop_s_per_round": {"kind": "timing", "max_ratio": 8.0},
    "vmap_s_per_round": {"kind": "timing", "max_ratio": 8.0},
    "scale_s_per_round": {"kind": "timing", "max_ratio": 8.0},
    "traced_s_per_round": {"kind": "timing", "max_ratio": 8.0},
    "untraced_s_per_round": {"kind": "timing", "max_ratio": 8.0},
    # observability: enabling ring tracing must stay cheap relative to the
    # same run untraced — an absolute cap, not baseline-relative, because
    # the ratio is already machine-normalized
    "trace_overhead_ratio": {"kind": "ceiling", "abs_ceiling": 1.25},
}


def _check(metric: str, new, base) -> str | None:
    """Violation message, or None if the metric passes / has no rule."""
    rule = _RULES.get(metric)
    if rule is None or isinstance(new, (dict, list, str)):
        return None
    kind = rule["kind"]
    if kind == "exact":
        if new != base:
            return f"{metric}: {new!r} != baseline {base!r}"
        return None
    if kind == "ceiling":               # baseline-independent: cap only
        if float(new) > rule["abs_ceiling"]:
            return (f"{metric}: {float(new):g} above ceiling "
                    f"{rule['abs_ceiling']:g}")
        return None
    new, base = float(new), float(base)
    if kind == "close":
        tol = rule["atol"] + rule["rtol"] * abs(base)
        if abs(new - base) > tol:
            return (f"{metric}: {new:g} vs baseline {base:g} "
                    f"(tolerance {tol:g})")
    elif kind == "timing":
        if new > rule["max_ratio"] * base + 1.0:
            return (f"{metric}: {new:g} > {rule['max_ratio']:g}x "
                    f"baseline {base:g}")
    elif kind == "floor":
        floor = max(rule["abs_floor"], rule["frac"] * base)
        if new < floor:
            return f"{metric}: {new:g} below floor {floor:g} (baseline {base:g})"
    return None


def run_modules(only: list[str]) -> dict[str, list[dict]]:
    out = {}
    for name in MODULES:
        if only and name not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        out[name] = mod.run(fast=True)
    return out


def compare(module: str, rows: list[dict]) -> list[str]:
    path = os.path.join(BASELINE_DIR, f"{module}.json")
    if not os.path.exists(path):
        return [f"{module}: no baseline at {path} "
                f"(run with --update and commit it)"]
    with open(path) as f:
        base_rows = {r["name"]: r for r in json.load(f)["rows"]}
    failures = []
    seen = set()
    for row in rows:
        name = row["name"]
        seen.add(name)
        base = base_rows.get(name)
        if base is None:
            failures.append(f"{name}: row not in baseline (--update?)")
            continue
        for metric, new in row.items():
            if metric == "name":
                continue
            msg = _check(metric, new, base.get(metric))
            if msg:
                failures.append(f"{name}: {msg}")
    for missing in sorted(set(base_rows) - seen):
        failures.append(f"{missing}: baseline row missing from fresh run")
    return failures


def _attribute(history_path: str) -> None:
    """On gate failure, name the dominant phase/counter deltas between
    this run (just appended to history) and the previous one — the
    difference between "trace_overhead_ratio is over the ceiling" and
    "round.local got 2.1x slower and jax recompiled 14 more times"."""
    from repro.obs import diff_runs, read_history
    runs = read_history(history_path, event="run")
    if len(runs) < 2:
        print("# --attribute: no previous run in history to diff against",
              file=sys.stderr)
        return
    old, new = runs[-2], runs[-1]
    d = diff_runs(old, new)
    print(f"# ATTRIBUTION vs {old.get('git_sha', '?')} "
          f"@ {old.get('iso', '?')}:", file=sys.stderr)
    for p in d["phases"]:
        ratio = ("inf" if p["old_s"] == 0 else f"{p['ratio']:.2f}x")
        print(f"#   phase {p['phase']}: {p['old_s']:.4f}s -> "
              f"{p['new_s']:.4f}s ({p['delta_s']:+.4f}s, {ratio})",
              file=sys.stderr)
    for c in d["counters"]:
        print(f"#   counter {c['counter']}: {c['old']:g} -> {c['new']:g} "
              f"({c['delta']:+g}, {c['rel']:.1%})", file=sys.stderr)
    if not d["phases"] and not d["counters"]:
        print("#   no phase/counter deltas between the two runs",
              file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite benchmarks/baselines/*.json from this run")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    ap.add_argument("--out", default="BENCH_latest.json",
                    help="write all fresh rows here (CI artifact)")
    ap.add_argument("--trace", default="BENCH_trace.json",
                    help="export a Perfetto trace of the gated run here "
                         "('': disable)")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="append this run (per-module rows + a run line "
                         "with phase_summary and counters) to this "
                         "append-only JSONL ('': disable)")
    ap.add_argument("--attribute", action="store_true",
                    help="on rule failure, diff this run against the "
                         "previous history run line and name the top "
                         "phase/counter deltas")
    args = ap.parse_args()
    only = [m.strip() for m in args.only.split(",") if m.strip()]

    if args.trace:
        from repro.obs import get_tracer
        get_tracer().enable(mode="ring", capacity=1 << 18)

    results = run_modules(only)
    with open(args.out, "w") as f:
        json.dump({"modules": {m: rows for m, rows in results.items()}},
                  f, indent=1, default=str)
    print(f"# wrote {sum(len(r) for r in results.values())} rows "
          f"to {args.out}")
    if args.trace:
        from repro.obs import write_trace
        doc = write_trace(args.trace)
        print(f"# wrote trace ({doc['otherData']['spans']} spans) "
              f"to {args.trace}")
    if args.history:
        from repro.obs import append_history, phase_summary, snapshot_counters
        n = append_history(
            args.history, results,
            phase_summary_doc=phase_summary() if args.trace else None,
            counters=snapshot_counters(),
            note="update" if args.update else "gate")
        print(f"# appended {n} lines to {args.history}")

    if args.update:
        os.makedirs(BASELINE_DIR, exist_ok=True)
        for module, rows in results.items():
            path = os.path.join(BASELINE_DIR, f"{module}.json")
            with open(path, "w") as f:
                json.dump({"module": module, "rows": rows}, f, indent=1,
                          default=str)
                f.write("\n")
            print(f"# baseline updated: {path}")
        return

    failures = []
    for module, rows in results.items():
        failures.extend(compare(module, rows))
    if failures:
        print(f"# BENCH GATE: {len(failures)} violation(s)", file=sys.stderr)
        for msg in failures:
            print(f"#   {msg}", file=sys.stderr)
        if args.attribute and args.history:
            _attribute(args.history)
        raise SystemExit(1)
    n = sum(len(r) for r in results.values())
    print(f"# bench gate OK: {n} rows within tolerance of baselines")


if __name__ == "__main__":
    main()
