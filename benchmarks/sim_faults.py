"""Fault-realistic deployment sweep: clean vs lossy vs shared-uplink.

Every scenario runs the same DisPFL training through ``repro.sim.SimEngine``
on narrow links (so transfer time is visible next to compute) and reports
virtual time-to-target and busiest-node MB — the paper's deployment axis
under progressively less idealized networks:

* ``clean``        — v1 physics: per-edge parallel transfers, no loss.
* ``uplink_fifo``  — a sender's concurrent transfers serialize on its
  shared uplink (FIFO), stretching every round's arrival tail.
* ``uplink_fair``  — same uplink, processor-sharing discipline.
* ``lossy``        — 20% per-link Bernoulli drops with timeout/retransmit;
  every retransmitted byte is measured on the wire.

Sync rows share one training trajectory (the barrier transport is
reliable), so their time-to-target differences are *pure network physics*;
the async rows show how loss + uplink contention shift an actual
staleness-bounded run.  All quantities are virtual — deterministic given
the seed — which is what lets ``benchmarks/check_regression.py`` gate them
tightly in CI.
"""
from __future__ import annotations

from benchmarks.common import fl_setup, timer

TARGET_EPS = 1e-9


def _scenarios():
    from repro.sim import LossModel

    return [
        ("clean", "parallel", None),
        ("uplink_fifo", "fifo", None),
        ("uplink_fair", "fair", None),
        ("lossy", "parallel", LossModel(0.2, timeout_s=0.25, seed=0)),
    ]


def run(fast: bool = True) -> list[dict]:
    from repro.fl import make_strategy
    from repro.sim import LinkModel, LossModel, SimEngine, hetero_speeds
    from repro.sim.report import time_to_target

    task, clients, cfg = fl_setup(fast, "dirichlet")
    k = cfg.n_clients
    speeds = hetero_speeds(k, seed=cfg.seed)
    links = LinkModel.uniform(k, mbps=2.0, latency_ms=20.0)
    rows = []

    # --- sync: one trajectory, four network physics ----------------------
    sync = {}
    sync_rows = {}
    for name, uplink, loss in _scenarios():
        eng = SimEngine(
            make_strategy("dispfl"), task, clients, cfg, mode="sync",
            links=links, round_s=1.0, compute_speeds=speeds,
            uplink=uplink, loss=loss)
        with timer() as t:
            eng.run()
        sync[name] = eng
        sync_rows[name] = _row(f"sim_faults/sync/{name}", eng, t["s"], cfg)
        rows.append(sync_rows[name])
    # all sync runs evaluate identical models — network faults only stretch
    # the clock, so time-to-target ordering is a pure physics statement
    target = min(max(a for _, a in e.acc_trace) for e in sync.values())
    target -= TARGET_EPS
    for name, eng in sync.items():
        hit = time_to_target(eng.acc_trace, target)
        sync_rows[name]["sim_s_to_target"] = round(hit, 3)
        sync_rows[name]["busiest_MB_at_target"] = (
            round(eng.stats.busiest_mb_until(hit), 3) if hit >= 0 else -1)
    t_clean = time_to_target(sync["clean"].acc_trace, target)
    t_fifo = time_to_target(sync["uplink_fifo"].acc_trace, target)
    rows.append({
        "name": "sim_faults/sync/check",
        "same_trajectory": all(
            e.acc_trace[-1][1] == sync["clean"].acc_trace[-1][1]
            for e in sync.values()),
        "fifo_stretches_clock": t_fifo >= t_clean,
        "uplink_slowdown_x": round(t_fifo / t_clean, 3) if t_clean > 0 else -1,
        "lossy_retrans_MB": round(sync["lossy"].stats.retrans_mb, 3),
        "clean_retrans_MB": round(sync["clean"].stats.retrans_mb, 3),
    })

    # --- async: faults change what actually arrives ----------------------
    for name, uplink, loss in (("clean", "parallel", None),
                               ("lossy_fifo", "fifo",
                                LossModel(0.2, timeout_s=0.25, seed=0))):
        eng = SimEngine(
            make_strategy("dispfl"), task, clients, cfg, mode="async",
            staleness=2, links=links, round_s=1.0, compute_speeds=speeds,
            uplink=uplink, loss=loss)
        with timer() as t:
            eng.run()
        row = _row(f"sim_faults/async/{name}", eng, t["s"], cfg)
        row["lost_messages"] = eng.stats.n_lost
        rows.append(row)
    return rows


def _row(name: str, eng, wall: float, cfg) -> dict:
    return {
        "name": name,
        "us_per_call": round(wall * 1e6 / max(cfg.rounds, 1)),
        "sim_wall_s": round(eng.sim_time, 3),
        "busiest_MB_total": round(eng.stats.busiest_node()[1], 3),
        "total_MB": round(eng.stats.total_mb, 3),
        "retrans_MB": round(eng.stats.retrans_mb, 3),
        "n_retransmits": eng.stats.n_retransmits,
        "final_acc": round(eng.acc_trace[-1][1], 4) if eng.acc_trace else -1,
    }


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(fast=True))
