"""Paper Table 4: sparsity-ratio sweep (accuracy vs comm vs FLOPs).
The paper finds a sweet spot at sparsity 0.5 — too sparse loses accuracy
(little mask overlap), too dense loses the personalization benefit."""
from __future__ import annotations

import dataclasses

from benchmarks.common import fl_setup, timer

SPARSITIES = [0.8, 0.5, 0.2]          # density = 1 - sparsity
FULL_SPARSITIES = [0.8, 0.6, 0.5, 0.4, 0.2]


def run(fast: bool = True) -> list[dict]:
    from repro.fl import run_strategy

    rows = []
    task, clients, base = fl_setup(fast, "dirichlet")
    for sp in (SPARSITIES if fast else FULL_SPARSITIES):
        cfg = dataclasses.replace(base, density=1.0 - sp)
        with timer() as t:
            res = run_strategy("dispfl", task, clients, cfg)
        rows.append({
            "name": f"table4/sparsity_{sp}",
            "us_per_call": round(t["s"] * 1e6 / max(cfg.rounds, 1)),
            "acc": round(res.final_acc, 4),
            "comm_busiest_MB": round(res.comm_busiest_mb, 3),
            "flops_1e9": round(res.flops_per_round / 1e9, 2),
        })
    # monotone comm: higher sparsity => less communication
    comms = [r["comm_busiest_MB"] for r in rows]
    rows.append({"name": "table4/check/comm_monotone_in_sparsity",
                 "ok": all(a <= b for a, b in zip(comms, comms[1:]))})
    return rows
