"""Shared benchmark scaffolding.

Every benchmark module exposes ``run(fast: bool) -> list[dict]`` where each
dict is one result row.  ``emit`` renders rows as the harness CSV
(``name,us_per_call,derived``): *name* identifies the experiment cell,
*us_per_call* is the wall-time per unit of work, and *derived* carries the
paper-comparable quantities (accuracy / MB / FLOPs / roofline terms).
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def emit(rows: list[dict]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        print(f"{name},{us},{json.dumps(r, default=str)}")


def fl_setup(fast: bool, partition: str, seed: int = 0,
             n_clients: int | None = None):
    from repro.data import build_federated_image_task
    from repro.fl import FLConfig, make_cnn_task

    k = n_clients or (8 if fast else 20)
    clients, _ = build_federated_image_task(
        seed, n_clients=k, partition=partition, alpha=0.3,
        classes_per_client=2,
        n_train_per_class=60 if fast else 150,
        n_test_per_client=30 if fast else 60,
        hw=16, noise=0.8)
    task = make_cnn_task("smallcnn", 10, 16, width=8 if fast else 16)
    cfg = FLConfig(n_clients=k, rounds=4 if fast else 20,
                   local_epochs=2 if fast else 5,
                   batch_size=32, degree=min(10, k - 1) if not fast else 3,
                   seed=seed, eval_every=1)
    return task, clients, cfg
