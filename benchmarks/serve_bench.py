"""§Serve: multi-tenant batched serving vs per-user dense serving.

Two claims under the gate:

* throughput — the serving plane (packed store + slot-pool cache +
  micro-batched pool-wide launches) must beat per-user dense serving by
  >= 2x requests/s at K=64, d=0.5.  The gate measures *steady state*: a
  first (untimed for the gate, reported as ``cold_requests_per_s``) pass
  pays the cold decode of the working set into the slot pool; the gated
  pass then serves with the tenants resident, which is what a serving
  plane is for.  The dense baseline is the loop the plane replaces: every
  user's dense model at rest on the host, one dispatch per request that
  stages that user's params to the device (no residency plane, no
  batching).  ``dense_resident_requests_per_s`` additionally reports the
  all-K-models-pre-staged loop (the pure dispatch floor — no at-rest
  format at all, so not the gated baseline, but the batched path beats it
  too) for scale;
* storage — bytes at rest are codec frames, so they scale with mask
  density instead of K dense replicas (the bytes-vs-density curve).

Latency rows (p50/p99, requests/s) are wall-clock and gated only against
order-of-magnitude blowups; the speedup floor and byte ratios are the
machine-independent contracts.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import timer


def _build(model, n_users: int, density: float, cache_size: int, seed: int = 0):
    from repro.core.masks import apply_mask, init_mask
    from repro.serve import ModelStore

    base = model.init(jax.random.PRNGKey(seed))
    store = ModelStore(base, cache_size=cache_size)
    dense = {}
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), 2 * n_users)
    for u in range(n_users):
        p = model.init(keys[2 * u])
        m = init_mask(keys[2 * u + 1], p, density)
        pm = apply_mask(p, m)
        store.put(u, pm, m)
        dense[u] = pm
    return store, dense


def _dense_nbytes(params) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))


def run(fast: bool = True) -> list[dict]:
    from repro.serve import MLPModel, RequestStream, ServeEngine

    rows = []
    n_users, density = 64, 0.5          # the acceptance operating point
    n_requests = 512 if fast else 2048

    # one sample per request (the serving grain); pool = tenant working set
    model = MLPModel(d_in=64, widths=(128, 128), n_out=32, rows=1)
    store, dense = _build(model, n_users, density, cache_size=n_users)
    stream = RequestStream(n_users=n_users, n_requests=n_requests,
                           seed=0, rate=30000.0, popularity="uniform")
    reqs = stream.requests()

    # batched sparse serving: micro-batched pool-wide launches; service
    # time covers the whole launch (miss decodes, input scatter, forward)
    engine = ServeEngine(store, model, backend="vmap", max_batch=n_users,
                         max_wait=0.005)
    cold = engine.serve(reqs)
    res = engine.serve(reqs, warmup=False)       # steady state: the gate
    s = res.summary

    # per-user dense serving (the gated baseline): each user's dense model
    # at rest as host arrays; every request stages its user's params into
    # one dispatch — no unpack cache, no batching
    fwd = jax.jit(model.forward)
    dense_host = {u: jax.tree.map(np.asarray, p) for u, p in dense.items()}
    xs = {r.rid: model.make_input(r.input_seed) for r in reqs}
    jax.block_until_ready(fwd(dense_host[reqs[0].user], xs[reqs[0].rid]))
    lat = []
    with timer() as t:
        for r in reqs:
            t0 = time.perf_counter()
            jax.block_until_ready(fwd(dense_host[r.user], xs[r.rid]))
            lat.append((time.perf_counter() - t0) * 1e3)
    dense_rps = n_requests / t["s"]

    # informational bound: all K dense models pre-staged on device
    dense_dev = {u: jax.device_put(p) for u, p in dense.items()}
    jax.block_until_ready(fwd(dense_dev[reqs[0].user], xs[reqs[0].rid]))
    with timer() as t:
        for r in reqs:
            jax.block_until_ready(fwd(dense_dev[r.user], xs[r.rid]))
    dense_resident_rps = n_requests / t["s"]

    rows.append({
        "name": f"serve/k{n_users}_d{density}_batched_vs_dense",
        "us_per_call": round(s["service_s"] / n_requests * 1e6, 2),
        "users": n_users,
        "density": density,
        "requests": n_requests,
        "mean_batch": s["mean_batch"],
        "requests_per_s": s["requests_per_s"],
        "cold_requests_per_s": cold.summary["requests_per_s"],
        "dense_requests_per_s": round(dense_rps, 1),
        "dense_resident_requests_per_s": round(dense_resident_rps, 1),
        "speedup_vs_dense": round(s["requests_per_s"] / dense_rps, 2),
        "p50_ms": s["p50_ms"],
        "p99_ms": s["p99_ms"],
        "dense_p50_ms": round(float(np.percentile(lat, 50)), 3),
        "dense_p99_ms": round(float(np.percentile(lat, 99)), 3),
        "cache_hit_rate": s["cache_hit_rate"],
    })

    # bytes at rest vs density: K sparse frames vs K dense replicas
    k_store = 8
    for d in (0.1, 0.5, 1.0):
        st, _ = _build(model, k_store, d, cache_size=2, seed=7)
        dense_total = k_store * _dense_nbytes(st.base)
        rows.append({
            "name": f"serve/bytes_at_rest_d{d}",
            "users": k_store,
            "density": d,
            "bytes_at_rest": st.total_bytes_at_rest(),
            "dense_bytes_at_rest": dense_total,
            "at_rest_ratio": round(st.total_bytes_at_rest() / dense_total, 4),
        })
    return rows
