"""Paper-exact Table 1/2 comm + FLOPs columns, computed analytically from
the real ResNet18-GN / VGG11-GN definitions (no training needed).

Expected (paper): ResNet18 dense comm 446.9 MB, DisPFL 223.4 MB; ring 89.4 /
44.6 MB; FC 4423.9 / 2211.4 MB; FLOPs 8.3e12 dense, ~7.0e12 DisPFL@0.5;
VGG11 comm 184.6 MB @50%.
"""
from __future__ import annotations

import jax

from benchmarks.common import timer
from repro.core.accounting import decentralized_comm, sparse_training_flops
from repro.core.masks import erk_densities_for_params
from repro.core.topology import fully_connected, ring, time_varying_random
from repro.models import cnn
from repro.utils.tree import tree_size


def run(fast: bool = True) -> list[dict]:
    del fast
    rows = []
    with timer() as t:
        r18 = cnn.init_resnet18(jax.random.PRNGKey(0), 10)
        v11 = cnn.init_vgg11(jax.random.PRNGKey(0), 10)
        n18, n11 = tree_size(r18), tree_size(v11)
        k = 100
        topo = {
            "dynamic": time_varying_random(k, 10, 0, seed=0),
            "ring": ring(k),
            "fc": fully_connected(k),
        }
        for tname, a in topo.items():
            dense = decentralized_comm(a, [n18] * k, n18)
            sparse = decentralized_comm(a, [int(n18 * 0.5)] * k, n18)
            rows.append({"name": f"comm/resnet18/{tname}/dense",
                         "MB": dense.row()["busiest_MB"]})
            rows.append({"name": f"comm/resnet18/{tname}/dispfl_0.5",
                         "MB": sparse.row()["busiest_MB"]})
        dense_v = decentralized_comm(topo["dynamic"], [int(n11 * 0.5)] * k, n11)
        rows.append({"name": "comm/vgg11/dynamic/dispfl_0.5",
                     "MB": dense_v.row()["busiest_MB"]})

        fl18 = cnn.resnet18_fwd_flops(10, 32)
        dens = erk_densities_for_params(r18, 0.5)
        rows.append({
            "name": "flops/resnet18/dense",
            "flops_1e12": round(sparse_training_flops(
                fl18, {p: 1.0 for p in fl18}, 500, 5, 0).per_round_flops / 1e12, 2),
            "paper": 8.3})
        rows.append({
            "name": "flops/resnet18/dispfl_0.5",
            "flops_1e12": round(sparse_training_flops(
                fl18, dens, 500, 5, 1, 128).per_round_flops / 1e12, 2),
            "paper": 7.0})
        fl11 = cnn.vgg11_fwd_flops(10, 32)
        dens11 = erk_densities_for_params(v11, 0.5)
        rows.append({
            "name": "flops/vgg11/dispfl_0.5",
            "flops_1e12": round(sparse_training_flops(
                fl11, dens11, 500, 5, 1, 128).per_round_flops / 1e12, 2)})
    for r in rows:
        r.setdefault("us_per_call", round(t["s"] * 1e6 / len(rows)))
    return rows
