"""Paper Table 3 + Fig 4: heterogeneous client capacities.

Setting (i): every client at density 0.5.
Setting (ii): 5 capacity groups {0.2, 0.4, 0.6, 0.8, 1.0}.
D-PSGD baselines are confined to the weakest capacity (0.2) in setting (ii).
Also reports per-capacity-group accuracy (Fig 4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import fl_setup, timer


def run(fast: bool = True) -> list[dict]:
    from repro.fl import run_strategy
    from repro.fl.decentralized import run_dpsgd

    rows = []
    task, clients, base = fl_setup(fast, "pathological")
    k = len(clients)
    levels = [0.2, 0.4, 0.6, 0.8, 1.0]
    caps = [levels[i % 5] for i in range(k)]

    # setting (i): homogeneous 0.5
    cfg_i = dataclasses.replace(base, density=0.5, capacities=None)
    with timer() as t:
        res = run_strategy("dispfl", task, clients, cfg_i)
    rows.append({"name": "table3/setting_i/dispfl",
                 "us_per_call": round(t["s"] * 1e6),
                 "acc": round(res.final_acc, 4),
                 "comm_avg_MB": res.comm_rows["avg_node_MB"]})

    # setting (ii): heterogeneous capacities
    cfg_ii = dataclasses.replace(base, capacities=caps)
    with timer() as t:
        res_ii = run_strategy("dispfl", task, clients, cfg_ii)
    rows.append({"name": "table3/setting_ii/dispfl",
                 "us_per_call": round(t["s"] * 1e6),
                 "acc": round(res_ii.final_acc, 4),
                 "comm_avg_MB": res_ii.comm_rows["avg_node_MB"]})

    # D-PSGD confined to the weakest device (20% params)
    with timer() as t:
        res_d = run_dpsgd(task, clients, cfg_i, finetune=True,
                          param_fraction=0.2)
    rows.append({"name": "table3/setting_ii/dpsgd_ft_20pct",
                 "us_per_call": round(t["s"] * 1e6),
                 "acc": round(res_d.final_acc, 4)})
    rows.append({"name": "table3/check/dispfl_beats_weakest_constrained",
                 "ok": res_ii.final_acc > res_d.final_acc})

    # Fig 4: per-capacity-group accuracy under setting (ii)
    accs = np.array(res_ii.final_accs)
    for lvl in levels:
        sel = [i for i, c in enumerate(caps) if c == lvl]
        if sel:
            rows.append({"name": f"table3/fig4/group_density_{lvl}",
                         "acc": round(float(accs[sel].mean()), 4),
                         "n_clients": len(sel)})
    return rows
