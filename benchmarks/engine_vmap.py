"""Local-phase execution: per-client Python loop vs the engine's vmap fast
path (stacked clients, jitted lax.scan).  The vmap path removes the O(K)
Python/dispatch overhead per round, which dominates simulation wall-clock
for small models at K >= 16.

Rows report seconds per round and the loop/vmap speedup at each K.

The ``trace_overhead`` row is the instrumentation-cost contract of
``repro.obs``: the same vmap engine run with tracing disabled vs enabled
(ring mode), alternating per round so drift hits both sides equally.
``check_regression`` gates the ratio against an absolute ceiling.  The
probe swaps in a private ``Tracer`` so it never clobbers a run-level
``--trace`` capture.
"""
from __future__ import annotations

import statistics

from benchmarks.common import timer


def _setup(k: int, fast: bool):
    import dataclasses

    from repro.data import build_federated_image_task
    from repro.fl import FLConfig, make_cnn_task

    clients, _ = build_federated_image_task(
        0, n_clients=k, partition="pathological", classes_per_client=2,
        n_train_per_class=64 if fast else 160,
        n_test_per_client=20, hw=16, noise=0.8)
    # equalize shard sizes: the vmap fast path requires every client to share
    # one batch schedule (the homogeneous-simulation regime it accelerates)
    n_min = min(c.n_train for c in clients)
    clients = [dataclasses.replace(c, train_x=c.train_x[:n_min],
                                   train_y=c.train_y[:n_min])
               for c in clients]
    task = make_cnn_task("smallcnn", 10, 16, width=8 if fast else 16)
    cfg = FLConfig(n_clients=k, rounds=3 if fast else 5,
                   local_epochs=2 if fast else 5, batch_size=32,
                   degree=min(10, k - 1), eval_every=10**6)
    return task, clients, cfg


def _trace_overhead_row(fast: bool) -> dict:
    from repro.fl import RoundEngine, make_strategy
    from repro.obs import Tracer, set_tracer

    import dataclasses

    task, clients, cfg = _setup(8, True)
    cfg = dataclasses.replace(cfg, rounds=9 if fast else 17)
    eng = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                      local_exec="vmap")
    probe = Tracer()
    old = set_tracer(probe)
    try:
        it = eng.rounds()
        next(it)                          # warm-up round (jit compiles)
        off, on = [], []
        n_spans = 0
        for m in it:
            # m.wall_s was measured under the tracer state armed *before*
            # the round ran; flip the state for the next round
            if probe.enabled:
                on.append(m.wall_s)
                n_spans += len(probe)
                probe.disable()
            else:
                off.append(m.wall_s)
                probe.enable(mode="ring")   # resets the buffer
    finally:
        probe.disable()
        set_tracer(old)
    untraced = statistics.median(off)
    traced = statistics.median(on)
    return {
        "name": "engine_vmap/trace_overhead",
        # added us per round; clamped — machine jitter can make the traced
        # median land under the untraced one, and the timing rule assumes
        # a nonnegative baseline
        "us_per_call": round(max(traced - untraced, 0.0) * 1e6, 1),
        "untraced_s_per_round": round(untraced, 4),
        "traced_s_per_round": round(traced, 4),
        "trace_overhead_ratio": round(traced / untraced, 4),
        "spans_per_round": round(n_spans / max(len(on), 1), 1),
    }


def run(fast: bool) -> list[dict]:
    from repro.fl import RoundEngine, make_strategy

    rows = []
    for k in ((16,) if fast else (16, 32)):
        task, clients, cfg = _setup(k, fast)
        walls = {}
        accs = {}
        for exec_mode in ("loop", "vmap"):
            eng = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                              local_exec=exec_mode)
            it = eng.rounds()
            next(it)                      # warm-up round (jit compiles)
            with timer() as box:
                steady = sum(1 for _ in it)
            walls[exec_mode] = box["s"] / max(steady, 1)
            accs[exec_mode] = eng.result().final_acc
        rows.append({
            "name": f"engine_vmap/dispfl_K{k}",
            "us_per_call": round(walls["vmap"] * 1e6, 1),
            "loop_s_per_round": round(walls["loop"], 3),
            "vmap_s_per_round": round(walls["vmap"], 3),
            "speedup": round(walls["loop"] / walls["vmap"], 2),
            "acc_loop": round(accs["loop"], 4),
            "acc_vmap": round(accs["vmap"], 4),
        })
    rows.append(_trace_overhead_row(fast))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(fast=True))
