"""Paper Table 1: personalized accuracy + comm + FLOPs, all methods, both
non-IID partitions (synthetic task at CPU scale; paper-exact comm/FLOP
columns come from benchmarks/comm_flops.py)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import fl_setup, timer

METHODS = ["local", "fedavg", "fedavg_ft", "dpsgd", "dpsgd_ft", "ditto",
           "fomo", "subfedavg", "dispfl"]


def run(fast: bool = True) -> list[dict]:
    from repro.fl import run_strategy

    rows = []
    for partition in ("dirichlet", "pathological"):
        task, clients, cfg = fl_setup(fast, partition)
        for method in METHODS:
            with timer() as t:
                res = run_strategy(method, task, clients, cfg)
            rows.append({
                "name": f"table1/{partition}/{method}",
                "us_per_call": round(t["s"] * 1e6 / max(cfg.rounds, 1)),
                "acc": round(res.final_acc, 4),
                "comm_busiest_MB": round(res.comm_busiest_mb, 2),
                "flops_1e9": round(res.flops_per_round / 1e9, 2),
            })
    # the paper's headline ordering: DisPFL beats the global-model methods
    by = {r["name"].split("/", 1)[1]: r["acc"] for r in rows}
    rows.append({
        "name": "table1/check/dispfl_beats_global_methods",
        "pathological_dispfl": by.get("pathological/dispfl"),
        "pathological_fedavg": by.get("pathological/fedavg"),
        "ok": by.get("pathological/dispfl", 0) > by.get("pathological/fedavg", 1),
    })
    return rows
