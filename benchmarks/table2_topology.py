"""Paper Table 2/8/9: ring vs fully-connected vs time-varying topologies."""
from __future__ import annotations

import dataclasses

from benchmarks.common import fl_setup, timer


def run(fast: bool = True) -> list[dict]:
    from repro.fl import run_strategy

    rows = []
    task, clients, base = fl_setup(fast, "pathological")
    for topology in ("ring", "fc", "random"):
        cfg = dataclasses.replace(base, topology=topology)
        for method in ("dpsgd", "dpsgd_ft", "dispfl"):
            with timer() as t:
                res = run_strategy(method, task, clients, cfg)
            rows.append({
                "name": f"table2/{topology}/{method}",
                "us_per_call": round(t["s"] * 1e6 / max(cfg.rounds, 1)),
                "acc": round(res.final_acc, 4),
                "comm_busiest_MB": round(res.comm_busiest_mb, 2),
            })
    # DisPFL should halve the per-topology busiest-node comm of D-PSGD
    ring_ratio = (rows[2]["comm_busiest_MB"] / rows[0]["comm_busiest_MB"]
                  if rows[0]["comm_busiest_MB"] else None)
    rows.append({"name": "table2/check/ring_sparse_ratio",
                 "ratio": round(ring_ratio, 3) if ring_ratio else None,
                 "ok": ring_ratio is not None and ring_ratio < 0.62})
    return rows
