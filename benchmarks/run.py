"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV rows (derived = JSON payload with
the paper-comparable quantities).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    "comm_flops",        # paper-exact Table 1/2 comm + FLOPs columns
    "kernels_bench",     # Pallas kernel micro-benchmarks
    "table1_accuracy",   # Table 1 (accuracy, both partitions)
    "table2_topology",   # Table 2/8/9
    "table3_heterogeneous",  # Table 3 + Fig 4
    "table4_sparsity",   # Table 4
    "table5_convergence",  # Tables 5-7
    "fig5_masks",        # Fig 5
    "fig6_dropping",     # Fig 6
    "sim_async",         # §Sim: sync vs async wall-clock + busiest-node MB
    "sim_faults",        # §Sim v2: clean vs lossy vs shared-uplink physics
    "sparse_codec",      # §Sparse: packed payload throughput + bytes vs density
    "engine_vmap",       # §Perf: loop vs vmap local phase at K>=16
    "scale_engine",      # §Scale: one-program stacked round vs loop engine
    "serve_bench",       # §Serve: batched multi-tenant serving vs dense loop
    "roofline",          # dry-run roofline aggregation
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow on CPU)")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    ap.add_argument("--trace", default="",
                    help="export a Perfetto trace_event JSON of the whole "
                         "benchmark run (repro.obs) to this path")
    ap.add_argument("--history", default="",
                    help="append timestamped, git-sha-stamped rows per "
                         "module to this JSONL (the append-only perf "
                         "trajectory; BENCH_latest.json only holds the "
                         "newest run)")
    args = ap.parse_args()
    only = [m.strip() for m in args.only.split(",") if m.strip()]

    if args.trace:
        from repro.obs import get_tracer
        get_tracer().enable(mode="ring", capacity=1 << 18)

    rows = []
    by_module: dict[str, list] = {}
    failed = []
    for name in MODULES:
        if only and name not in only:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod_rows = mod.run(fast=not args.full)
            rows.extend(mod_rows)
            by_module[name] = mod_rows
        except Exception:
            traceback.print_exc()
            failed.append(name)
            rows.append({"name": f"{name}/ERROR", "error": "see stderr"})
    emit(rows)
    if args.trace:
        from repro.obs import write_trace
        doc = write_trace(args.trace)
        print(f"# wrote trace ({doc['otherData']['spans']} spans) to "
              f"{args.trace}", file=sys.stderr)
    if args.history:
        from repro.obs import append_history, phase_summary, snapshot_counters
        n = append_history(
            args.history, by_module,
            phase_summary_doc=phase_summary() if args.trace else None,
            counters=snapshot_counters(),
            note="full" if args.full else "fast")
        print(f"# appended {n} lines to {args.history}", file=sys.stderr)
    if failed:
        print(f"# FAILED modules: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
