"""Packed payload subsystem: throughput and bytes-on-wire vs density.

For each global density, a smallcnn-sized parameter tree is masked at an
ERK allocation and pushed through the full packed pipeline: ``pack_tree``
(message construction), ``codec.encode``/``decode`` (the wire), and a
degree-3 ``packed_gossip_one`` (the mix hot path, jnp backend — what the
engine runs on CPU).  Reported per cell: per-op wall time, the exact codec
frame size, the dense frame it replaces, and the measured compression
ratio — which should track density (values dominate the frame; the bitmap
adds a fixed coords/8 floor).
"""
from __future__ import annotations

from benchmarks.common import timer

DENSITIES = [1.0, 0.5, 0.2, 0.1, 0.05]
REPS = 5


def _world(density: float, degree: int = 3, seed: int = 0):
    import jax
    from repro.core.masks import init_mask
    from repro.fl import make_cnn_task

    task = make_cnn_task("smallcnn", 10, 16, width=16)
    key = jax.random.PRNGKey(seed)
    params = task.init_fn(key)
    masks = [init_mask(jax.random.fold_in(key, i), params, density)
             for i in range(degree + 1)]
    models = [jax.tree.map(lambda w, m: w * m, params, mk) for mk in masks]
    return models, masks


def run(fast: bool = True) -> list[dict]:
    import numpy as np
    from repro.sparse import (
        TreeSpec,
        decode,
        encode,
        encoded_nbytes,
        pack_tree,
        packed_gossip_one,
        unpack_tree,
    )
    from repro.utils.tree import tree_size

    reps = REPS if fast else 4 * REPS
    rows = []
    for density in DENSITIES:
        models, masks = _world(density)
        own_w, own_m = models[0], masks[0]
        with timer() as t_pack:
            for _ in range(reps):
                packs = [pack_tree(w, m) for w, m in zip(models[1:], masks[1:])]
        spec = TreeSpec.from_tree(packs[0])
        with timer() as t_codec:
            for _ in range(reps):
                frames = [encode(p) for p in packs]
                packs = [decode(f, spec) for f in frames]
        with timer() as t_unpack:
            for _ in range(reps):
                unpack_tree(packs[0])
        with timer() as t_gossip:
            for _ in range(reps):
                mixed = packed_gossip_one(own_w, own_m, packs)
        del mixed
        n_coords = tree_size(own_w)
        wire = encoded_nbytes(packs[0])
        dense_wire = encoded_nbytes(pack_tree(models[1]))
        rows.append({
            "name": f"sparse_codec/d={density}",
            "us_per_call": round(t_gossip["s"] * 1e6 / reps),
            "pack_us": round(t_pack["s"] * 1e6 / (reps * len(packs))),
            "encode_decode_us": round(t_codec["s"] * 1e6 / (reps * len(packs))),
            "unpack_us": round(t_unpack["s"] * 1e6 / reps),
            "gossip_deg3_us": round(t_gossip["s"] * 1e6 / reps),
            "wire_bytes": wire,
            "dense_wire_bytes": dense_wire,
            "bytes_ratio": round(wire / dense_wire, 4),
            "coords": n_coords,
        })
    # the headline check: payload bytes shrink ~proportionally with density
    # (bitmap floor = coords/8 + 8B header keeps the ratio slightly above d)
    ratios = {r["name"].split("=")[1]: r["bytes_ratio"] for r in rows}
    rows.append({
        "name": "sparse_codec/check",
        "ratio_tracks_density": all(
            abs(ratios[str(d)] - d) < 0.04 + d * 0.1 for d in DENSITIES),
        "ratios": ratios,
    })
    return rows
