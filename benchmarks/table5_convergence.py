"""Paper Tables 5-7: communication rounds to reach target accuracies."""
from __future__ import annotations

from benchmarks.common import fl_setup, timer


def run(fast: bool = True) -> list[dict]:
    from repro.fl import run_strategy

    rows = []
    task, clients, cfg = fl_setup(fast, "pathological")
    targets = (0.3, 0.45) if fast else (0.4, 0.6, 0.7)
    for method in ("local", "dpsgd_ft", "subfedavg", "dispfl"):
        with timer() as t:
            res = run_strategy(method, task, clients, cfg, targets=targets)
        row = {"name": f"table5/{method}",
               "us_per_call": round(t["s"] * 1e6 / max(cfg.rounds, 1)),
               "final_acc": round(res.final_acc, 4)}
        for tgt, r in res.rounds_to.items():
            row[f"rounds_to_{tgt}"] = r
        rows.append(row)
    return rows
