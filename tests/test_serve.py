"""repro.serve: store round-trip + at-rest accounting, LRU determinism,
batcher reproducibility, batched-kernel parity, engine bit-exactness (every
registered smoke arch) and end-to-end serving."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core.masks import apply_mask, init_mask
from repro.serve import (
    MicroBatcher,
    MLPModel,
    ModelStore,
    RequestStream,
    ServeEngine,
    TaskModel,
)
from repro.serve.model import ArchModel
from repro.sparse import encoded_nbytes, pack_tree

pytestmark = pytest.mark.tier1


def _mlp_store(model, n_users=6, density=0.5, cache_size=4, seed=0):
    base = model.init(jax.random.PRNGKey(seed))
    store = ModelStore(base, cache_size=cache_size)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), 2 * n_users)
    masked, masks = [], []
    for u in range(n_users):
        p = model.init(keys[2 * u])
        m = init_mask(keys[2 * u + 1], p, density)
        pm = apply_mask(p, m)
        store.put(u, pm, m)
        masked.append(pm)
        masks.append(m)
    return store, masked, masks


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------


def test_store_roundtrip_bit_exact():
    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    store, masked, masks = _mlp_store(model)
    for u in range(len(masked)):
        p, m = store.get(u)
        assert _trees_equal(p, masked[u])
        assert _trees_equal(m, masks[u])


def test_store_bytes_at_rest_is_codec_frame():
    """The acceptance invariant: bytes_at_rest == codec.encoded_nbytes of
    the user's packed delta, byte for byte."""
    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    store, masked, masks = _mlp_store(model, density=0.3)
    for u in range(len(masked)):
        packed = pack_tree(masked[u], masks[u], dtype=np.float32)
        assert store.bytes_at_rest(u) == encoded_nbytes(packed)
    assert store.total_bytes_at_rest() == sum(
        store.bytes_at_rest(u) for u in store.users())


def test_store_bytes_scale_with_density():
    model = MLPModel(d_in=32, widths=(64,), n_out=16)
    sizes = {}
    for d in (0.1, 0.5, 1.0):
        store, _, _ = _mlp_store(model, n_users=2, density=d)
        sizes[d] = store.bytes_at_rest(0)
    assert sizes[0.1] < sizes[0.5] < sizes[1.0]


def test_store_unknown_user_cold_start():
    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    store, _, _ = _mlp_store(model, n_users=2)
    p, m = store.get(999)
    assert _trees_equal(p, store.base)
    assert all(bool(jnp.all(x == 1)) for x in jax.tree.leaves(m))
    assert 999 not in store


def test_store_put_overwrites_and_invalidates_cache():
    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    store, masked, masks = _mlp_store(model, n_users=2)
    store.get(0)
    assert store.resident(0)
    new_p = jax.tree.map(lambda x: x * 2.0, masked[1])
    store.put(0, new_p, masks[1])
    assert not store.resident(0)
    p, _ = store.get(0)
    assert _trees_equal(p, apply_mask(new_p, masks[1]))


def test_decode_dense_matches_unpacked_decode():
    """The store's fused miss path (frame -> dense host leaves in one
    bit-unpack pass) is bit-exact vs decode + unpack_tree/unpack_mask_tree."""
    from repro.sparse import decode, decode_dense, unpack_mask_tree, unpack_tree

    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    store, _, _ = _mlp_store(model, n_users=2, density=0.3)
    frame = store._frames[0]
    packed = decode(frame, store.spec)
    p_new, m_new = decode_dense(frame, store.spec)
    assert _trees_equal(p_new, unpack_tree(packed))
    assert _trees_equal(m_new, unpack_mask_tree(packed))


def test_lru_eviction_deterministic():
    model = MLPModel(d_in=16, widths=(32,), n_out=8)

    def run():
        store, _, _ = _mlp_store(model, n_users=5, cache_size=2)
        for u in [0, 1, 0, 2, 1, 0, 3, 4]:
            store.get(u)
        return store.stats()

    a, b = run(), run()
    assert a == b
    # by hand: 0m 1m 0h(0 MRU) 2m(evict 1) 1m(evict 0) 0m(evict 2)
    # 3m(evict 1) 4m(evict 0) -> 1 hit, 7 misses, 5 evictions
    assert (a["hits"], a["misses"], a["evictions"]) == (1, 7, 5)
    assert a["resident"] == 2


# ---------------------------------------------------------------------------
# store <- real trained checkpoint
# ---------------------------------------------------------------------------


def test_store_from_trained_checkpoint(tmp_path):
    from repro.data import build_federated_image_task
    from repro.fl import FLConfig, RoundEngine, make_cnn_task, make_strategy

    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=2, local_epochs=1, batch_size=16,
                   degree=2, eval_every=2)
    eng = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                      local_exec="loop")
    eng.run()
    path = str(tmp_path / "dispfl.npz")
    eng.save(path)

    store = ModelStore.from_checkpoint(path, cache_size=4)
    assert store.users() == [0, 1, 2, 3]
    for k in range(4):
        p, m = store.get(k)
        want = apply_mask(eng.state["params"][k], eng.state["masks"][k])
        assert _trees_equal(p, want), f"client {k} params not bit-exact"
        assert _trees_equal(m, eng.state["masks"][k])
    # the checkpointed models really serve: engine forward == task forward
    tm = TaskModel(task, hw=8)
    engine = ServeEngine(store, tm, backend="vmap", max_batch=2)
    reqs = RequestStream(n_users=4, n_requests=8, seed=5).requests()
    res = engine.serve(reqs)
    for r in reqs:
        p, _ = store.get(r.user)
        want = np.asarray(tm.forward(p, tm.make_input(r.input_seed)))
        assert np.array_equal(want, res.outputs[r.rid])


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------


def test_request_stream_reproducible():
    a = RequestStream(n_users=8, n_requests=50, seed=7).requests()
    b = RequestStream(n_users=8, n_requests=50, seed=7).requests()
    assert a == b
    c = RequestStream(n_users=8, n_requests=50, seed=8).requests()
    assert a != c
    assert all(0 <= r.user < 8 for r in a)
    assert all(a[i].t_arrival < a[i + 1].t_arrival for i in range(len(a) - 1))


@pytest.mark.parametrize("max_batch,max_wait", [(4, 0.002), (8, 0.0), (1, 0.01)])
def test_batcher_respects_knobs(max_batch, max_wait):
    reqs = RequestStream(n_users=8, n_requests=60, seed=3).requests()
    batches = list(MicroBatcher(reqs, max_batch=max_batch,
                                max_wait=max_wait).batches())
    served = [r.rid for b in batches for r in b.requests]
    assert sorted(served) == list(range(60))          # every request, once
    eps = 1e-12
    for b in batches:
        assert 1 <= len(b.requests) <= max_batch
        assert all(w <= max_wait + eps for w in b.queue_waits())
        assert all(w >= -eps for w in b.queue_waits())


def test_batcher_deterministic_and_resident_first():
    reqs = RequestStream(n_users=6, n_requests=40, seed=1).requests()
    resident = lambda u: u % 2 == 0

    def run():
        return [(b.t_flush, b.users) for b in
                MicroBatcher(reqs, max_batch=4, max_wait=0.003,
                             resident=resident).batches()]

    a, b = run(), run()
    assert a == b
    for _, users in a:
        # resident users form a prefix of every batch
        flags = [resident(u) for u in users]
        assert flags == sorted(flags, reverse=True)


# ---------------------------------------------------------------------------
# batched kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("density", [0.05, 0.5, 1.0])
def test_batched_kernel_matches_oracle_and_dense(density):
    from repro.kernels.ops import batched_masked_matmul
    from repro.kernels.ref import batched_masked_matmul_ref

    rng = np.random.default_rng(int(density * 100))
    u, m, k, n = 4, 5, 70, 33                    # odd shapes force padding
    x = jnp.asarray(rng.standard_normal((u, m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((u, k, n)).astype(np.float32))
    mask = jnp.asarray((rng.random((u, k, n)) < density).astype(np.float32))

    got = batched_masked_matmul(x, w, mask, bm=8, bn=16, bk=32)
    want = batched_masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    # ... and against the per-user dense-masked loop
    for i in range(u):
        dense = np.asarray(x[i]) @ (np.asarray(w[i]) * np.asarray(mask[i]))
        np.testing.assert_allclose(np.asarray(got[i]), dense,
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def test_engine_vmap_bit_exact_vs_per_user_loop():
    model = MLPModel(d_in=16, widths=(32,), n_out=8, rows=2)
    store, _, _ = _mlp_store(model, n_users=6, cache_size=3)
    reqs = RequestStream(n_users=6, n_requests=24, seed=2).requests()
    res = ServeEngine(store, model, backend="vmap", max_batch=4).serve(reqs)
    assert sorted(res.outputs) == [r.rid for r in sorted(reqs, key=lambda r: r.rid)]
    for r in reqs:
        p, _ = store.get(r.user)
        want = np.asarray(model.forward(p, model.make_input(r.input_seed)))
        assert np.array_equal(want, res.outputs[r.rid]), r.rid


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_engine_sparse_backends_match_vmap(backend):
    model = MLPModel(d_in=16, widths=(32,), n_out=8, rows=2)
    store, _, _ = _mlp_store(model, n_users=6, cache_size=3)
    reqs = RequestStream(n_users=6, n_requests=16, seed=4).requests()
    base = ServeEngine(store, model, backend="vmap", max_batch=4).serve(reqs)
    got = ServeEngine(store, model, backend=backend, max_batch=4).serve(reqs)
    for rid in base.outputs:
        np.testing.assert_allclose(got.outputs[rid], base.outputs[rid],
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("arch", sorted(SMOKE_ARCHS))
def test_engine_bit_exact_every_smoke_arch(arch):
    """The acceptance criterion: multi-tenant batching never perturbs a
    user's output.  Every request served in a mixed-user batch is bit-exact
    (fp32) vs the per-user reference — the same request served alone
    through a launch of the same width (the launch width is part of the
    compiled program, so it is held fixed; XLA lowers some archs'
    reductions differently at different widths)."""
    model = ArchModel(SMOKE_ARCHS[arch], prompt_len=4, rows=1)
    base = model.init(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    deltas = []
    for u in range(2):
        p = model.init(keys[2 * u])
        m = init_mask(keys[2 * u + 1], p, 0.5)
        deltas.append((apply_mask(p, m), m))

    def build_store():
        store = ModelStore(base, cache_size=2)
        for u, (p, m) in enumerate(deltas):
            store.put(u, p, m)
        return store

    reqs = RequestStream(n_users=2, n_requests=4, seed=6).requests()
    batched = ServeEngine(build_store(), model, backend="vmap",
                          max_batch=2).serve(reqs)
    alone = ServeEngine(build_store(), model, backend="vmap", max_batch=2)
    for r in reqs:
        want = alone.serve([r], warmup=False).outputs[r.rid]
        assert np.array_equal(want, batched.outputs[r.rid]), (arch, r.rid)
        # and the values are the per-user dense-masked forward (tolerance:
        # vmap fuses fp32 reductions differently than the unbatched apply)
        p, _ = build_store().get(r.user)
        ref = np.asarray(jax.jit(model.forward)(
            p, jnp.asarray(model.make_input(r.input_seed))))
        np.testing.assert_allclose(batched.outputs[r.rid], ref,
                                   atol=1e-4, rtol=1e-4)


def test_engine_serve_reproducible_counters():
    model = MLPModel(d_in=16, widths=(32,), n_out=8, rows=2)

    def run():
        store, _, _ = _mlp_store(model, n_users=8, cache_size=3)
        reqs = RequestStream(n_users=8, n_requests=40, seed=9)
        res = ServeEngine(store, model, backend="vmap", max_batch=4).serve(reqs)
        s = res.summary
        return (s["requests"], s["batches"], s["cache_hit_rate"],
                s["store_hits"], s["store_misses"], s["store_evictions"])

    assert run() == run()


def test_engine_metrics_stream(tmp_path):
    from repro.sim.report import MetricsStream

    model = MLPModel(d_in=16, widths=(32,), n_out=8, rows=2)
    store, _, _ = _mlp_store(model, n_users=4, cache_size=2)
    path = str(tmp_path / "serve.jsonl")
    stream = MetricsStream(path)
    eng = ServeEngine(store, model, backend="vmap", max_batch=2,
                      metrics=stream, metrics_every=2)
    eng.serve(RequestStream(n_users=4, n_requests=16, seed=0))
    stream.close()
    lines = [json.loads(l) for l in open(path)]
    assert lines, "no metrics emitted"
    summary = lines[-1]
    assert summary["event"] == "summary"
    for key in ("p50_ms", "p99_ms", "requests_per_s", "cache_hit_rate",
                "store_bytes_at_rest"):
        assert key in summary
    assert summary["requests"] == 16
    assert any(l["event"] == "serve" for l in lines[:-1])


def test_engine_rejects_unsupported_backend():
    from repro.fl.base import make_cnn_task

    model = TaskModel(make_cnn_task("smallcnn", 10, 8, width=4), hw=8)
    store = ModelStore(model.init(jax.random.PRNGKey(0)), cache_size=2)
    with pytest.raises(ValueError, match="backend"):
        ServeEngine(store, model, backend="pallas")
