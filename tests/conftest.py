# Tests run on the single real CPU device — the 512-device dry-run env var
# is deliberately NOT set here (see launch/dryrun.py which sets it itself).
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)
