"""End-to-end behaviour of the DisPFL system (paper's headline claims at
CPU scale).

These are the directional validations of EXPERIMENTS.md §Accuracy: under a
pathological non-IID split, (i) global-consensus methods underperform
personalized ones, (ii) DisPFL reaches at least local-training quality while
(iii) moving ~half the bytes of dense decentralized training and (iv)
spending fewer training FLOPs."""
import numpy as np
import pytest

from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, run_strategy


@pytest.fixture(scope="module")
def results():
    clients, _ = build_federated_image_task(
        3, n_clients=8, partition="pathological", classes_per_client=2,
        n_train_per_class=80, n_test_per_client=40, hw=16, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 16, width=10)
    cfg = FLConfig(n_clients=8, rounds=8, local_epochs=3, batch_size=32,
                   degree=4, density=0.5, eval_every=8)
    out = {}
    for m in ("local", "fedavg", "dpsgd", "dispfl"):
        out[m] = run_strategy(m, task, clients, cfg)
    return out


def test_dispfl_beats_global_consensus(results):
    # paper Table 1 pathological: FedAvg/D-PSGD << personalized methods
    assert results["dispfl"].final_acc > results["fedavg"].final_acc + 0.1
    assert results["dispfl"].final_acc > results["dpsgd"].final_acc


def test_dispfl_at_least_local_quality(results):
    assert results["dispfl"].final_acc >= results["local"].final_acc - 0.03


def test_dispfl_halves_communication(results):
    ratio = results["dispfl"].comm_busiest_mb / results["dpsgd"].comm_busiest_mb
    assert 0.4 < ratio < 0.62, ratio


def test_dispfl_saves_flops(results):
    assert results["dispfl"].flops_per_round < results["dpsgd"].flops_per_round


def test_accuracy_above_chance(results):
    # 2 classes per client: must be far above the 10-class prior
    assert results["dispfl"].final_acc > 0.3


def test_history_recorded(results):
    for r in results.values():
        assert len(r.acc_history) >= 1
        assert all(np.isfinite(a) for a in r.acc_history)
