"""Integration test of the full dry-run pipeline (lower + compile + roofline
extraction) at smoke scale: reduced archs, tiny shapes, a 2x2(x2) host-device
test mesh.  Runs in a subprocess because the forced device count must be set
before jax initializes.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(tmp_path, arch, shape, multi_pod=False, gossip="einsum"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_DRYRUN_DEVICES"] = "8"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
           "--arch", arch, "--shape", shape, "--out", str(tmp_path),
           "--gossip", gossip]
    if multi_pod:
        cmd.append("--multi-pod")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    mesh = "testpod2x16x16" if multi_pod else "testpod16x16"
    tag = f"{arch}__{shape}__{mesh}" + (f"__{gossip}" if gossip != "einsum" else "")
    with open(os.path.join(tmp_path, tag + ".json")) as f:
        return json.load(f)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen3-8b", "train_4k"),
    ("deepseek-moe-16b", "train_4k"),
    ("mamba2-1.3b", "decode_32k"),
])
def test_smoke_dryrun_single_pod(tmp_path, arch, shape):
    rec = _run_dryrun(tmp_path, arch, shape)
    assert rec["status"] == "ok", rec
    assert rec["cost"]["flops"] > 0
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_smoke_dryrun_multi_pod_has_cross_pod_collectives(tmp_path):
    rec = _run_dryrun(tmp_path, "gemma3-1b", "train_4k", multi_pod=True)
    assert rec["status"] == "ok", rec
    # the gossip einsum over the ('pod','data') client axes must show up
    assert rec["coll_bytes_per_device"] > 0
    kinds = rec["collectives"]["counts"]
    assert any(k in kinds for k in
               ("all-gather", "all-reduce", "collective-permute", "all-to-all"))


@pytest.mark.slow
def test_smoke_dryrun_ring_gossip_uses_permute(tmp_path):
    rec = _run_dryrun(tmp_path, "gemma3-1b", "train_4k", gossip="ppermute")
    assert rec["status"] == "ok", rec
    assert rec["collectives"]["counts"].get("collective-permute", 0) > 0
