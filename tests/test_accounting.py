"""Faithful reproduction of the paper's Table 1/2 comm + FLOPs columns,
derived analytically from our actual ResNet18-GN / VGG11-GN definitions.

Paper numbers (CIFAR-10, 100 clients, busiest node = 10 connections):
    dense comm  446.9 MB  = 10 x 11.17M params x 4 B
    DisPFL comm 223.4 MB  (sparsity 0.5)
    dense FLOPs 8.3e12 / round = 500 samples x 5 epochs x 3 x fwd_flops
    DisPFL FLOPs ~7.0e12 (ERK density 0.5 is FLOPs-weighted ~0.84 because
    early conv layers have few params (dense under ERK) but most FLOPs)
    ring topology: dense 89.4 MB, DisPFL 44.6 MB
    VGG11: dense 184.6 MB at 50%  => 9.2M params
"""
import jax
import numpy as np
import pytest

from repro.core.accounting import (
    centralized_comm,
    decentralized_comm,
    sparse_training_flops,
)
from repro.core.masks import erk_densities_for_params
from repro.core.topology import fully_connected, ring, time_varying_random
from repro.models import cnn
from repro.utils.tree import tree_leaves_with_path, tree_size

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def resnet18():
    return cnn.init_resnet18(jax.random.PRNGKey(0), 10)


@pytest.fixture(scope="module")
def vgg11():
    return cnn.init_vgg11(jax.random.PRNGKey(0), 10)


def test_resnet18_param_count(resnet18):
    n = tree_size(resnet18)
    assert n == pytest.approx(11.17e6, rel=0.02), f"got {n/1e6:.2f}M"


def test_vgg11_param_count(vgg11):
    n = tree_size(vgg11)
    assert n == pytest.approx(9.2e6, rel=0.05), f"got {n/1e6:.2f}M"


def test_table1_dense_comm(resnet18):
    n = tree_size(resnet18)
    k = 100
    a = time_varying_random(k, 10, 0, seed=0)
    rep = decentralized_comm(a, [n] * k, n)
    assert rep.busiest_mb == pytest.approx(446.9, rel=0.05), rep.busiest_mb


def test_table1_dispfl_comm(resnet18):
    n = tree_size(resnet18)
    k = 100
    a = time_varying_random(k, 10, 0, seed=0)
    rep = decentralized_comm(a, [int(n * 0.5)] * k, n)
    assert rep.busiest_mb == pytest.approx(223.4, rel=0.05), rep.busiest_mb


def test_table2_ring_comm(resnet18):
    n = tree_size(resnet18)
    a = ring(100)
    dense = decentralized_comm(a, [n] * 100, n)
    sparse = decentralized_comm(a, [int(n * 0.5)] * 100, n)
    assert dense.busiest_mb == pytest.approx(89.4, rel=0.05)
    assert sparse.busiest_mb == pytest.approx(44.6, rel=0.06)


def test_table2_fc_comm(resnet18):
    n = tree_size(resnet18)
    a = fully_connected(100)
    dense = decentralized_comm(a, [n] * 100, n)
    assert dense.busiest_mb == pytest.approx(4423.9, rel=0.05)


def test_centralized_comm_matches_decentralized_budget(resnet18):
    n = tree_size(resnet18)
    rep = centralized_comm(10, [n] * 10, n)
    assert rep.busiest_mb == pytest.approx(446.9, rel=0.05)


def test_table1_dense_flops():
    fl = cnn.resnet18_fwd_flops(10, 32)
    rep = sparse_training_flops(fl, {k: 1.0 for k in fl}, n_samples=500,
                                local_epochs=5, mask_search_batches=0)
    assert rep.per_round_flops == pytest.approx(8.3e12, rel=0.07), (
        f"{rep.per_round_flops:.3e}")


def test_table1_dispfl_flops(resnet18):
    fl = cnn.resnet18_fwd_flops(10, 32)
    dens = erk_densities_for_params(resnet18, 0.5)
    # fwd_flops keys are weight-leaf paths -> map densities onto them
    rep = sparse_training_flops(fl, dens, n_samples=500, local_epochs=5,
                                mask_search_batches=1, batch_size=128)
    assert rep.per_round_flops == pytest.approx(7.0e12, rel=0.12), (
        f"{rep.per_round_flops:.3e}")
    # sparse < dense but > naive 0.5x scaling
    assert rep.per_round_flops < 8.3e12
    assert rep.per_round_flops > 0.55 * 8.3e12


def test_erk_flops_weighted_density_above_coordinate_density(resnet18):
    fl = cnn.resnet18_fwd_flops(10, 32)
    dens = erk_densities_for_params(resnet18, 0.5)
    total = sum(fl.values())
    weighted = sum(fl[k] * dens.get(k, 1.0) for k in fl) / total
    assert weighted > 0.6  # ERK makes FLOPs-heavy early layers denser


def test_flops_paths_align_with_params(resnet18):
    fl = cnn.resnet18_fwd_flops(10, 32)
    paths = {p for p, _ in tree_leaves_with_path(resnet18)}
    missing = [k for k in fl if k not in paths]
    assert not missing, missing
