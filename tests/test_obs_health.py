"""obs layer 2: time-series/histogram semantics (merge associativity,
alpha-bounded quantiles, bounded memory), fleet-health rollups reconciling
exactly with LinkStats, run manifests/archives/history, cross-run
regression attribution, the dashboard renderer, and the idempotent jax
compile-hook bridge."""
import json
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hypothesis_fallback import given, settings, st

from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, make_strategy
from repro.obs import (
    RunArchive,
    RunManifest,
    RunRegistry,
    LogHistogram,
    SeriesSet,
    TimeSeries,
    Tracer,
    append_history,
    comm_rollup,
    diff_runs,
    fleet_health,
    metric_history,
    read_history,
    save_run,
    set_tracer,
    snapshot_counters,
    spans_from_trace_doc,
    staleness_rollup,
    straggler_rollup,
    to_trace_events,
    uplink_rollup,
)
from repro.obs.health import HealthThresholds, density_drift, store_rollup
from repro.obs.series import COUNTER, snapshot_series

pytestmark = pytest.mark.tier1


@pytest.fixture()
def tracer():
    t = Tracer()
    old = set_tracer(t)
    t.enable(mode="full")
    yield t
    set_tracer(old)


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


@pytest.fixture(scope="module")
def lossy_sim_run(setup):
    """One lossy fair-uplink sim run under a full-mode tracer: the shared
    source for every reconciliation test below (spans + engine + final
    counter snapshot, all from the same process state)."""
    from repro.sim import LossModel, SimEngine

    task, clients, cfg = setup
    t = Tracer()
    old = set_tracer(t)
    t.enable(mode="full")
    try:
        sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                        local_exec="loop", mode="async", staleness=1,
                        uplink="fair",
                        loss=LossModel(0.3, timeout_s=0.05, seed=0))
        for _ in sim.rounds():
            pass
        # per-instance snapshots: the process-wide snapshot_counters() sums
        # same-key metrics across every live engine in this test session,
        # which would break the exactness assertions below
        counters = {f"sim.links/{k}": v
                    for k, v in sim.stats.obs.snapshot().items()}
        series = {"series": {f"sim.engine/{n}": d for n, d in
                             sim.sim_series.snapshot()["series"].items()}}
        yield t, sim, counters, series
    finally:
        set_tracer(old)


# ---------------------------------------------------------------------------
# LogHistogram: quantile error bound, merge algebra, bounded memory
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       scale=st.floats(min_value=0.1, max_value=100.0))
def test_histogram_quantile_within_alpha_of_exact(seed, scale):
    rng = np.random.default_rng(seed)
    xs = rng.lognormal(mean=math.log(scale), sigma=1.0, size=2000)
    h = LogHistogram(alpha=0.01)
    for x in xs:
        h.add(float(x))
    xs_sorted = np.sort(xs)
    for q in (0.01, 0.25, 0.5, 0.9, 0.99):
        exact = float(xs_sorted[int(q * (len(xs) - 1))])
        got = h.quantile(q)
        assert abs(got - exact) <= 0.0101 * exact, (q, got, exact)


def test_histogram_merge_is_associative_and_matches_bulk_add():
    rng = np.random.default_rng(7)
    parts = [rng.exponential(10 ** i, size=500) for i in range(3)]
    sketches = []
    for xs in parts:
        h = LogHistogram()
        for x in xs:
            h.add(float(x))
        sketches.append(h)
    a, b, c = sketches

    def buckets(h):
        """Everything order-independent: the float ``sum`` accumulator
        alone varies by rounding with addition order."""
        return {k: v for k, v in h.to_dict().items() if k != "sum"}

    left = LogHistogram().merge(a).merge(b).merge(c)
    bc = LogHistogram().merge(b).merge(c)
    right = LogHistogram().merge(a).merge(bc)
    assert buckets(left) == buckets(right)
    assert left.sum == pytest.approx(right.sum, rel=1e-12)

    bulk = LogHistogram()
    for xs in parts:
        for x in xs:
            bulk.add(float(x))
    # merge at the same alpha is exact: identical buckets, not just close
    assert buckets(left) == buckets(bulk)
    assert left.count == 1500 and left.sum == pytest.approx(bulk.sum)
    for q in (0.1, 0.5, 0.9, 0.99):
        assert left.quantile(q) == bulk.quantile(q) == right.quantile(q)


def test_histogram_memory_bounded_under_1e5_samples():
    rng = np.random.default_rng(0)
    h = LogHistogram(alpha=0.01, max_buckets=256)
    xs = rng.lognormal(mean=0.0, sigma=2.0, size=100_000)
    for x in xs:
        h.add(float(x))
    assert h.n_buckets <= 256
    assert h.count == 100_000
    # collapsing only the lowest buckets keeps tail quantiles honest
    exact_p99 = float(np.sort(xs)[int(0.99 * (len(xs) - 1))])
    assert abs(h.quantile(0.99) - exact_p99) <= 0.0101 * exact_p99


def test_histogram_zero_bucket_and_negative_rejection():
    h = LogHistogram()
    h.add(0.0, n=3)
    h.add(1.0)
    assert h.count == 4 and h.quantile(0.0) == 0.0
    with pytest.raises(ValueError):
        h.add(-1.0)


def test_histogram_merge_rejects_mismatched_alpha():
    with pytest.raises(ValueError):
        LogHistogram(alpha=0.01).merge(LogHistogram(alpha=0.02))


def test_histogram_roundtrip_via_dict():
    h = LogHistogram()
    for x in (0.0, 0.5, 2.0, 1e6):
        h.add(x)
    back = LogHistogram.from_dict(json.loads(json.dumps(h.to_dict())))
    assert back.to_dict() == h.to_dict()
    assert back.quantile(0.5) == h.quantile(0.5)


def test_same_grid_sketches_preserve_quantile_dominance():
    """latency = wait + service dominates wait pointwise; with one shared
    bucket grid that ordering survives into every quantile (what keeps
    the serve summary's p50_ms >= p50_wait_ms honest)."""
    rng = np.random.default_rng(3)
    waits = rng.exponential(2.0, size=800)
    services = rng.exponential(5.0, size=800)
    hw, hl = LogHistogram(), LogHistogram()
    for w, s in zip(waits, services):
        hw.add(float(w))
        hl.add(float(w + s))
    for q in np.linspace(0, 1, 21):
        assert hl.quantile(float(q)) >= hw.quantile(float(q))


# ---------------------------------------------------------------------------
# TimeSeries: counter deltas under decimation
# ---------------------------------------------------------------------------


def test_series_counter_delta_sum_survives_decimation():
    ts = TimeSeries("c", kind=COUNTER, max_points=16, initial=0.0)
    total = 0.0
    for i in range(1, 301):
        total += i
        ts.observe(float(i), total)
    assert len(ts.points()) <= 16
    assert ts.delta_sum() == pytest.approx(total)
    assert ts.last[1] == pytest.approx(total)
    # telescoping: deltas re-sum to last - initial even after decimation
    assert sum(d for _, d in ts.deltas()) == pytest.approx(total)


def test_gauge_series_rejects_deltas_and_keeps_newest():
    ts = TimeSeries("g", max_points=8)
    for i in range(100):
        ts.observe(float(i), float(i * 2))
    assert ts.last == (99.0, 198.0)
    with pytest.raises(TypeError):
        ts.deltas()


def test_series_set_snapshot_roundtrip():
    ss = SeriesSet("t.ns")
    ss.series("a", kind=COUNTER).observe(1.0, 5.0)
    ss.histogram("h").add(2.0)
    doc = snapshot_series(prefix="t.ns")
    assert "t.ns/a" in doc["series"] and "t.ns/h" in doc["histograms"]
    back = TimeSeries.from_dict(doc["series"]["t.ns/a"])
    assert back.points() == [(1.0, 5.0)] and back.kind == COUNTER


# ---------------------------------------------------------------------------
# fleet rollups reconcile exactly with LinkStats / engine accumulators
# ---------------------------------------------------------------------------


def test_comm_rollup_reconciles_bitexact_with_linkstats(lossy_sim_run):
    t, sim, counters, _ = lossy_sim_run
    comm = comm_rollup(t)
    stats = sim.stats
    n = sim.cfg.n_clients
    for k in range(n):
        assert comm["up_bytes"].get(k, 0.0) == stats.up[k]       # bit-exact
        assert comm["down_bytes"].get(k, 0.0) == stats.down[k]
        assert comm["up_wire_bytes"].get(k, 0.0) == stats.up_wire[k]
    assert comm["n_retransmits"] == stats.n_retransmits
    busiest = int(np.argmax(np.maximum(stats.up, stats.down)))
    assert comm["busiest_node"] == busiest
    assert comm["busiest_node_mb"] == pytest.approx(
        float(np.maximum(stats.up, stats.down).max()) * 1e-6)
    # and against the process-wide counter snapshot taken at run end
    assert sum(comm["up_bytes"].values()) == counters["sim.links/bytes_values"]
    assert comm["n_retransmits"] == counters["sim.links/n_retransmits"]


def test_comm_rollup_identical_from_exported_trace_doc(lossy_sim_run):
    t, _, _, _ = lossy_sim_run
    doc = json.loads(json.dumps(to_trace_events(t)))
    live, revived = comm_rollup(t), comm_rollup(doc)
    assert revived["up_bytes"] == live["up_bytes"]
    assert revived["n_retransmits"] == live["n_retransmits"]
    assert revived["link_retransmit_rate"] == live["link_retransmit_rate"]


def test_sim_series_counter_deltas_match_final_counters(lossy_sim_run):
    _, sim, counters, series = lossy_sim_run
    bv = TimeSeries.from_dict(series["series"]["sim.engine/bytes_values"])
    assert bv.delta_sum() == counters["sim.links/bytes_values"]
    assert bv.last[1] == float(sim.stats.up.sum())
    nr = TimeSeries.from_dict(series["series"]["sim.engine/n_retransmits"])
    assert nr.delta_sum() == counters["sim.links/n_retransmits"]


def test_linkstats_sketch_tracks_transfers_and_survives_restore(
        lossy_sim_run):
    _, sim, _, _ = lossy_sim_run
    stats = sim.stats
    assert stats._h_xfer_s.count == len(stats.transfers)
    # rebuilding from the restored transfer list reproduces the sketch
    from repro.sim.links import LinkStats

    clone = LinkStats(sim.cfg.n_clients)
    clone.load_state(stats.state_dict())
    assert clone._h_xfer_s.to_dict() == stats._h_xfer_s.to_dict()
    assert clone.transfer_time_quantile(0.5) == \
        stats.transfer_time_quantile(0.5)


def test_straggler_staleness_uplink_rollups(lossy_sim_run):
    t, sim, _, _ = lossy_sim_run
    strag = straggler_rollup(t)
    assert strag["n_clients"] == sim.cfg.n_clients
    assert strag["top_stragglers"][0][1] == max(strag["compute_s"].values())
    stale = staleness_rollup(t)
    assert stale["n_waits"] == stale["wait_s"].count
    up = uplink_rollup(t)
    assert up["busy_s"], "fair uplink run must record uplink.busy spans"
    for k, busy in up["busy_s"].items():
        assert 0.0 <= up["utilization"][k] <= 1.0 + 1e-9
        assert busy >= 0.0


def test_fleet_health_flags_lossy_run_and_dropped_spans(lossy_sim_run):
    t, _, counters, _ = lossy_sim_run
    roll, events = fleet_health(t, counters=counters)
    kinds = {e.kind for e in events}
    assert "link.retransmit_rate" in kinds     # 30% loss trips the 5% rule
    ev = next(e for e in events if e.kind == "link.retransmit_rate")
    assert ev.severity == "critical"           # > 2x threshold
    assert roll["comm"]["retransmit_rate"] > 0.05
    # a ring buffer that dropped spans must be surfaced, not reconciled
    _, events2 = fleet_health(t, dropped_spans=5)
    assert any(e.kind == "trace.dropped" for e in events2)


def test_fleet_health_thresholds_disable_and_store_rule():
    spans = []
    counters = {"serve.store/hits": 1, "serve.store/misses": 9}
    assert store_rollup(counters)["hit_ratio"] == pytest.approx(0.1)
    _, events = fleet_health(spans, counters=counters)
    assert any(e.kind == "store.hit_ratio" for e in events)
    _, none = fleet_health(
        spans, counters=counters,
        thresholds=HealthThresholds(min_store_hit_ratio=None))
    assert not any(e.kind == "store.hit_ratio" for e in none)


def test_density_drift_pairs_series_positionally():
    m = TimeSeries("m")
    t = TimeSeries("t")
    for i, (mv, tv) in enumerate([(0.5, 0.5), (0.45, 0.48), (0.40, 0.47)]):
        m.observe(float(i), mv)
        t.observe(float(i), tv)
    d = density_drift(m, t)
    assert d["n"] == 3
    assert d["max_drift"] == pytest.approx(0.07)
    assert d["final_drift"] == pytest.approx(0.07)
    _, events = fleet_health([], density=(m, t))
    assert any(e.kind == "density.drift" for e in events)


# ---------------------------------------------------------------------------
# run manifests, archives, history, attribution
# ---------------------------------------------------------------------------


def test_run_archive_roundtrip(tmp_path, tracer):
    from repro.obs import span
    with span("phase.a", track="x"):
        pass
    manifest = RunManifest.build("test", seed=7, config={"k": 1})
    ar = save_run(str(tmp_path / "r1"), manifest, tracer=tracer,
                  report={"ok": True})
    assert ar.exists
    m2 = ar.manifest()
    assert m2.run_id == manifest.run_id and m2.seed == 7
    assert m2.config == {"k": 1}
    assert ar.report() == {"ok": True}
    assert "phase.a" in ar.phase_summary()
    assert isinstance(ar.counters(), dict)
    reg = RunRegistry(str(tmp_path))
    assert reg.run_ids() == ["r1"]
    assert reg.latest()[0].run_dir == ar.run_dir


def test_append_and_read_history(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    rows = [{"name": "codec", "us_per_call": 10.0}]
    n = append_history(path, {"m1": rows}, sha="abc", ts=100.0)
    assert n == 2
    append_history(path, {"m1": [{"name": "codec", "us_per_call": 12.0}]},
                   sha="def", ts=200.0,
                   phase_summary_doc={"p": {"count": 1, "total_s": 2.0,
                                            "mean_s": 2.0, "max_s": 2.0}},
                   counters={"jax/backend_compiles": 3})
    mods = read_history(path, event="module")
    assert [r["git_sha"] for r in mods] == ["abc", "def"]
    runs = read_history(path, event="run")
    assert len(runs) == 2 and runs[1]["counters"] == \
        {"jax/backend_compiles": 3}
    assert metric_history(path, "m1", "codec", "us_per_call") == \
        [(100.0, 10.0), (200.0, 12.0)]
    # malformed lines are skipped, not fatal
    with open(path, "a") as f:
        f.write("not json\n")
    assert len(read_history(path)) == 4


def test_attribute_names_dominant_phase_on_injected_regression():
    old = {"phase_summary": {
        "round.local": {"count": 3, "total_s": 3.0, "mean_s": 1, "max_s": 1},
        "round.mix": {"count": 3, "total_s": 0.3, "mean_s": .1, "max_s": .1}},
        "counters": {"jax/backend_compiles": 1, "sim.links/transfers": 24}}
    new = {"phase_summary": {
        "round.local": {"count": 3, "total_s": 9.0, "mean_s": 3, "max_s": 3},
        "round.mix": {"count": 3, "total_s": 0.4, "mean_s": .1, "max_s": .2}},
        "counters": {"jax/backend_compiles": 14, "sim.links/transfers": 24}}
    d = diff_runs(old, new)
    assert d["phases"][0]["phase"] == "round.local"     # dominant |delta|
    assert d["phases"][0]["delta_s"] == pytest.approx(6.0)
    assert d["counters"][0]["counter"] == "jax/backend_compiles"
    assert all(c["counter"] != "sim.links/transfers"
               for c in d["counters"])                  # unchanged: dropped


# ---------------------------------------------------------------------------
# dashboard renderer
# ---------------------------------------------------------------------------


def test_dashboard_renders_and_checks_from_archive(tmp_path, lossy_sim_run):
    from repro.launch.dash import check_dashboard, render_dashboard

    t, _, counters, _ = lossy_sim_run
    manifest = RunManifest.build("sim", seed=0)
    # per-instance counters: other live LinkStats in a shared pytest
    # process would pollute the process-wide snapshot's sim.links/* keys
    ar = save_run(str(tmp_path / "run"), manifest, tracer=t,
                  counters=counters)
    page = render_dashboard(archive=ar)
    assert page.startswith("<!doctype html>")
    assert "<script" not in page.lower()
    assert manifest.run_id in page
    for sec in ("fleet health", "communication", "phases", "counters"):
        assert f"<h2>{sec}</h2>" in page
    # icon + label, never color alone, for tripped health rules
    assert "◆ serious" in page or "✖ critical" in page
    problems = check_dashboard(page, ar.trace(), ar.counters())
    assert problems == []


def test_dashboard_check_catches_broken_reconciliation(tmp_path,
                                                       lossy_sim_run):
    from repro.launch.dash import check_dashboard, render_dashboard

    t, _, counters, _ = lossy_sim_run
    doc = to_trace_events(t)
    # swap in the fixture's per-instance counters: the exported snapshot
    # aggregates every live LinkStats in a shared pytest process
    doc["otherData"]["counters"] = dict(counters)
    page = render_dashboard(trace_doc=doc)
    counters = dict(doc["otherData"]["counters"])
    assert check_dashboard(page, doc, counters) == []
    counters["sim.links/bytes_values"] += 1.0            # inject corruption
    assert any("reconcile" in p
               for p in check_dashboard(page, doc, counters))
    assert any("missing section" in p
               for p in check_dashboard("<!doctype html><html></html>",
                                        None, {}))


def test_diff_dashboard_renders_regression(tmp_path):
    from repro.launch.dash import render_diff

    old = {"phase_summary": {"round.local": {
        "count": 3, "total_s": 3.0, "mean_s": 1.0, "max_s": 1.0}},
        "counters": {"jax/backend_compiles": 1}}
    new = {"phase_summary": {"round.local": {
        "count": 3, "total_s": 9.0, "mean_s": 3.0, "max_s": 3.0}},
        "counters": {"jax/backend_compiles": 14}}
    page = render_diff(old, new, "old-sha", "new-sha")
    assert "round.local" in page and "▲" in page
    assert "jax/backend_compiles" in page


def test_dashboard_sparkline_svg_shape():
    from repro.launch.dash import _sparkline

    svg = _sparkline([(0.0, 1.0), (1.0, 3.0), (2.0, 2.0)])
    assert svg.startswith('<svg class="spark"')
    assert "<polyline" in svg and "<circle" in svg and "<title>" in svg
    assert _sparkline([(0.0, 1.0)]).startswith("<div")   # too few points


# ---------------------------------------------------------------------------
# idempotent jax compile hooks
# ---------------------------------------------------------------------------


def test_install_jax_hooks_idempotent():
    import jax.monitoring

    from repro.obs import counters as counters_mod

    cs1 = counters_mod.install_jax_hooks()
    cs2 = counters_mod.install_jax_hooks()
    assert cs1 is cs2
    marker = getattr(jax.monitoring, counters_mod._JAX_HOOK_ATTR)
    assert marker is cs1


def test_install_jax_hooks_survives_module_reload():
    # a module reload must rediscover the existing listener, not stack a
    # second one (double-counting every compile).  Reloading counters.py
    # re-executes it in the shared module dict, replacing the metric
    # classes process-wide — so run the reload in a subprocess rather
    # than poisoning every later test in this one.
    code = textwrap.dedent("""
        import importlib
        import jax.monitoring
        from repro.obs import counters as counters_mod

        cs1 = counters_mod.install_jax_hooks()
        n_before = len(
            jax.monitoring.get_event_duration_listeners()
            if hasattr(jax.monitoring, "get_event_duration_listeners")
            else [])
        reloaded = importlib.reload(counters_mod)
        cs3 = reloaded.install_jax_hooks()
        assert cs3 is cs1, "reload stacked a second listener set"
        assert getattr(jax.monitoring, reloaded._JAX_HOOK_ATTR) is cs1
        if hasattr(jax.monitoring, "get_event_duration_listeners"):
            n_after = len(jax.monitoring.get_event_duration_listeners())
            assert n_after == n_before, (n_before, n_after)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
