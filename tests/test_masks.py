"""ERK mask initialization: densities, budgets, personalization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: fixed-seed sampling fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.masks import (
    apply_mask,
    erk_densities_for_params,
    erk_layer_densities,
    init_client_masks,
    init_mask,
    mask_density,
)

pytestmark = pytest.mark.tier1


def _params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    return {
        "a": {"w": jax.random.normal(ks[0], (64, 128)), "b": jnp.zeros((128,))},
        "c": {"w": jax.random.normal(ks[1], (512, 256))},
        "d": {"w": jax.random.normal(ks[2], (8, 8))},
    }


def test_erk_total_density_hits_target():
    shapes = {"a": (64, 128), "b": (512, 256), "c": (8, 8)}
    for target in (0.1, 0.3, 0.5, 0.8):
        dens = erk_layer_densities(shapes, target)
        total = sum(np.prod(s) for s in shapes.values())
        nnz = sum(dens[k] * np.prod(s) for k, s in shapes.items())
        assert abs(nnz / total - target) < 1e-6


def test_erk_small_layers_denser():
    shapes = {"small": (8, 8), "big": (1024, 1024)}
    dens = erk_layer_densities(shapes, 0.3)
    assert dens["small"] > dens["big"]


@settings(max_examples=25, deadline=None)
@given(
    dims=st.lists(st.tuples(st.integers(4, 200), st.integers(4, 200)),
                  min_size=1, max_size=6),
    density=st.floats(0.05, 1.0),
)
def test_erk_property_density_and_clipping(dims, density):
    shapes = {f"l{i}": d for i, d in enumerate(dims)}
    dens = erk_layer_densities(shapes, density)
    assert all(0.0 <= v <= 1.0 for v in dens.values())
    total = sum(np.prod(s) for s in shapes.values())
    nnz = sum(dens[k] * np.prod(s) for k, s in shapes.items())
    # exact unless everything saturates at 1
    if any(v < 1.0 for v in dens.values()):
        assert nnz / total == pytest.approx(density, abs=1e-6)
    else:
        assert density >= nnz / total - 1e-6


def test_init_mask_density_and_dense_leaves():
    params = _params()
    mask = init_mask(jax.random.PRNGKey(1), params, 0.5)
    d = mask_density(mask, params)
    assert abs(d - 0.5) < 0.05
    # bias leaf stays fully dense
    assert bool(jnp.all(mask["a"]["b"] == 1))


def test_client_masks_personalized():
    params = _params()
    masks = init_client_masks(jax.random.PRNGKey(0), params, [0.5, 0.5, 0.2])
    assert mask_density(masks[2], params) < mask_density(masks[0], params)
    # two same-capacity clients still draw different masks
    diff = jnp.sum(masks[0]["c"]["w"] != masks[1]["c"]["w"])
    assert diff > 0


def test_apply_mask_zeroes():
    params = _params()
    mask = init_mask(jax.random.PRNGKey(1), params, 0.3)
    sparse = apply_mask(params, mask)
    assert bool(jnp.all(jnp.where(mask["c"]["w"] == 0,
                                  sparse["c"]["w"] == 0, True)))


def test_erk_rejects_bad_density():
    with pytest.raises(ValueError):
        erk_layer_densities({"a": (4, 4)}, 0.0)
