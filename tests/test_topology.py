"""Topology properties: degrees, self-loops, busiest-node bound, dropping."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.topology import (
    busiest_node_degree,
    fully_connected,
    make_adjacency,
    mixing_matrix,
    ring,
    time_varying_random,
)
from repro.fl.decentralized import metropolis_weights


def test_ring_degrees():
    a = ring(8)
    assert np.all(np.diag(a) == 1)
    assert busiest_node_degree(a) == 2
    assert np.all(a.sum(1) == 3)


def test_fc():
    a = fully_connected(5)
    assert busiest_node_degree(a) == 4


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 40), deg=st.integers(1, 12), r=st.integers(0, 5))
def test_random_topology_degree_bounds(n, deg, r):
    a = time_varying_random(n, deg, r, seed=1)
    assert np.all(np.diag(a) == 1)
    in_deg = a.sum(1) - 1
    out_deg = a.sum(0) - 1
    if deg < n:
        # the busiest-node constraint bounds BOTH directions (paper §4.1)
        assert np.all(in_deg <= deg) and np.all(out_deg <= deg)
        assert np.all(in_deg >= 1)
        assert busiest_node_degree(a) <= deg


def test_time_varying_changes_by_round():
    a0 = time_varying_random(20, 5, 0, seed=3)
    a1 = time_varying_random(20, 5, 1, seed=3)
    assert not np.array_equal(a0, a1)


def test_drop_prob_isolates():
    a = time_varying_random(30, 5, 0, seed=0, drop_prob=0.9)
    dropped = [k for k in range(30)
               if a[k].sum() == 1 and a[:, k].sum() == 1]
    assert len(dropped) > 10


def test_mixing_row_stochastic():
    a = make_adjacency("random", 12, 3, degree=4)
    w = mixing_matrix(a)
    assert np.allclose(w.sum(1), 1.0)


def test_metropolis_doubly_stochastic():
    a = make_adjacency("random", 10, 1, degree=3)
    w = metropolis_weights(a)
    assert np.allclose(w.sum(0), 1.0, atol=1e-9)
    assert np.allclose(w.sum(1), 1.0, atol=1e-9)
    assert np.allclose(w, w.T)
    assert np.all(w >= -1e-12)
