"""Topology properties: degrees, self-loops, busiest-node bound, dropping."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: fixed-seed sampling fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.topology import (
    busiest_node_degree,
    fully_connected,
    make_adjacency,
    mixing_matrix,
    ring,
    time_varying_random,
)
from repro.fl.decentralized import metropolis_weights

pytestmark = pytest.mark.tier1


def test_ring_degrees():
    a = ring(8)
    assert np.all(np.diag(a) == 1)
    assert busiest_node_degree(a) == 2
    assert np.all(a.sum(1) == 3)


def test_fc():
    a = fully_connected(5)
    assert busiest_node_degree(a) == 4


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 40), deg=st.integers(1, 12), r=st.integers(0, 5))
def test_random_topology_degree_bounds(n, deg, r):
    a = time_varying_random(n, deg, r, seed=1)
    assert np.all(np.diag(a) == 1)
    in_deg = a.sum(1) - 1
    out_deg = a.sum(0) - 1
    if deg < n:
        # the busiest-node constraint bounds BOTH directions (paper §4.1)
        assert np.all(in_deg <= deg) and np.all(out_deg <= deg)
        assert np.all(in_deg >= 1)
        assert busiest_node_degree(a) <= deg


def test_time_varying_changes_by_round():
    a0 = time_varying_random(20, 5, 0, seed=3)
    a1 = time_varying_random(20, 5, 1, seed=3)
    assert not np.array_equal(a0, a1)


def test_drop_prob_isolates():
    a = time_varying_random(30, 5, 0, seed=0, drop_prob=0.9)
    dropped = [k for k in range(30)
               if a[k].sum() == 1 and a[:, k].sum() == 1]
    assert len(dropped) > 10


def test_mixing_row_stochastic():
    a = make_adjacency("random", 12, 3, degree=4)
    w = mixing_matrix(a)
    assert np.allclose(w.sum(1), 1.0)


def test_metropolis_doubly_stochastic():
    a = make_adjacency("random", 10, 1, degree=3)
    w = metropolis_weights(a)
    assert np.allclose(w.sum(0), 1.0, atol=1e-9)
    assert np.allclose(w.sum(1), 1.0, atol=1e-9)
    assert np.allclose(w, w.T)
    assert np.all(w >= -1e-12)


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(["random", "ring", "fc"]),
       n=st.integers(3, 32), deg=st.integers(1, 10),
       r=st.integers(0, 4), seed=st.integers(0, 20))
def test_metropolis_doubly_stochastic_property(kind, n, deg, r, seed):
    """Double stochasticity + symmetry for every topology family."""
    a = make_adjacency(kind, n, r, degree=deg, seed=seed)
    w = metropolis_weights(a)
    assert np.allclose(w.sum(0), 1.0, atol=1e-9)
    assert np.allclose(w.sum(1), 1.0, atol=1e-9)
    assert np.allclose(w, w.T)
    assert np.all(w >= -1e-12)


def _metropolis_reference(a: np.ndarray) -> np.ndarray:
    """The seed's O(K^2) double loop, kept as the oracle."""
    sym = ((a + a.T) > 0).astype(float)
    np.fill_diagonal(sym, 0.0)
    deg = sym.sum(1)
    k = len(a)
    w = np.zeros_like(sym)
    for i in range(k):
        for j in range(k):
            if sym[i, j] > 0:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    for i in range(k):
        w[i, i] = 1.0 - w[i].sum()
    return w


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), deg=st.integers(1, 8), seed=st.integers(0, 50))
def test_metropolis_matches_reference_loop(n, deg, seed):
    a = make_adjacency("random", n, 0, degree=deg, seed=seed)
    assert np.allclose(metropolis_weights(a), _metropolis_reference(a))
