"""Partial-training strategies (dfedalt / dfedsam): smoke, partial packed
payloads, comm/FLOP accounting, simulator compatibility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accounting import decentralized_comm, message_bytes
from repro.data import build_federated_image_task
from repro.fl import (
    FLConfig,
    RoundEngine,
    make_cnn_task,
    make_strategy,
    run_strategy,
)
from repro.fl.partial import head_selector, split_masks
from repro.sparse import encoded_nbytes, unpack_tree
from repro.utils.tree import tree_nnz, tree_size

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=2, local_epochs=1, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


@pytest.mark.parametrize("name", ["dfedalt", "dfedsam"])
def test_partial_strategy_smoke(name, setup):
    task, clients, cfg = setup
    res = run_strategy(name, task, clients, cfg)
    assert len(res.final_accs) == len(clients)
    assert len(res.acc_history) == cfg.rounds
    assert all(np.isfinite(a) for a in res.final_accs)
    assert res.comm_busiest_mb > 0


def test_dfedalt_partial_payload_and_comm(setup):
    """The wire contract: dfedalt ships the shared body only — message
    nnz, the codec frame and the analytic busiest-node MB all shrink by
    the personal head's size."""
    task, clients, cfg = setup
    strat = make_strategy("dfedalt")
    state = strat.init_state(task, clients, cfg)
    n_coords = tree_size(state["params"][0])
    body_sel, head_sel = split_masks(state["params"][0])
    head_size = tree_nnz(head_sel)
    assert head_size > 0
    assert strat.message_nnz(state, 0) == n_coords - head_size
    # the packed payload's bitmap is zero on every head coordinate
    payload = strat.snapshot_message(state, 0)["packed"]
    assert encoded_nbytes(payload) == message_bytes(
        n_coords - head_size, n_coords, with_bitmap=True)
    dense = unpack_tree(payload)
    from repro.utils.tree import tree_leaves_with_path

    for path, leaf in tree_leaves_with_path(dense):
        if head_selector(path):
            assert bool(jnp.all(leaf == 0)), path
    # engine-reported comm == the analytic body-only report
    eng = RoundEngine(strat, task, clients, cfg, local_exec="loop")
    m0 = next(eng.rounds())
    ctx = eng._make_ctx(0)
    expect = decentralized_comm(
        ctx.adjacency, [n_coords - head_size] * len(clients), n_coords)
    assert m0.comm_busiest_mb == pytest.approx(expect.busiest_mb)


def test_dfedalt_heads_stay_personal(setup):
    """The mix averages bodies; each client's head is never overwritten by
    a neighbor's."""
    task, clients, cfg = setup
    strat = make_strategy("dfedalt")
    state = strat.init_state(task, clients, cfg)
    heads_before = [p["fc"]["w"] for p in state["params"]]
    ctx = RoundEngine(strat, task, clients, cfg)._make_ctx(0)
    strat.mix(state, ctx)
    for before, after in zip(heads_before, state["params"]):
        assert bool(jnp.array_equal(before, after["fc"]["w"]))
    # bodies did mix (the round-0 adjacency has edges): client 0's conv
    # weights moved away from its own init toward the neighborhood mean
    fresh = strat.init_state(task, clients, cfg)
    assert not bool(jnp.array_equal(state["params"][0]["conv0"]["w"],
                                    fresh["params"][0]["conv0"]["w"]))


def test_dfedsam_differs_from_dpsgd_and_doubles_flops(setup):
    task, clients, cfg = setup
    res_sam = run_strategy("dfedsam", task, clients, cfg)
    res_dpsgd = run_strategy("dpsgd", task, clients, cfg, local_exec="loop")
    # the SAM perturbation changes the trajectory
    eng = RoundEngine(make_strategy("dfedsam"), task, clients, cfg)
    eng2 = RoundEngine(make_strategy("dpsgd"), task, clients, cfg,
                       local_exec="loop")
    next(eng.rounds())
    next(eng2.rounds())
    same = all(bool(jnp.array_equal(x, y)) for x, y in zip(
        jax.tree.leaves(eng.state), jax.tree.leaves(eng2.state)))
    assert not same
    # SAM quotes two gradient passes per batch
    assert res_sam.flops_per_round == pytest.approx(
        2 * res_dpsgd.flops_per_round)
    # dense payloads: same wire bytes as dpsgd
    assert res_sam.comm_busiest_mb == pytest.approx(res_dpsgd.comm_busiest_mb)


def test_partial_strategies_resume_exact(setup, tmp_path):
    from repro.fl import Checkpointer

    task, clients, cfg = setup
    for name in ("dfedalt", "dfedsam"):
        path = str(tmp_path / f"{name}.npz")
        eng_a = RoundEngine(make_strategy(name), task, clients, cfg,
                            callbacks=[Checkpointer(path)])
        next(eng_a.rounds())
        eng_b = RoundEngine(make_strategy(name), task, clients, cfg)
        eng_b.restore(path)
        res_b = eng_b.run()
        eng_c = RoundEngine(make_strategy(name), task, clients, cfg)
        res_c = eng_c.run()
        assert res_b.acc_history == res_c.acc_history, name
        assert all(bool(jnp.array_equal(x, y)) for x, y in zip(
            jax.tree.leaves(eng_b.state), jax.tree.leaves(eng_c.state))), name


def test_partial_strategies_run_through_async_sim(setup):
    """Both ride the simulator via the generic payload machinery — dfedalt
    with its partial packed payload, dfedsam with dpsgd's packed mix_one."""
    from repro.sim import SimEngine

    task, clients, cfg = setup
    for name in ("dfedalt", "dfedsam"):
        eng = SimEngine(make_strategy(name), task, clients, cfg,
                        mode="async", staleness=2)
        rounds = list(eng.rounds())
        assert len(rounds) == cfg.rounds, name
        assert eng.stats.total_mb > 0, name
