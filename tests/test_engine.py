"""RoundEngine: golden equivalence vs reference loops, checkpoint/resume,
vmap fast path, registry, streaming metrics and callbacks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.evolve import cosine_prune_rate, evolve_masks, layer_nnz_budgets
from repro.core.gossip import gossip_average_one
from repro.core.masks import apply_mask, erk_densities_for_params, init_mask
from repro.core.topology import make_adjacency
from repro.fl import (
    Checkpointer,
    EarlyStopAtTarget,
    FLConfig,
    JsonlLogger,
    RoundEngine,
    make_cnn_task,
    make_strategy,
    run_strategy,
)
from repro.fl.base import evaluate_clients, local_sgd
from repro.fl.decentralized import metropolis_weights
from repro.fl.engine import StrategyBase, _pack, _unpack, derive_rng, register
from repro.data import build_federated_image_task
from repro.optim import SGDConfig

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Golden equivalence: engine-ported strategies == straight-line reference
# loops (same per-(seed, round, client) rng derivation), bit for bit.
# ---------------------------------------------------------------------------


def _reference_dispfl(task, clients, cfg):
    """DisPFL as one flat loop — the seed semantics with derived seeds."""
    k_clients = len(clients)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), 2 * k_clients)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    params = [task.init_fn(keys[k]) for k in range(k_clients)]
    densities = [erk_densities_for_params(params[k], cfg.client_density(k))
                 for k in range(k_clients)]
    masks = [init_mask(keys[k_clients + k], params[k], cfg.client_density(k))
             for k in range(k_clients)]
    budgets = [layer_nnz_budgets(params[k], densities[k])
               for k in range(k_clients)]
    params = [apply_mask(p, m) for p, m in zip(params, masks)]
    history = []
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        alpha = cosine_prune_rate(cfg.alpha0, t, cfg.rounds)
        a = make_adjacency(cfg.topology, k_clients, t, cfg.degree, cfg.seed,
                           cfg.drop_prob)
        mixed = []
        for k in range(k_clients):
            nbrs = [j for j in range(k_clients) if a[k, j] > 0 and j != k]
            mixed.append(gossip_average_one(
                params[k], masks[k],
                [params[j] for j in nbrs], [masks[j] for j in nbrs]))
        new_params, new_masks = [], []
        for k in range(k_clients):
            rng = derive_rng(cfg.seed, t, k)
            c = clients[k]
            w = local_sgd(task, mixed[k], c.train_x, c.train_y,
                          cfg.local_epochs, cfg.batch_size, lr, opt, rng,
                          mask=masks[k])
            xb, yb = c.sample_batch(rng, cfg.batch_size)
            _, g = task.value_and_grad(w, xb, yb)
            m_new, w = evolve_masks(w, masks[k], g, alpha, budgets[k])
            new_params.append(w)
            new_masks.append(m_new)
        params, masks = new_params, new_masks
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, params, clients))))
    return params, masks, history


def _reference_dpsgd(task, clients, cfg):
    k_clients = len(clients)
    opt = SGDConfig(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    w0 = task.init_fn(jax.random.PRNGKey(cfg.seed))
    params = [jax.tree.map(lambda x: x, w0) for _ in range(k_clients)]
    history = []
    for t in range(cfg.rounds):
        lr = cfg.lr_at(t)
        a = make_adjacency(cfg.topology, k_clients, t, cfg.degree, cfg.seed,
                           cfg.drop_prob)
        w_mix = metropolis_weights(a)
        mixed = []
        for k in range(k_clients):
            acc = None
            for j in range(k_clients):
                if w_mix[k, j] == 0.0:
                    continue
                contrib = jax.tree.map(lambda x: w_mix[k, j] * x, params[j])
                acc = contrib if acc is None else jax.tree.map(
                    lambda u, v: u + v, acc, contrib)
            mixed.append(acc)
        params = [
            local_sgd(task, mixed[k], clients[k].train_x, clients[k].train_y,
                      cfg.local_epochs, cfg.batch_size, lr, opt,
                      derive_rng(cfg.seed, t, k))
            for k in range(k_clients)
        ]
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            history.append(float(np.mean(evaluate_clients(task, params, clients))))
    return params, history


@pytest.mark.slow
def test_dispfl_golden_equivalence(setup):
    task, clients, cfg = setup
    ref_params, ref_masks, ref_hist = _reference_dispfl(task, clients, cfg)
    eng = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                      local_exec="loop")
    res = eng.run()
    assert res.acc_history == ref_hist
    for k in range(len(clients)):
        assert _trees_equal(eng.state["params"][k], ref_params[k])
        assert _trees_equal(eng.state["masks"][k], ref_masks[k])


def test_dpsgd_golden_equivalence(setup):
    task, clients, cfg = setup
    ref_params, ref_hist = _reference_dpsgd(task, clients, cfg)
    eng = RoundEngine(make_strategy("dpsgd"), task, clients, cfg,
                      local_exec="loop")
    res = eng.run()
    assert res.acc_history == ref_hist
    for k in range(len(clients)):
        assert _trees_equal(eng.state["params"][k], ref_params[k])


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip():
    state = {"params": [{"a": np.ones(2)}, {"a": np.zeros(2)}],
             "w": {"nested": np.arange(3)}}
    packed = _pack(state)
    out = _unpack(packed)
    assert isinstance(out["params"], list) and len(out["params"]) == 2
    assert np.array_equal(out["params"][1]["a"], np.zeros(2))
    assert np.array_equal(out["w"]["nested"], np.arange(3))


@pytest.mark.parametrize("name", ["dispfl", "fedavg"])
def test_checkpoint_resume_matches_uninterrupted(name, setup, tmp_path):
    task, clients, cfg = setup
    path = str(tmp_path / f"{name}.npz")
    # interrupted run: stop after 2 of 3 rounds, checkpointing each round
    eng_a = RoundEngine(make_strategy(name), task, clients, cfg,
                        local_exec="loop", callbacks=[Checkpointer(path)])
    it = eng_a.rounds()
    next(it)
    next(it)
    # resume into a fresh engine and finish
    eng_b = RoundEngine(make_strategy(name), task, clients, cfg,
                        local_exec="loop").restore(path)
    res_b = eng_b.run()
    # uninterrupted reference
    eng_c = RoundEngine(make_strategy(name), task, clients, cfg,
                        local_exec="loop")
    res_c = eng_c.run()
    assert res_b.acc_history == res_c.acc_history
    assert res_b.final_accs == res_c.final_accs
    assert res_b.comm_busiest_mb == pytest.approx(res_c.comm_busiest_mb)
    assert _trees_equal(eng_b.state, eng_c.state)


# ---------------------------------------------------------------------------
# vmap fast path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["dispfl", "dpsgd", "local", "fedavg"])
def test_vmap_matches_loop(name, setup):
    task, clients, cfg = setup
    res_loop = run_strategy(name, task, clients, cfg, local_exec="loop")
    res_vmap = run_strategy(name, task, clients, cfg, local_exec="vmap")
    np.testing.assert_allclose(res_vmap.final_accs, res_loop.final_accs,
                               atol=5e-2)
    np.testing.assert_allclose(res_vmap.acc_history, res_loop.acc_history,
                               atol=5e-2)


def test_vmap_handles_ragged_batch_schedules(setup):
    import dataclasses as dc
    task, clients, cfg = setup
    # trim client 0 so step counts disagree (but all n >= batch_size): the
    # stacked path must pad to max steps and mask the padded updates
    c0 = clients[0]
    ragged = [dc.replace(c0, train_x=c0.train_x[:-16], train_y=c0.train_y[:-16])]
    ragged += list(clients[1:])
    steps = {-(-c.n_train // cfg.batch_size) for c in ragged}
    assert len(steps) > 1  # genuinely ragged
    for name in ("dispfl", "dpsgd"):
        res_loop = run_strategy(name, task, ragged, cfg, local_exec="loop")
        res_vmap = run_strategy(name, task, ragged, cfg, local_exec="vmap")
        np.testing.assert_allclose(res_vmap.final_accs, res_loop.final_accs,
                                   atol=5e-2)
        np.testing.assert_allclose(res_vmap.acc_history, res_loop.acc_history,
                                   atol=5e-2)


def test_vmap_momentum_matches_loop(setup):
    # momentum rides the stacked fast path as per-client optimizer state,
    # zero-initialized each local phase exactly like the loop's init_sgd
    task, clients, _ = setup
    cfg = FLConfig(n_clients=4, rounds=2, local_epochs=2, batch_size=16,
                   degree=2, momentum=0.9, eval_every=1)
    for name in ("dispfl", "dpsgd"):
        res_loop = run_strategy(name, task, clients, cfg, local_exec="loop")
        res_vmap = run_strategy(name, task, clients, cfg, local_exec="vmap")
        np.testing.assert_allclose(res_vmap.final_accs, res_loop.final_accs,
                                   atol=5e-2)
        np.testing.assert_allclose(res_vmap.acc_history, res_loop.acc_history,
                                   atol=5e-2)


def test_auto_falls_back_on_heterogeneous(setup):
    task, clients, _ = setup
    cfg = FLConfig(n_clients=4, rounds=1, local_epochs=1, batch_size=16,
                   degree=2, capacities=[0.2, 0.4, 0.6, 0.8], eval_every=1)
    res = run_strategy("dispfl", task, clients, cfg)  # auto -> loop, no raise
    assert len(res.final_accs) == 4


# ---------------------------------------------------------------------------
# Streaming metrics, callbacks, accounting
# ---------------------------------------------------------------------------


def test_streaming_metrics_and_mean_comm(setup):
    task, clients, cfg = setup
    eng = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                      local_exec="loop")
    seen = list(eng.rounds())
    assert [m.round for m in seen] == list(range(cfg.rounds))
    assert all(m.acc_mean is not None for m in seen)  # eval_every=1
    assert all(m.comm_busiest_mb > 0 for m in seen)
    cum = [m.cum_flops for m in seen]
    assert all(b > a for a, b in zip(cum, cum[1:]))
    res = eng.result()
    # FLResult reports the MEAN over rounds of the per-round busiest-node
    # comm (time-varying adjacency), not the round-0 snapshot
    assert res.comm_busiest_mb == pytest.approx(
        np.mean([m.comm_busiest_mb for m in seen]))


def test_jsonl_logger_and_early_stop(setup, tmp_path):
    import json
    task, clients, cfg = setup
    log = str(tmp_path / "rounds.jsonl")
    eng = RoundEngine(make_strategy("local"), task, clients, cfg,
                      callbacks=[JsonlLogger(log), EarlyStopAtTarget(0.0)])
    eng.run()
    rows = [json.loads(l) for l in open(log)]
    assert len(rows) == 1  # target 0.0 stops after the first evaluated round
    assert {"round", "lr", "acc_mean", "comm_busiest_mb"} <= set(rows[0])


def test_registry_custom_strategy(setup):
    task, clients, cfg = setup

    @register("_test_noop")
    class NoopStrategy(StrategyBase):
        def init_state(self, task, clients, cfg):
            super().init_state(task, clients, cfg)
            keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(clients))
            return {"params": [task.init_fn(k) for k in keys]}

        def local_update(self, state, k, ctx):
            pass

        def round_flops(self, state, ctx):
            from repro.core.accounting import sparse_training_flops
            return sparse_training_flops(
                self.task.fwd_flops, {k: 1.0 for k in self.task.fwd_flops},
                self.n_samples, 0)

    res = run_strategy("_test_noop", task, clients, cfg)
    assert len(res.final_accs) == len(clients)
    with pytest.raises(KeyError):
        run_strategy("definitely_not_registered", task, clients, cfg)
