"""repro.sim: golden equivalence vs RoundEngine, measured bytes-on-wire vs
core.accounting, staleness invariants, availability model sharing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hypothesis_fallback import given, settings, st

from repro.core.accounting import decentralized_comm
from repro.core.topology import (
    bernoulli_alive,
    directed_out_neighbors,
    make_adjacency,
)
from repro.data import build_federated_image_task
from repro.fl import FLConfig, JsonlLogger, RoundEngine, make_cnn_task, make_strategy
from repro.sim import (
    AlwaysUp,
    BandwidthTrace,
    BernoulliAvailability,
    ComputeModel,
    EventQueue,
    LinkModel,
    LossModel,
    SimEngine,
    TraceAvailability,
    UplinkScheduler,
    hetero_speeds,
)
from repro.sim.report import time_to_target

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# substrate: events, links, availability
# ---------------------------------------------------------------------------


def test_event_queue_orders_by_time_then_insertion():
    q = EventQueue()
    q.push(2.0, "wake", k=0)
    q.push(1.0, "wake", k=1)
    q.push(1.0, "arrival", k=2)
    kinds = [(ev.time, ev.kind, ev.data["k"]) for ev in q.drain()]
    assert kinds == [(1.0, "wake", 1), (1.0, "arrival", 2), (2.0, "wake", 0)]


def test_link_transfer_time_and_skew():
    lm = LinkModel.uniform(4, mbps=100, latency_ms=10)
    # 1 MB over 100 Mbps = 0.08 s + 10 ms latency
    assert lm.transfer_time(1e6, 0, 1) == pytest.approx(0.09)
    sk = LinkModel.skewed(6, mbps=100, skew=10, slow_frac=0.5, seed=0)
    assert np.sum(np.isclose(np.diag(sk.bw_mbps), 10.0)) == 3


def test_compute_model_paced_and_hetero():
    cm = ComputeModel.paced(4, flops_round=1e9, round_s=2.0)
    assert cm.local_time(0, 1e9) == pytest.approx(2.0)
    hs = hetero_speeds(10, seed=3)
    assert sorted(set(hs.tolist())) == [0.2, 0.4, 0.6, 0.8, 1.0]
    cm2 = ComputeModel.paced(10, 1e9, 1.0, speeds=hs)
    assert max(cm2.local_time(k, 1e9) for k in range(10)) == pytest.approx(5.0)


def test_availability_shares_the_engine_drop_model():
    # sim.availability and topology drop_prob derive identical alive sets
    av = BernoulliAvailability(12, 0.4, seed=7)
    tr = TraceAvailability.from_bernoulli(12, 5, 0.4, seed=7)
    for t in range(5):
        ref = bernoulli_alive(12, t, 0.4, seed=7)
        assert np.array_equal(av.alive(t), ref)
        assert np.array_equal(tr.alive(t), ref)
        a_engine = make_adjacency("fc", 12, t, seed=7, drop_prob=0.4)
        a_avail = make_adjacency("fc", 12, t, seed=7, alive=av.alive(t))
        assert np.array_equal(a_engine, a_avail)
    dead = np.where(~av.alive(0))[0]
    assert dead.size > 0
    a = make_adjacency("fc", 12, 0, seed=7, drop_prob=0.4)
    for k in dead:
        assert a[k, k] == 1.0 and a[k].sum() == 1.0 and a[:, k].sum() == 1.0


def test_directed_out_neighbors_derived_and_bounded():
    nbrs = directed_out_neighbors(10, 3, 5, degree=4, seed=1)
    assert len(nbrs) == 4 and 3 not in nbrs
    again = directed_out_neighbors(10, 3, 5, degree=4, seed=1)
    assert np.array_equal(nbrs, again)
    assert not np.array_equal(nbrs, directed_out_neighbors(10, 3, 6, 4, 1))


# ---------------------------------------------------------------------------
# golden equivalence: sync-barrier simulator == RoundEngine, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["dispfl", "dpsgd"])
def test_sync_mode_bit_identical_to_round_engine(name, setup):
    task, clients, cfg = setup
    ref = RoundEngine(make_strategy(name), task, clients, cfg,
                      local_exec="loop")
    res_ref = ref.run()
    sim = SimEngine(make_strategy(name), task, clients, cfg,
                    local_exec="loop", mode="sync")
    res_sim = sim.run()
    assert res_sim.acc_history == res_ref.acc_history
    assert res_sim.final_accs == res_ref.final_accs
    assert _trees_equal(sim.state, ref.state)
    # and the simulator adds a strictly increasing virtual timeline
    assert sim.sim_time > 0
    assert len(sim.stats.transfers) > 0


def test_sync_mode_with_availability_matches_drop_prob(setup):
    import dataclasses
    task, clients, cfg = setup
    cfg_drop = dataclasses.replace(cfg, topology="random", drop_prob=0.4)
    ref = RoundEngine(make_strategy("dispfl"), task, clients, cfg_drop,
                      local_exec="loop")
    res_ref = ref.run()
    cfg_clean = dataclasses.replace(cfg, topology="random")
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg_clean,
                    local_exec="loop", mode="sync",
                    availability=BernoulliAvailability(4, 0.4, seed=cfg.seed))
    res_sim = sim.run()
    assert res_sim.acc_history == res_ref.acc_history
    assert _trees_equal(sim.state, ref.state)


# ---------------------------------------------------------------------------
# property: simulated bytes-on-wire == accounting totals (static topologies)
# ---------------------------------------------------------------------------


import functools


@functools.lru_cache(maxsize=1)
def _prop_setup():
    # @given hides the signature from pytest (see _hypothesis_fallback), so
    # the property test cannot take fixtures; build its tiny world here
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


@settings(max_examples=4, deadline=None)
@given(topology=st.sampled_from(["ring", "fc", "random"]),
       degree=st.integers(min_value=1, max_value=3),
       density=st.sampled_from([0.3, 0.5, 1.0]))
def test_bytes_on_wire_match_accounting(topology, degree, density):
    import dataclasses
    task, clients, cfg = _prop_setup()
    cfg = dataclasses.replace(cfg, topology=topology, degree=degree,
                              rounds=2, local_epochs=1, eval_every=2)
    strat = make_strategy("dpsgd", param_fraction=density)
    sim = SimEngine(strat, task, clients, cfg, mode="sync")
    sim.run()
    # measured transfers == the engine's own decentralized_comm accounting
    assert sim.stats.total_mb == pytest.approx(sum(sim._comm["total_mb"]))
    if topology in ("ring", "fc"):
        # static adjacency + static nnz: cumulative busiest-node traffic is
        # the per-round analytic busiest summed over rounds
        assert max(sim.stats.per_node_mb()) == pytest.approx(
            sum(sim._comm["busiest_mb"]))


def test_bytes_on_wire_dispfl_totals(setup):
    # DisPFL: per-layer nnz budgets are conserved by evolve, so measured
    # totals equal the analytic decentralized_comm sum over rounds
    task, clients, cfg = setup
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg, mode="sync")
    sim.run()
    assert sim.stats.total_mb == pytest.approx(sum(sim._comm["total_mb"]))
    nnz = [sim.strategy.message_nnz(sim.state, k) for k in range(4)]
    coords = sim.strategy.message_coords(sim.state, 0)
    expect = sum(
        decentralized_comm(sim._make_ctx(t).adjacency, nnz, coords).total_mb
        for t in range(cfg.rounds))
    assert sim.stats.total_mb == pytest.approx(expect)


# ---------------------------------------------------------------------------
# async: staleness invariants, determinism, streaming
# ---------------------------------------------------------------------------


def test_async_staleness_bound_invariant(setup):
    task, clients, cfg = setup
    for bound in (0, 1):
        sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                        mode="async", staleness=bound, round_s=1.0,
                        compute_speeds=hetero_speeds(4, seed=2))
        res = sim.run()
        assert sim.observed_spread <= bound
        assert sim.observed_mix_lag <= bound
        # the bound must not be vacuous: models do get mixed (staleness=0
        # still admits lag-0 messages, matching the sync protocol's freshness)
        assert sim.mixed_messages > 0
        assert len(res.acc_history) == cfg.rounds  # every round evaluated
        assert sim.sim_time > 0


def test_async_permanently_down_client_terminates(setup):
    task, clients, cfg = setup
    trace = np.ones((1, 4), dtype=bool)
    trace[0, 2] = False          # client 2 is down in every slot
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=1, round_s=1.0,
                    max_down_retries=5,
                    availability=TraceAvailability(trace))
    res = sim.run()              # must not hang: client 2 is declared dead
    assert len(res.acc_history) == cfg.rounds
    assert sim.mixed_messages > 0
    # everyone down forever: the run must end *partial*, not fabricate rounds
    sim2 = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                     mode="async", staleness=1, round_s=1.0,
                     max_down_retries=3,
                     availability=TraceAvailability(np.zeros((1, 4), bool)))
    res2 = sim2.run()
    assert res2.acc_history == []


def test_async_unbounded_exceeds_barrier_spread(setup):
    task, clients, cfg = setup
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=-1, round_s=1.0,
                    compute_speeds=np.array([0.2, 1.0, 1.0, 1.0]))
    sim.run()
    # a 5x-slower client must fall behind when nothing bounds staleness
    assert sim.observed_spread >= 2


def test_async_deterministic_and_streams_jsonl(setup, tmp_path):
    import json
    task, clients, cfg = setup
    runs = []
    log = str(tmp_path / "sim.jsonl")
    for _ in range(2):
        sim = SimEngine(make_strategy("dpsgd"), task, clients, cfg,
                        mode="async", staleness=1, round_s=1.0,
                        compute_speeds=hetero_speeds(4, seed=5),
                        availability=BernoulliAvailability(4, 0.2, seed=3),
                        callbacks=[JsonlLogger(log)])
        res = sim.run()
        runs.append((res.acc_history, sim.sim_time, sim.stats.total_mb))
    assert runs[0] == runs[1]
    rows = [json.loads(l) for l in open(log)]
    assert len(rows) == cfg.rounds
    assert {"round", "sim_time_s", "measured_total_mb", "acc_mean"} <= set(rows[0])
    assert rows[-1]["sim_time_s"] >= rows[0]["sim_time_s"]


def test_async_time_to_target_monotone(setup):
    task, clients, cfg = setup
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=2, round_s=1.0)
    sim.run()
    assert time_to_target(sim.acc_trace, -1.0) == sim.acc_trace[0][0]
    assert time_to_target(sim.acc_trace, 2.0) == -1.0
    rep = sim.report(targets=(0.0,))
    assert rep.sim_wall_s == pytest.approx(sim.sim_time)
    assert rep.total_mb == pytest.approx(sim.stats.total_mb)
    assert rep.n_transfers == len(sim.stats.transfers)


def test_async_rejects_global_state_and_foreign_checkpoints(setup, tmp_path):
    task, clients, cfg = setup
    sim = SimEngine(make_strategy("fedavg"), task, clients, cfg, mode="async")
    with pytest.raises(ValueError):
        list(sim.rounds())
    # a RoundEngine checkpoint carries no virtual timeline: restoring it
    # into a SimEngine would silently zero the clock -> refused
    path = str(tmp_path / "eng.npz")
    eng = RoundEngine(make_strategy("dpsgd"), task, clients, cfg)
    eng.save(path)
    with pytest.raises(ValueError, match="SimEngine checkpoint"):
        SimEngine(make_strategy("dpsgd"), task, clients, cfg,
                  mode="sync").restore(path)
    # mode mismatch: a sync checkpoint has no event-loop state to resume
    path2 = str(tmp_path / "sync.npz")
    SimEngine(make_strategy("dpsgd"), task, clients, cfg,
              mode="sync").save(path2)
    with pytest.raises(ValueError, match="mode"):
        SimEngine(make_strategy("dpsgd"), task, clients, cfg,
                  mode="async").restore(path2)
    # the superset direction is fine: RoundEngine can resume a sim archive
    RoundEngine(make_strategy("dpsgd"), task, clients, cfg).restore(path2)


# ---------------------------------------------------------------------------
# v2 substrate: shared uplinks, message loss, bandwidth traces
# ---------------------------------------------------------------------------


def test_uplink_scheduler_disciplines():
    lm = LinkModel.uniform(4, mbps=100, latency_ms=10)
    jobs = [(1, 1e6), (2, 1e6), (3, 1e6)]   # 0.08 s serialization each
    par = UplinkScheduler(4, "parallel").schedule(lm, 0, jobs, 1.0)
    assert all(s == 1.0 and e == pytest.approx(1.09) for s, e in par)
    fifo = UplinkScheduler(4, "fifo")
    got = fifo.schedule(lm, 0, jobs, 1.0)
    assert [round(e, 3) for _, e in got] == [1.09, 1.17, 1.25]
    assert fifo.free_at[0] == pytest.approx(1.24)   # busy through 3 frames
    # a later batch queues behind the busy uplink
    (s2, _e2), = fifo.schedule(lm, 0, [(1, 1e6)], 1.0)
    assert s2 == pytest.approx(1.24)
    # fair: processor sharing — equal sizes all finish at 3x one frame
    fair = UplinkScheduler(4, "fair").schedule(lm, 0, jobs, 1.0)
    assert all(e == pytest.approx(1.25) for _, e in fair)
    with pytest.raises(ValueError):
        UplinkScheduler(4, "warp")


def test_loss_model_deterministic_and_bounded():
    loss = LossModel(0.5, timeout_s=0.2, max_retries=3, seed=1)
    draws = [loss.attempts(0, 1, t) for t in range(50)]
    assert draws == [loss.attempts(0, 1, t) for t in range(50)]
    assert any(a > 1 for a, _ in draws)          # drops do happen at p=0.5
    assert all(1 <= a <= 4 for a, _ in draws)    # capped at max_retries + 1
    assert all(ok for a, ok in draws if a <= 3)  # early exit == delivered
    # p=0 short-circuits; different links draw independent streams
    assert LossModel(0.0).attempts(3, 2, 7) == (1, True)
    other = [loss.attempts(2, 3, t) for t in range(50)]
    assert other != draws


def test_bandwidth_trace_scales_transfer_time(tmp_path):
    import json
    tr = BandwidthTrace([0.0, 10.0], np.array([1.0, 0.25]))
    lm = LinkModel.uniform(2, mbps=100, latency_ms=0, trace=tr)
    assert lm.transfer_time(1e6, 0, 1, 5.0) == pytest.approx(0.08)
    assert lm.transfer_time(1e6, 0, 1, 15.0) == pytest.approx(0.32)
    # per-client rows scale the *sender's* uplink
    p = tmp_path / "trace.json"
    p.write_text(json.dumps({"times": [0.0], "scale": [[1.0, 0.5]]}))
    lm2 = LinkModel.uniform(2, mbps=100, latency_ms=0,
                            trace=BandwidthTrace.from_json(str(p)))
    assert lm2.transfer_time(1e6, 0, 1, 0.0) == pytest.approx(0.08)
    assert lm2.transfer_time(1e6, 1, 0, 0.0) == pytest.approx(0.16)
    with pytest.raises(ValueError):
        BandwidthTrace([0.0], np.array([0.0]))   # non-positive scale


def test_sync_faults_keep_state_and_stretch_clock(setup):
    # the barrier's transport is reliable: loss + uplink contention change
    # the timeline and the bytes, never the training trajectory
    task, clients, cfg = setup
    ref = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                      local_exec="loop").run()
    clean = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                      local_exec="loop", mode="sync")
    clean.run()
    faulty = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                       local_exec="loop", mode="sync", uplink="fifo",
                       loss=LossModel(0.3, timeout_s=0.05, seed=0))
    res = faulty.run()
    assert res.acc_history == ref.acc_history
    assert faulty.stats.n_retransmits > 0
    assert faulty.stats.retrans_mb > 0
    assert faulty.stats.n_lost == 0              # reliable: always delivered
    assert faulty.sim_time > clean.sim_time      # retransmits + serialization
    assert faulty.stats.total_mb > clean.stats.total_mb
    rep = faulty.report()
    assert rep.retrans_mb == pytest.approx(faulty.stats.retrans_mb)
    assert rep.n_retransmits == faulty.stats.n_retransmits


# ---------------------------------------------------------------------------
# checkpoint/resume: bit-identical to the uninterrupted run, both modes
# ---------------------------------------------------------------------------


def _strip_wall(d: dict) -> dict:
    d = dict(d)
    d.pop("wall_s")          # host wall-clock: never bit-stable
    return d


@pytest.mark.parametrize("mode,kw", [
    ("sync", {}),
    ("async", dict(staleness=1, round_s=1.0)),
    ("async", dict(staleness=2, round_s=1.0, uplink="fifo",
                   loss=LossModel(0.25, timeout_s=0.3, seed=0))),
], ids=["sync", "async", "async_faults"])
def test_checkpoint_resume_bit_identical(mode, kw, setup, tmp_path):
    task, clients, cfg = setup
    speeds = hetero_speeds(4, seed=2) if mode == "async" else None

    def build():
        return SimEngine(make_strategy("dispfl"), task, clients, cfg,
                         mode=mode, compute_speeds=speeds, **kw)

    ref = build()
    ref_metrics = [_strip_wall(m.to_dict()) for m in ref.rounds()]

    path = str(tmp_path / "sim_ck.npz")
    first = build()
    got = []
    for m in first.rounds():       # cut mid-run, checkpoint, abandon
        got.append(_strip_wall(m.to_dict()))
        if m.round == 1:
            first.save(path)
            break
    resumed = build().restore(path)
    for m in resumed.rounds():
        got.append(_strip_wall(m.to_dict()))

    assert got == ref_metrics                      # every streamed metric
    assert _trees_equal(resumed.state, ref.state)  # final params/masks
    assert resumed.clock.now == ref.clock.now      # virtual clock, exact
    assert resumed.acc_trace == ref.acc_trace
    # LinkStats: aggregates and the full transfer log
    assert np.array_equal(resumed.stats.up, ref.stats.up)
    assert np.array_equal(resumed.stats.down, ref.stats.down)
    assert np.array_equal(resumed.stats.edge_busy_s, ref.stats.edge_busy_s)
    assert resumed.stats.transfers == ref.stats.transfers
    assert resumed.stats.n_retransmits == ref.stats.n_retransmits
    assert resumed.stats.n_lost == ref.stats.n_lost
    assert np.array_equal(resumed.uplink.free_at, ref.uplink.free_at)
    assert resumed.report((0.0,)).to_dict() == ref.report((0.0,)).to_dict()


def test_async_finished_run_extends_on_resume(setup, tmp_path):
    """Resuming a *finished* async run with a larger cfg.rounds re-arms the
    retired clients' WAKE events and emits the additional rounds (the old
    behaviour was to end silently); the extension is deterministic."""
    import dataclasses

    task, clients, cfg = setup
    path = str(tmp_path / "finished.npz")

    def build(rounds):
        c = dataclasses.replace(cfg, rounds=rounds)
        return SimEngine(make_strategy("dispfl"), task, clients, c,
                         mode="async", staleness=2)

    eng = build(2)
    first = [m.round for m in eng.rounds()]
    assert first == [0, 1]
    eng.save(path)

    extended = build(4).restore(path)
    more = [m.round for m in extended.rounds()]
    assert more == [2, 3]
    assert all(int(t) == 4 for t in extended._as.t_local)
    assert len(extended._acc_history) == 4
    assert extended.clock.now > eng.clock.now

    # deterministic: a second extension from the same archive is identical
    again = build(4).restore(path)
    assert [m.round for m in again.rounds()] == more
    assert _trees_equal(again.state, extended.state)
    assert again.clock.now == extended.clock.now

    # resuming with the ORIGINAL rounds still ends immediately (no rounds
    # fabricated), and a restored-but-not-extended engine stays finished
    same = build(2).restore(path)
    assert list(same.rounds()) == []
