"""Minimal stand-in for ``hypothesis`` when it is not installed.

Implements just the surface the test suite uses — ``given`` with keyword
strategies, ``settings(max_examples=, deadline=)`` and the ``integers`` /
``floats`` / ``lists`` / ``tuples`` / ``sampled_from`` strategies — by
sampling a fixed-seed batch of examples per test.  Far weaker than real
hypothesis (no shrinking, no edge-case bias), but it keeps the property
tests running in hermetic environments; when hypothesis is importable the
real library is used instead (see the try/except at each import site).
"""
from __future__ import annotations



import numpy as np


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(lambda rng: [
        elements.sample(rng)
        for _ in range(int(rng.integers(min_size, max_size + 1)))
    ])


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


class _St:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
    sampled_from = staticmethod(sampled_from)


st = _St()

_DEFAULT_EXAMPLES = 10


def given(**strategies):
    def deco(fn):
        # deliberately no functools.wraps: pytest must see the bare
        # (*args, **kwargs) signature, not the strategy params as fixtures
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(0)
            for _ in range(wrapper._max_examples):
                example = {k: s.sample(rng) for k, s in strategies.items()}
                fn(*args, **example, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn

    return deco
