"""repro.scale: K=8 golden equivalence vs RoundEngine, stacked primitive
parity, stacked packed payload round-trips, sharding spec resolution,
checkpoint interop, and the sharded subprocess smoke."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.evolve import evolve_masks, layer_nnz_budgets
from repro.core.gossip import gossip_average_one
from repro.core.masks import erk_densities_for_params
from repro.core.topology import make_adjacency
from repro.data import build_federated_image_task
from repro.fl import (
    Checkpointer,
    FLConfig,
    RoundEngine,
    make_cnn_task,
    make_strategy,
)
from repro.fl.decentralized import metropolis_weights
from repro.scale import (
    ScaleEngine,
    fold_stacked,
    make_stacked,
    masked_gossip_stacked,
    pack_stacked,
    plain_mix_stacked,
    split_stacked,
    stack_payloads,
    stacked_evolve_exact,
    stacked_nnz_per_client,
    unpack_stacked,
)
from repro.scale.stacked import evolve_counts_for
from repro.sparse import encoded_nbytes, pack_tree
from repro.utils.tree import tree_index, tree_stack, tree_unstack

pytestmark = pytest.mark.tier1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=8, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=8, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


def _stacked_allclose(stacked, lists, atol):
    ref = tree_stack(lists)
    for x, y in zip(jax.tree.leaves(stacked), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=0)


# ---------------------------------------------------------------------------
# Golden equivalence at K=8: ScaleEngine vs RoundEngine(local_exec="loop")
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden_refs(setup):
    """Reference trajectories, computed once per strategy on demand."""
    task, clients, cfg = setup
    cache = {}

    def get(name):
        if name not in cache:
            eng = RoundEngine(make_strategy(name), task, clients, cfg,
                              local_exec="loop")
            res = eng.run()
            cache[name] = (eng, res)
        return cache[name]

    return get


@pytest.mark.parametrize("reduction", ["ordered", "einsum"])
def test_dispfl_golden_k8(setup, golden_refs, reduction):
    """The tentpole contract: masks bit-identical for both reductions;
    with the ordered fold the *whole trajectory* (params, metrics) is
    bit-identical; the einsum fold agrees to fp-reduction-order tolerance
    (documented in repro/scale/__init__.py)."""
    task, clients, cfg = setup
    ref, rres = golden_refs("dispfl")
    eng = ScaleEngine(make_strategy("dispfl"), task, clients, cfg,
                      reduction=reduction)
    eres = eng.run()
    assert _trees_equal(eng.state["masks"], tree_stack(ref.state["masks"]))
    if reduction == "ordered":
        assert _trees_equal(eng.state["params"],
                            tree_stack(ref.state["params"]))
        assert eres.acc_history == rres.acc_history
    else:
        _stacked_allclose(eng.state["params"], ref.state["params"],
                          atol=1e-5)
        np.testing.assert_allclose(eres.acc_history, rres.acc_history,
                                   atol=1e-5)
    assert eres.comm_busiest_mb == pytest.approx(rres.comm_busiest_mb)
    assert eres.flops_per_round == pytest.approx(rres.flops_per_round)


@pytest.mark.slow
def test_dispfl_anneal_golden_k8(setup, golden_refs):
    task, clients, cfg = setup
    ref, rres = golden_refs("dispfl_anneal")
    eng = ScaleEngine(make_strategy("dispfl_anneal"), task, clients, cfg,
                      reduction="ordered")
    eres = eng.run()
    assert _trees_equal(eng.state["masks"], tree_stack(ref.state["masks"]))
    assert _trees_equal(eng.state["params"], tree_stack(ref.state["params"]))
    assert eres.acc_history == rres.acc_history
    assert eres.comm_busiest_mb == pytest.approx(rres.comm_busiest_mb)
    # the annealed budgets flow through traced counts: payload nnz shrinks
    nnz = stacked_nnz_per_client(eng.state["masks"])
    init_nnz = stacked_nnz_per_client(
        tree_stack(make_strategy("dispfl_anneal").init_state(
            task, clients, cfg)["masks"]))
    assert all(a < b for a, b in zip(nnz, init_nnz))


@pytest.mark.parametrize("reduction", ["ordered", "einsum"])
def test_dpsgd_golden_k8(setup, golden_refs, reduction):
    """dpsgd has no masks; its documented golden contract is metric
    equality + params at fp-contraction tolerance (the fused stacked
    program FMA-contracts the SGD update — even the engine's own vmap path
    differs from the loop by ~1e-8 here)."""
    task, clients, cfg = setup
    ref, rres = golden_refs("dpsgd")
    eng = ScaleEngine(make_strategy("dpsgd"), task, clients, cfg,
                      reduction=reduction)
    eres = eng.run()
    _stacked_allclose(eng.state["params"], ref.state["params"], atol=1e-5)
    np.testing.assert_allclose(eres.acc_history, rres.acc_history, atol=1e-5)
    assert eres.comm_busiest_mb == pytest.approx(rres.comm_busiest_mb)


def test_scale_checkpoint_interop_with_round_engine(setup, tmp_path):
    """ScaleEngine checkpoints are written in the engine's per-client list
    layout: a run checkpointed under ScaleEngine resumes bit-identically
    under RoundEngine, and vice versa (ordered fold)."""
    task, clients, cfg = setup
    path = str(tmp_path / "scale.npz")
    eng_a = ScaleEngine(make_strategy("dispfl"), task, clients, cfg,
                        reduction="ordered", callbacks=[Checkpointer(path)])
    it = eng_a.rounds()
    next(it)
    next(it)
    # finish under RoundEngine from the ScaleEngine checkpoint
    eng_b = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                        local_exec="loop").restore(path)
    res_b = eng_b.run()
    # uninterrupted loop reference
    eng_c = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                        local_exec="loop")
    res_c = eng_c.run()
    assert res_b.acc_history == res_c.acc_history
    assert _trees_equal(eng_b.state, eng_c.state)
    # and back: resume the RoundEngine-written archive under ScaleEngine
    eng_b.save(path)
    eng_d = ScaleEngine(make_strategy("dispfl"), task, clients, cfg,
                        reduction="ordered").restore(path)
    assert eng_d._next_round == cfg.rounds
    assert _trees_equal(eng_d.state["params"],
                        tree_stack(eng_c.state["params"]))


def test_scale_engine_rejects_unsupported_configs(setup):
    task, clients, cfg = setup
    import dataclasses as dc

    with pytest.raises(KeyError, match="no stacked adapter"):
        ScaleEngine(make_strategy("fedavg"), task, clients, cfg)
    with pytest.raises(ValueError, match="homogeneous"):
        ScaleEngine(make_strategy("dispfl"), task, clients,
                    dc.replace(cfg, capacities=[0.2] * 4 + [0.8] * 4))
    with pytest.raises(ValueError, match="-FT"):
        ScaleEngine(make_strategy("dpsgd_ft"), task, clients, cfg)
    with pytest.raises(ValueError, match="param_fraction"):
        ScaleEngine(make_strategy("dpsgd", param_fraction=0.5),
                    task, clients, cfg)
    # fp16 wire payloads are a message-boundary feature; the stacked mix
    # never crosses one, so the config must refuse rather than silently
    # run (and report) the fp32 trajectory
    with pytest.raises(ValueError, match="payload_dtype"):
        ScaleEngine(make_strategy("dispfl", payload_dtype="fp16"),
                    task, clients, cfg)
    ragged = [dc.replace(clients[0], train_x=clients[0].train_x[:8],
                         train_y=clients[0].train_y[:8])] + list(clients[1:])
    with pytest.raises(ValueError, match="effective batch size"):
        ScaleEngine(make_strategy("dispfl"), task, ragged, cfg)


def test_stacked_eval_golden_equal_to_loop(setup):
    """The vmapped personalized eval replacing the per-client host loop is
    bit-equal to it — on round-0 state and on a trained trajectory, with
    ragged per-client test sets."""
    import dataclasses as dc

    from repro.fl.base import evaluate_clients, evaluate_clients_stacked

    task, clients, cfg = setup
    # make the test sets ragged so the padding + live-mask path is exercised
    ragged = [dc.replace(c, test_x=c.test_x[: len(c.test_y) - k],
                         test_y=c.test_y[: len(c.test_y) - k])
              for k, c in enumerate(clients)]
    eng = ScaleEngine(make_strategy("dispfl"), task, ragged,
                      dc.replace(cfg, rounds=2))
    loop = evaluate_clients(task, eng.adapter.eval_params(eng.state), ragged)
    stacked = evaluate_clients_stacked(
        task, eng.adapter.stacked_eval_params(eng.state), ragged)
    assert loop == stacked
    for _ in eng.rounds():
        pass
    loop = evaluate_clients(task, eng.adapter.eval_params(eng.state), ragged)
    assert eng._stacked_eval() == loop
    assert eng.result().final_accs == loop


# ---------------------------------------------------------------------------
# Stacked primitive parity (unit level)
# ---------------------------------------------------------------------------


def _random_world(k=6, density=0.5, seed=0):
    rng = np.random.default_rng(seed)
    shapes = {"conv/w": (3, 3, 2, 4), "fc": {"w": (17, 10), "b": (10,)}}

    def tree(fn):
        return {"conv/w": fn((k,) + shapes["conv/w"]),
                "fc": {"w": fn((k,) + shapes["fc"]["w"]),
                       "b": fn((k,) + shapes["fc"]["b"])}}

    w = tree(lambda s: jnp.asarray(rng.normal(size=s).astype(np.float32)))
    m = tree(lambda s: jnp.asarray((rng.random(s) < density)
                                   .astype(np.float32)))
    m["fc"]["b"] = jnp.ones_like(m["fc"]["b"])  # biases dense
    w = jax.tree.map(lambda a, b: a * b, w, m)
    return w, m


def test_masked_gossip_stacked_matches_reference_fold():
    w, m = _random_world()
    k = 6
    a = make_adjacency("random", k, 0, 3, 0)
    ref = []
    for i in range(k):
        nbrs = [j for j in range(k) if a[i, j] > 0 and j != i]
        ref.append(gossip_average_one(
            tree_index(w, i), tree_index(m, i),
            [tree_index(w, j) for j in nbrs],
            [tree_index(m, j) for j in nbrs]))
    ref = tree_stack(ref)
    adj = jnp.asarray(a, jnp.float32)
    ordered = jax.jit(
        lambda p, q: masked_gossip_stacked(p, q, adj, "ordered"))(w, m)
    assert _trees_equal(ordered, ref)   # bit-exact accumulation order
    einsum = jax.jit(
        lambda p, q: masked_gossip_stacked(p, q, adj, "einsum"))(w, m)
    for x, y in zip(jax.tree.leaves(einsum), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_plain_mix_stacked_matches_metropolis_reference():
    w, _ = _random_world(seed=3)
    k = 6
    wm = metropolis_weights(make_adjacency("random", k, 1, 2, 0))
    ref = []
    for i in range(k):
        acc = None
        for j in range(k):
            if wm[i, j] == 0.0:
                continue
            contrib = jax.tree.map(lambda x: wm[i, j] * x, tree_index(w, j))
            acc = contrib if acc is None else jax.tree.map(
                lambda u, v: u + v, acc, contrib)
        ref.append(acc)
    ref = tree_stack(ref)
    mix = jnp.asarray(wm, jnp.float32)
    # both reductions sit at fp tolerance of the eager reference: XLA
    # FMA-contracts the jitted multiply-accumulate (same reason the dpsgd
    # golden contract is tolerance-based, see test_dpsgd_golden_k8)
    for reduction in ("ordered", "einsum"):
        got = jax.jit(
            lambda p: plain_mix_stacked(p, mix, reduction))(w)
        for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6, rtol=0)


def test_stacked_evolve_exact_matches_core_evolve():
    """Batched prune/regrow with traced counts == the per-client reference
    (same argsort tie-breaks, exact counts), across several prune rates."""
    w, m = _random_world(seed=5)
    rng = np.random.default_rng(7)
    g = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape).astype(np.float32)), w)
    k = 6
    dens = erk_densities_for_params(tree_index(w, 0), 0.5)
    budgets = layer_nnz_budgets(tree_index(w, 0), dens)
    for rate in (0.0, 0.3, 0.77, 1.0):
        ref_m, ref_w = [], []
        for i in range(k):
            nm, nw = evolve_masks(tree_index(w, i), tree_index(m, i),
                                  tree_index(g, i), rate, budgets)
            ref_m.append(nm)
            ref_w.append(nw)
        counts = evolve_counts_for(budgets, rate)
        got_m, got_w = jax.jit(
            lambda p, q, r, c: stacked_evolve_exact(p, q, r, c))(
                w, m, g, counts)
        assert _trees_equal(got_m, tree_stack(ref_m)), rate
        assert _trees_equal(got_w, tree_stack(ref_w)), rate


# ---------------------------------------------------------------------------
# Stacked packed payloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [None, np.float16])
def test_pack_stacked_roundtrip(dtype):
    w, m = _random_world(seed=11)
    sp = pack_stacked(w, m, dtype=dtype)
    dense = unpack_stacked(sp)
    ref = jax.tree.map(lambda a, b: (a * b).astype(dtype or a.dtype), w, m)
    assert _trees_equal(dense, ref)
    # dense packing: all-ones bitmaps, full nnz
    sp_dense = pack_stacked(w, None)
    assert _trees_equal(unpack_stacked(sp_dense), w)


def test_split_stack_payloads_roundtrip_and_codec():
    w, m = _random_world(seed=13)
    sp = pack_stacked(w, m)
    parts = split_stacked(sp)
    assert len(parts) == 6
    # each split payload is codec-framable and equals the direct pack
    for i, part in enumerate(parts):
        direct = pack_tree(tree_index(w, i), tree_index(m, i))
        assert encoded_nbytes(part) == encoded_nbytes(direct)
        assert _trees_equal(
            jax.tree.leaves(part), jax.tree.leaves(direct))
    sp2 = stack_payloads(parts)
    assert _trees_equal(jax.tree.leaves(sp), jax.tree.leaves(sp2))


@pytest.mark.parametrize("backend", ["ref", "pallas", "pallas_rows"])
def test_fold_stacked_backends_agree(backend):
    w, m = _random_world(seed=17)
    sp = pack_stacked(w, m)
    num = jax.tree.map(jnp.zeros_like, w)
    den = jax.tree.map(jnp.zeros_like, w)
    n2, d2 = fold_stacked(num, den, sp, 1.0, backend=backend)
    assert _trees_equal(n2, jax.tree.map(lambda a, b: a * b, w, m))
    assert _trees_equal(d2, m)


# ---------------------------------------------------------------------------
# Sharding specs resolve on the test meshes
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape, axes):
        self.shape = dict(zip(axes, shape))
        self.axis_names = tuple(axes)


MESH_2X2 = _FakeMesh((2, 2), ("data", "model"))
MESH_PODS = _FakeMesh((2, 2, 2), ("pod", "data", "model"))


def test_stacked_spec_resolves_on_test_meshes():
    from repro.sharding.rules import stacked_spec

    # K=8 divides both client-axis products
    assert stacked_spec((8, 3, 3, 2, 4), MESH_2X2)[0] == ("data",)
    assert stacked_spec((8, 10), MESH_PODS)[0] == ("pod", "data")
    # K=2 on the pods mesh: ('pod','data') product 4 doesn't divide 2 ->
    # trimmed to ('pod',)
    assert stacked_spec((2, 10), MESH_PODS)[0] == ("pod",)
    # K=1 stays unsharded
    assert stacked_spec((1, 10), MESH_2X2)[0] is None
    # body dims never shard in the stacked layout
    for spec in (stacked_spec((8, 64, 64), MESH_2X2),
                 stacked_spec((8, 64, 64), MESH_PODS)):
        assert all(s is None for s in spec[1:])


def test_param_and_batch_specs_resolve_on_test_meshes():
    from repro.sharding.rules import batch_spec, param_spec

    # a stacked matmul weight: client axes lead, 'model' on the out dim
    spec = param_spec("blocks/attn/wq/w", (8, 4, 128, 128), MESH_2X2,
                      fsdp2d=False)
    assert spec[0] == ("data",)
    assert spec[-1] == "model"
    spec = param_spec("blocks/attn/wq/w", (8, 4, 128, 128), MESH_PODS,
                      fsdp2d=False)
    assert spec[0] == ("pod", "data")
    # replicated leaves stay replicated
    spec = param_spec("blocks/norm/scale", (8, 4, 128), MESH_2X2, False)
    assert all(s is None for s in spec[1:])
    b = batch_spec("tokens", (8, 2, 32), MESH_PODS)
    assert b[0] == ("pod", "data")


def test_scale_engine_sharded_subprocess():
    """K=8 over a 4-host-device mesh through the launcher (the forced
    device count must precede jax init, hence the subprocess), checked
    against the unsharded ScaleEngine run for identical accuracy."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    code = """
import json
from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, make_strategy
from repro.launch.mesh import make_test_mesh
from repro.scale import ScaleEngine

clients, _ = build_federated_image_task(
    0, n_clients=8, partition="pathological", classes_per_client=2,
    n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
task = make_cnn_task("smallcnn", 10, 8, width=4)
cfg = FLConfig(n_clients=8, rounds=2, local_epochs=1, batch_size=16,
               degree=2, eval_every=1)
accs = {}
for label, mesh in (("meshed", make_test_mesh(data=4, model=1)),
                    ("single", None)):
    eng = ScaleEngine(make_strategy("dispfl"), task, clients, cfg, mesh=mesh)
    accs[label] = eng.run().acc_history
print(json.dumps(accs))
"""
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    import json
    accs = json.loads(r.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(accs["meshed"], accs["single"], atol=1e-5)
