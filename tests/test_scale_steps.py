"""Single-device functional tests of the mesh-scale step builders: the same
code the dry-run lowers, executed concretely at smoke size (K clients
stacked on one CPU device, no mesh).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, SMOKE_ARCHS
from repro.launch import steps as steps_mod
from repro.models import bind
from repro.utils.tree import tree_stack


class _FakeMesh:
    shape = {"data": 1, "model": 1}
    axis_names = ("data", "model")


def _plan(cfg, k=2, b=2, s=32, mode="train"):
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=s,
                                global_batch=k * b)
    if mode != "train":
        shape = dataclasses.replace(
            INPUT_SHAPES["decode_32k" if mode == "decode" else "prefill_32k"],
            seq_len=s, global_batch=k * b)
    return steps_mod.ScalePlan(arch=cfg, shape=shape, mesh=_FakeMesh(),
                               n_clients=k, per_client_batch=b, fsdp2d=False,
                               seq_data=False, dtype=jnp.float32)


def _stacked_state(api, cfg, k):
    keys = jax.random.split(jax.random.PRNGKey(0), k)
    params = tree_stack([api.init(kk) for kk in keys])
    masks = jax.tree.map(
        lambda x: (jax.random.uniform(jax.random.PRNGKey(1), x.shape) < 0.5)
        .astype(jnp.int8) if x.ndim >= 3 else jnp.ones(x.shape, jnp.int8),
        params)
    params = jax.tree.map(lambda w, m: w * m.astype(w.dtype), params, masks)
    return params, masks


def _batch(cfg, k, b, s, key=3):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    return {"tokens": jax.random.randint(ks[0], (k, b, s), 0, cfg.vocab),
            "labels": jax.random.randint(ks[1], (k, b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("gossip", ["einsum", "einsum_bf16", "ppermute", "none"])
def test_train_step_runs_and_respects_masks(gossip):
    cfg = SMOKE_ARCHS["qwen3-8b"]
    api = bind(cfg, remat=False)
    k, b, s = 3, 2, 16
    plan = _plan(cfg, k, b, s)
    params, masks = _stacked_state(api, cfg, k)
    batch = _batch(cfg, k, b, s)
    adj = jnp.asarray(np.ones((k, k), np.float32))
    step = jax.jit(steps_mod.make_train_step(api, plan, gossip))
    new_params, losses = step(params, masks, batch, adj, jnp.float32(0.01))
    assert losses.shape == (k,)
    assert np.isfinite(np.asarray(losses)).all()
    # dormant coordinates stay exactly zero after gossip + update
    for w, m in zip(jax.tree.leaves(new_params), jax.tree.leaves(masks)):
        if w.ndim >= 3:
            assert bool(jnp.all(jnp.where(m == 0, w == 0, True)))


def test_einsum_and_ppermute_agree_on_ring():
    """ppermute gossip == einsum gossip with the ring adjacency."""
    cfg = SMOKE_ARCHS["gemma-2b"]
    api = bind(cfg, remat=False)
    k, b, s = 4, 1, 8
    plan = _plan(cfg, k, b, s)
    params, masks = _stacked_state(api, cfg, k)
    from repro.core.topology import ring
    adj = jnp.asarray(ring(k).astype(np.float32))
    from repro.launch.gossip_opt import ppermute_gossip

    def einsum_mix(w, m):
        a = adj.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        wf = w.astype(jnp.float32) * mf
        num = jnp.einsum("kj,j...->k...", a, wf)
        den = jnp.einsum("kj,j...->k...", a, mf)
        return ((num / jnp.maximum(den, 1.0)) * mf).astype(w.dtype)

    ref = jax.tree.map(einsum_mix, params, masks)
    out = ppermute_gossip(params, masks, plan, degree=2)
    for r, o in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(r, np.float32),
                                   np.asarray(o, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_mask_update_step_preserves_budget():
    cfg = SMOKE_ARCHS["qwen3-8b"]
    api = bind(cfg, remat=False)
    k, b, s = 2, 2, 16
    plan = _plan(cfg, k, b, s)
    params, masks = _stacked_state(api, cfg, k)
    batch = _batch(cfg, k, b, s)
    rate = 0.3
    step = jax.jit(steps_mod.make_mask_update_step(api, plan, density=0.5))
    new_params, new_masks = step(params, masks, batch, jnp.float32(rate))
    for m0, m1, w1 in zip(jax.tree.leaves(masks), jax.tree.leaves(new_masks),
                          jax.tree.leaves(new_params)):
        if m0.ndim >= 3 and m0.shape[-1] >= 64 and m0.shape[-2] >= 64:
            k_ = m0.shape[0]
            after = np.asarray(m1.reshape(k_, -1).sum(1))
            n = m0.reshape(k_, -1).shape[1]
            # upper budget: never exceeds density*n (+ threshold-tie drift);
            # lower: pruning removes at most rate*budget, and regrowth may
            # legitimately underfill on sparse-gradient leaves (untied
            # embedding tables only see the input-scatter gradient)
            assert np.all(after <= 0.5 * n + max(8, 0.02 * n))
            assert np.all(after >= 0.5 * n * (1 - rate) - max(8, 0.02 * n))
            assert bool(jnp.all(jnp.where(m1 == 0, w1 == 0, True)))


def test_decode_step_emits_tokens():
    cfg = SMOKE_ARCHS["mamba2-1.3b"]
    api = bind(cfg, remat=False)
    k, b = 2, 2
    plan = _plan(cfg, k, b, 32, mode="decode")
    params, _ = _stacked_state(api, cfg, k)
    cache = jax.vmap(lambda _: api.init_cache(b, 32))(jnp.arange(k))
    batch = {"tokens": jnp.zeros((k, b, 1), jnp.int32),
             "pos": jnp.zeros((k,), jnp.int32)}
    step = jax.jit(steps_mod.make_decode_step(api, plan))
    tok, cache = step(params, batch, cache)
    assert tok.shape == (k, b)
    assert tok.dtype == jnp.int32
