"""Behavioural tests of the FL strategy zoo on a fast synthetic non-IID task."""
import numpy as np
import pytest

from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, run_strategy, STRATEGIES

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=6, partition="pathological", classes_per_client=2,
        n_train_per_class=40, n_test_per_client=30, hw=16, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 16, width=8)
    cfg = FLConfig(n_clients=6, rounds=3, local_epochs=2, batch_size=32,
                   degree=3, eval_every=3)
    return task, clients, cfg


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_strategy_runs_and_reports(name, setup):
    task, clients, cfg = setup
    res = run_strategy(name, task, clients, cfg)
    assert len(res.final_accs) == len(clients)
    assert all(0.0 <= a <= 1.0 for a in res.final_accs)
    assert res.acc_history, "history must be recorded"
    assert np.isfinite(res.flops_per_round)


def test_dispfl_personalization_beats_random(setup):
    task, clients, _ = setup
    cfg = FLConfig(n_clients=6, rounds=6, local_epochs=3, batch_size=32,
                   degree=3, eval_every=6)
    res = run_strategy("dispfl", task, clients, cfg)
    # pathological 2-class clients: random guess = ~0.5 within the 2 local
    # classes only if degenerate; global random = 0.1
    assert res.final_acc > 0.35, res.final_acc


def test_dispfl_comm_half_of_dpsgd(setup):
    task, clients, cfg = setup
    r_sparse = run_strategy("dispfl", task, clients, cfg)
    r_dense = run_strategy("dpsgd", task, clients, cfg)
    ratio = r_sparse.comm_busiest_mb / r_dense.comm_busiest_mb
    assert 0.4 < ratio < 0.62, ratio  # density 0.5 (+ dense norm/bias leaves)


def test_dispfl_flops_below_dense(setup):
    task, clients, cfg = setup
    r_sparse = run_strategy("dispfl", task, clients, cfg)
    r_dense = run_strategy("dpsgd", task, clients, cfg)
    assert r_sparse.flops_per_round < r_dense.flops_per_round


def test_heterogeneous_capacities(setup):
    task, clients, _ = setup
    cfg = FLConfig(n_clients=6, rounds=2, local_epochs=1, batch_size=32,
                   degree=3, eval_every=2,
                   capacities=[0.2, 0.4, 0.6, 0.8, 1.0, 0.5])
    res = run_strategy("dispfl", task, clients, cfg)
    assert len(res.final_accs) == 6


def test_client_dropping_still_trains(setup):
    task, clients, _ = setup
    cfg = FLConfig(n_clients=6, rounds=2, local_epochs=1, batch_size=32,
                   degree=3, drop_prob=0.5, eval_every=2)
    res = run_strategy("dispfl", task, clients, cfg)
    assert res.acc_history


def test_ring_comm_cheaper_than_dynamic(setup):
    task, clients, _ = setup
    base = dict(n_clients=6, rounds=2, local_epochs=1, batch_size=32,
                eval_every=2)
    r_ring = run_strategy("dispfl", task, clients,
                          FLConfig(topology="ring", degree=5, **base))
    r_dyn = run_strategy("dispfl", task, clients,
                         FLConfig(topology="random", degree=5, **base))
    assert r_ring.comm_busiest_mb < r_dyn.comm_busiest_mb
