"""Intersection-weighted gossip: hand cases, properties, stacked-vs-single
consistency, Pallas kernel agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: fixed-seed sampling fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.gossip import (
    gossip_average_one,
    gossip_average_stacked,
    plain_gossip_stacked,
)
from repro.core.topology import fully_connected, mixing_matrix
from repro.kernels import ops as kops

pytestmark = pytest.mark.tier1


def test_hand_example():
    # two clients, one coordinate held by both, one held by self only
    w_own = {"w": jnp.array([2.0, 4.0])}
    m_own = {"w": jnp.array([1.0, 1.0])}
    w_nb = {"w": jnp.array([6.0, 0.0])}
    m_nb = {"w": jnp.array([1.0, 0.0])}
    out = gossip_average_one(w_own, m_own, [w_nb], [m_nb])
    np.testing.assert_allclose(out["w"], [4.0, 4.0])  # (2+6)/2, 4/1


def test_respects_own_mask():
    w_own = {"w": jnp.array([0.0, 0.0])}
    m_own = {"w": jnp.array([0.0, 1.0])}
    w_nb = {"w": jnp.array([5.0, 5.0])}
    m_nb = {"w": jnp.array([1.0, 1.0])}
    out = gossip_average_one(w_own, m_own, [w_nb], [m_nb])
    np.testing.assert_allclose(out["w"], [0.0, 2.5])


@settings(max_examples=15, deadline=None)
@given(k=st.integers(2, 6), n=st.integers(1, 64), seed=st.integers(0, 99))
def test_all_dense_masks_reduce_to_plain_average(k, n, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n))
    m = jnp.ones((k, n))
    a = jnp.asarray(fully_connected(k))
    out = gossip_average_stacked({"w": w}, {"w": m}, a)
    expected = plain_gossip_stacked({"w": w}, jnp.asarray(mixing_matrix(np.array(a))))
    np.testing.assert_allclose(out["w"], expected["w"], rtol=1e-4, atol=1e-6)


def test_stacked_matches_single():
    key = jax.random.PRNGKey(0)
    k, n = 4, 37
    w = jax.random.normal(key, (k, n))
    m = (jax.random.uniform(jax.random.PRNGKey(1), (k, n)) < 0.6).astype(jnp.float32)
    w = w * m
    a = np.eye(k)
    a[0, 2] = a[0, 3] = 1.0  # client 0 hears 2 and 3
    out = gossip_average_stacked({"w": w}, {"w": m}, jnp.asarray(a))
    single = gossip_average_one(
        {"w": w[0]}, {"w": m[0]},
        [{"w": w[2]}, {"w": w[3]}], [{"w": m[2]}, {"w": m[3]}])
    np.testing.assert_allclose(out["w"][0], single["w"], rtol=1e-5)


def test_density_preserved():
    key = jax.random.PRNGKey(0)
    k, n = 5, 200
    m = (jax.random.uniform(key, (k, n)) < 0.5).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * m
    a = jnp.asarray(fully_connected(k))
    out = gossip_average_stacked({"w": w}, {"w": m}, a)
    assert bool(jnp.all((out["w"] != 0) <= (m > 0)))


def test_kernel_matches_reference_tree():
    key = jax.random.PRNGKey(2)
    tree = {"a": jax.random.normal(key, (33, 17)),
            "b": jax.random.normal(key, (9,))}
    masks = jax.tree.map(
        lambda x: (jax.random.uniform(jax.random.PRNGKey(3), x.shape) < 0.5)
        .astype(jnp.float32), tree)
    trees = [jax.tree.map(lambda x: x * (i + 1), tree) for i in range(3)]
    trees = [jax.tree.map(jnp.multiply, t, masks) for t in trees]
    ref = gossip_average_one(trees[0], masks, trees[1:], [masks, masks])
    out = kops.gossip_avg_tree(trees, [masks] * 3, masks)
    for r, o in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(r, o, rtol=1e-5)
