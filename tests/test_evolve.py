"""Mask search (Alg. 2): budget preservation, prune/regrow selection,
cosine annealing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: fixed-seed sampling fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.evolve import (
    cosine_prune_rate,
    evolve_mask_layer,
    evolve_masks,
    layer_nnz_budgets,
)
from repro.core.masks import erk_densities_for_params, init_mask, apply_mask

pytestmark = pytest.mark.tier1


def test_cosine_annealing_endpoints():
    assert cosine_prune_rate(0.5, 0, 100) == pytest.approx(0.5)
    assert cosine_prune_rate(0.5, 100, 100) == pytest.approx(0.0, abs=1e-9)
    assert cosine_prune_rate(0.5, 50, 100) == pytest.approx(0.25)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(n=st.integers(32, 400), rate=st.floats(0.0, 0.9), seed=st.integers(0, 50))
def test_nnz_budget_preserved(n, rate, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n,))
    m = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,)) < 0.5).astype(jnp.float32)
    n_active = int(jnp.sum(m))
    w = w * m
    g = jax.random.normal(jax.random.PRNGKey(seed + 2), (n,))
    nm, nw = evolve_mask_layer(w, m, g, rate, n_active)
    assert int(jnp.sum(nm)) == n_active
    # pruned coordinates have zero weight
    assert bool(jnp.all(jnp.where(nm == 0, nw == 0, True)))


def test_prunes_smallest_and_grows_largest():
    w = jnp.array([0.01, 5.0, 0.02, 4.0, 0.0, 0.0])
    m = jnp.array([1.0, 1.0, 1.0, 1.0, 0.0, 0.0])
    g = jnp.array([0.0, 0.0, 0.0, 0.0, 9.0, 0.1])
    nm, nw = evolve_mask_layer(w, m, g, 0.5, 4)  # prune 2, regrow 2
    np.testing.assert_array_equal(np.asarray(nm), [0, 1, 0, 1, 1, 1])
    # regrown enter at zero (warm-started by next gossip)
    assert float(nw[4]) == 0.0


def test_evolve_masks_tree_only_touches_sparsifiable():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (32, 32)), "b": jnp.ones((32,))}
    densities = erk_densities_for_params(params, 0.5)
    mask = init_mask(key, params, 0.5)
    params = apply_mask(params, mask)
    budgets = layer_nnz_budgets(params, densities)
    g = {"w": jax.random.normal(key, (32, 32)), "b": jnp.zeros((32,))}
    nm, npar = evolve_masks(params, mask, g, 0.3, budgets)
    assert bool(jnp.all(nm["b"] == 1))
    np.testing.assert_array_equal(np.asarray(npar["b"]), np.ones(32))
    assert int(jnp.sum(nm["w"])) == budgets["w"]


def test_zero_rate_is_identity():
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (64,))
    m = (jax.random.uniform(key, (64,)) < 0.4).astype(jnp.float32)
    w = w * m + m * 1e-3  # ensure no zero-valued active weights
    g = jax.random.normal(jax.random.PRNGKey(5), (64,))
    nm, nw = evolve_mask_layer(w, m, g, 0.0, int(jnp.sum(m)))
    np.testing.assert_array_equal(np.asarray(nm), np.asarray(m))
