"""Roofline math + HLO parsing unit tests (no devices needed)."""
import pytest

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    active_params,
    build_report,
    model_flops,
    total_params,
)


def test_total_params_match_assignments():
    # sanity vs the public parameter counts (loose: our defs are faithful
    # but tokenizer/tying details shift a few percent)
    expect = {
        "qwen3-8b": (7.0e9, 9.5e9),
        "starcoder2-7b": (6.5e9, 8.5e9),
        "llava-next-mistral-7b": (6.5e9, 8.0e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "qwen3-moe-30b-a3b": (27e9, 33e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
        "gemma-2b": (2.0e9, 3.0e9),
        "gemma3-1b": (0.9e9, 1.4e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
    }
    for name, (lo, hi) in expect.items():
        n = total_params(ARCHS[name])
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_below_total():
    for name in ("deepseek-moe-16b", "qwen3-moe-30b-a3b", "jamba-1.5-large-398b"):
        cfg = ARCHS[name]
        assert active_params(cfg) < 0.5 * total_params(cfg), name


def test_qwen3_moe_active_about_3b():
    n = active_params(ARCHS["qwen3-moe-30b-a3b"])
    assert 2.0e9 < n < 4.5e9, n / 1e9


def test_model_flops_modes():
    cfg = ARCHS["qwen3-8b"]
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * active_params(cfg) * 256 * 4096)
    assert pf == pytest.approx(2 * active_params(cfg) * 32 * 32768)
    assert dc == pytest.approx(2 * active_params(cfg) * 128)


def test_build_report_terms_and_bottleneck():
    cfg = ARCHS["qwen3-8b"]
    shape = INPUT_SHAPES["train_4k"]
    cost = {"flops": 1e13, "bytes accessed": 1e12}
    rep = build_report(cfg, shape, "pod16x16", 256, cost, 5e10)
    assert rep.compute_s == pytest.approx(1e13 / PEAK_FLOPS)
    assert rep.memory_s == pytest.approx(1e12 / HBM_BW)
    assert rep.collective_s == pytest.approx(5e10 / ICI_BW)
    assert rep.bottleneck == "memory"
    assert 0 < rep.mfu <= 1.5
