"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
ref.py, executed with interpret=True (kernel bodies run on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gossip_avg import gossip_avg_flat
from repro.kernels.masked_matmul import block_mask_from_mask


@pytest.mark.parametrize("j", [1, 3, 7])
@pytest.mark.parametrize("n", [128, 1000, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_kernel_sweep(j, n, dtype):
    key = jax.random.PRNGKey(j * 100 + n)
    ks = jax.random.split(key, 3)
    m = (jax.random.uniform(ks[0], (j, n)) < 0.5).astype(dtype)
    w = (jax.random.normal(ks[1], (j, n)) * m.astype(jnp.float32)).astype(dtype)
    own = m[0]
    out = gossip_avg_flat(w, m, own)
    exp = ref.gossip_avg_ref(w, m, own)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", [(64, 128, 128), (128, 256, 128),
                                   (70, 200, 90), (13, 50, 17)])
@pytest.mark.parametrize("density", [0.0, 0.2, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_matmul_sweep(shape, density, dtype):
    m_dim, k_dim, n_dim = shape
    key = jax.random.PRNGKey(m_dim + k_dim)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (m_dim, k_dim)).astype(dtype)
    w = jax.random.normal(ks[1], (k_dim, n_dim)).astype(dtype)
    mask = (jax.random.uniform(ks[2], (k_dim, n_dim)) < density).astype(jnp.float32)
    y = ops.masked_matmul(x, w, mask, bm=32, bn=64, bk=64)
    exp = ref.masked_matmul_ref(x, w, mask)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(exp, np.float32), atol=tol * k_dim ** 0.5,
                               rtol=tol)


def test_block_mask_occupancy():
    mask = jnp.zeros((256, 256)).at[0, 0].set(1.0).at[200, 200].set(1.0)
    bm = block_mask_from_mask(mask, 128, 128)
    np.testing.assert_array_equal(np.asarray(bm), [[1, 0], [0, 1]])
    assert ops.block_occupancy(mask, 128, 128) == pytest.approx(0.5)


def test_masked_matmul_skips_equal_dense():
    """Zero blocks contribute exactly nothing (skip path == masked math)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    mask = jnp.zeros((256, 128)).at[:128, :].set(1.0)  # half the K blocks dead
    y = ops.masked_matmul(x, w, mask, bm=64, bn=128, bk=128)
    exp = x[:, :128] @ w[:128, :]
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp), rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("n", [256, 1000, 4096])
@pytest.mark.parametrize("rate", [0.1, 0.5])
def test_prune_regrow_sweep(n, rate):
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 3)
    m = (jax.random.uniform(ks[0], (n,)) < 0.5).astype(jnp.float32)
    w = jax.random.normal(ks[1], (n,)) * m
    g = jax.random.normal(ks[2], (n,))
    nm, nw = ops.prune_regrow(w, g, m, rate)
    # density approximately preserved (threshold ties may drift by a few)
    assert abs(float(nm.sum()) - float(m.sum())) <= max(4, 0.02 * n)
    assert bool(jnp.all(jnp.where(nm == 0, nw == 0, True)))
    # kernel agrees with its threshold oracle
    n_active = int(m.sum())
    import math
    n_prune = math.ceil(rate * n_active)
    keep_scores = jnp.where(m > 0, jnp.abs(w), -jnp.inf)
    w_th = jnp.sort(keep_scores)[::-1][max(n_active - n_prune - 1, 0)]
    grow_scores = jnp.where(m > 0, -jnp.inf, jnp.abs(g))
    g_th = jnp.sort(grow_scores)[::-1][max(n_prune - 1, 0)]
    em, ew = ref.prune_regrow_ref(w, g, m, w_th, g_th)
    np.testing.assert_array_equal(np.asarray(nm), np.asarray(em))
    np.testing.assert_allclose(np.asarray(nw), np.asarray(ew))
