"""repro.obs: span recording semantics, counter registry, Perfetto export
validity/determinism, engine instrumentation (sim virtual spans reconciling
bit-for-bit with LinkStats, the ScaleEngine recompile guard, store/serve
counters), the MetricsStream fixes, and the --trace CLI smokes."""
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.data import build_federated_image_task
from repro.fl import FLConfig, make_cnn_task, make_strategy
from repro.obs import (
    Counter,
    CounterSet,
    Gauge,
    Tracer,
    VIRTUAL,
    WALL,
    phase_summary,
    set_tracer,
    snapshot_counters,
    span,
    to_trace_events,
    validate_trace,
    write_trace,
)
from repro.obs.trace import _NULL

pytestmark = pytest.mark.tier1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture()
def tracer():
    """A private enabled tracer installed as the process default, so no
    test leaks spans into (or out of) the shared tracer."""
    t = Tracer()
    old = set_tracer(t)
    t.enable(mode="full")
    yield t
    set_tracer(old)


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_span_nesting_records_both_and_attrs_mutable(tracer):
    with span("outer", track="t", a=1) as outer:
        with span("inner", track="t"):
            time.sleep(0.001)
        outer.attrs["b"] = 2          # annotate a result computed inside
    spans = tracer.spans()
    assert [s.name for s in spans] == ["inner", "outer"]   # close order
    inner, outer = spans
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1   # nesting
    assert outer.attrs == {"a": 1, "b": 2}
    assert all(s.dur >= 0 and s.clock == WALL for s in spans)
    assert [s.seq for s in spans] == [0, 1]


def test_disabled_tracer_is_shared_noop():
    t = Tracer()
    old = set_tracer(t)
    try:
        assert span("a") is span("b") is _NULL    # no per-call allocation
        with span("x", track="y") as s:
            s.attrs["k"] = 1                      # annotating is safe
        t.add_span("v", 0.0, 1.0)
        assert len(t) == 0
    finally:
        set_tracer(old)


def test_ring_mode_drops_full_mode_keeps():
    t = Tracer()
    t.enable(mode="ring", capacity=4)
    for i in range(10):
        t.add_span("s", i, i + 1)
    assert len(t) == 4 and t.dropped == 6
    assert [s.t0 for s in t.spans()] == [6.0, 7.0, 8.0, 9.0]
    t.enable(mode="full")
    for i in range(10):
        t.add_span("s", i, i + 1)
    assert len(t) == 10 and t.dropped == 0


def test_begin_end_open_spans_and_end_all(tracer):
    h = tracer.begin("resident", track="slot/0", clock=VIRTUAL, t=1.0)
    tracer.end(h, t=3.0, user=7)
    assert tracer.end(None) is None               # disabled-mode handle
    h2 = tracer.begin("resident", track="slot/1", clock=VIRTUAL, t=5.0)
    assert tracer.end_all(t=9.0) == 1
    tracer.end(h2, t=11.0)                        # already closed: no dup
    spans = tracer.spans(clock=VIRTUAL)
    assert [(s.t0, s.t1) for s in spans] == [(1.0, 3.0), (5.0, 9.0)]
    assert spans[0].attrs == {"user": 7}


def test_phase_summary_aggregates(tracer):
    tracer.add_span("a", 0.0, 1.0, track="x")
    tracer.add_span("a", 0.0, 3.0, track="x")
    tracer.add_span("b", 0.0, 2.0, track="y")
    agg = phase_summary(tracer)
    assert agg["a"] == {"count": 2, "total_s": 4.0, "max_s": 3.0,
                        "mean_s": 2.0}
    assert phase_summary(tracer, track="x").keys() == {"a"}


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------


def test_counter_monotonic_and_gauge_fn():
    cs = CounterSet("test.ns1")
    c = cs.counter("n")
    c.inc()
    c.inc(4)
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 5
    box = {"v": 0.0}
    cs.gauge("g", fn=lambda: box["v"])
    box["v"] = 2.5
    snap = snapshot_counters("test.ns1")
    assert snap == {"test.ns1/n": 5, "test.ns1/g": 2.5}
    assert cs.counter("n") is c                   # create-or-return
    with pytest.raises(TypeError):
        cs.gauge("n")                             # name already a counter
    cs.reset()
    assert cs.counter("n").value == 0


def test_registry_sums_and_forgets_dead_sets():
    import gc

    a = CounterSet("test.ns2")
    b = CounterSet("test.ns2")
    a.counter("k").inc(2)
    b.counter("k").inc(3)
    assert snapshot_counters("test.ns2") == {"test.ns2/k": 5}
    del b
    gc.collect()                                  # WeakSet registry
    assert snapshot_counters("test.ns2") == {"test.ns2/k": 2}


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------


def test_export_schema_and_deterministic_tids(tracer, tmp_path):
    with span("w", track="zeta"):
        pass
    tracer.add_span("v", 1.0, 2.0, track="link/1->0", n=3)
    tracer.add_span("v", 0.5, 2.5, track="client/2")
    h = tracer.begin("open", track="client/2", clock=VIRTUAL, t=1.0)
    del h                                          # closed by export
    doc = write_trace(str(tmp_path / "t.json"))
    with open(tmp_path / "t.json") as f:
        assert json.load(f) == doc
    assert validate_trace(doc) == []
    other = doc["otherData"]
    assert other["traceSchemaVersion"] == 1
    assert other["jsonlSchemaVersion"] == 1
    assert other["spans"] == 4 and other["droppedSpans"] == 0
    assert isinstance(other["counters"], dict)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    # one pid per clock domain; tids assigned by sorted track name
    assert {e["pid"] for e in xs if e["cat"] == "wall"} == {1}
    assert {e["pid"] for e in xs if e["cat"] == "virtual"} == {2}
    virt = {e["name"]: e["tid"] for e in xs if e["cat"] == "virtual"}
    # sorted virtual tracks: client/2 < link/1->0  -> tids 1, 2
    assert virt["open"] == 1 and virt["v"] in (1, 2)
    names = {e["args"]["name"]
             for e in doc["traceEvents"] if e["name"] == "thread_name"}
    assert names == {"zeta", "client/2", "link/1->0"}


def test_validate_trace_catches_breakage():
    assert validate_trace({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0, "dur": -1},
        {"ph": "Q", "name": "b", "pid": 1},
    ]}
    problems = validate_trace(bad)
    assert any("negative dur" in p for p in problems)
    assert any("unsupported ph" in p for p in problems)


# ---------------------------------------------------------------------------
# engine + sim instrumentation
# ---------------------------------------------------------------------------


def test_sim_sync_virtual_spans_match_linkstats_bitforbit(tracer, setup):
    from repro.sim import LossModel, SimEngine

    task, clients, cfg = setup
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    local_exec="loop", mode="sync", uplink="fifo",
                    loss=LossModel(0.3, timeout_s=0.05, seed=0))
    sim.run()
    recorded = {(s.name, s.t0, s.t1, s.attrs["src"], s.attrs["dst"],
                 s.attrs["bytes_values"], s.attrs["bytes_wire"])
                for s in tracer.spans(clock=VIRTUAL)
                if s.name in ("transfer", "retransmit")}
    expected = {("retransmit" if tr.attempt else "transfer",
                 tr.t_start, tr.t_end, tr.src, tr.dst,
                 tr.bytes_values, tr.bytes_wire)
                for tr in sim.stats.transfers}
    assert recorded == expected                   # identical floats
    assert any(n == "retransmit" for n, *_ in recorded)
    # fifo discipline also emits uplink-residency spans
    assert tracer.spans(clock=VIRTUAL, track="uplink/0")
    # counters mirror the same accumulators the spans were stamped from
    snap = snapshot_counters("sim.links")
    assert snap["sim.links/transfers"] == len(sim.stats.transfers)
    assert snap["sim.links/bytes_wire"] == float(sim.stats.up_wire.sum())
    # the host-side engine phases landed on the wall clock
    agg = phase_summary(tracer, clock=WALL, track="engine")
    assert agg["round.mix"]["count"] == cfg.rounds
    assert agg["round.local"]["count"] == cfg.rounds


def test_sim_async_compute_and_wait_spans(tracer, setup):
    from repro.sim import SimEngine

    task, clients, cfg = setup
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=0, round_s=1.0,
                    compute_speeds=np.array([0.2, 1.0, 1.0, 1.0]))
    sim.run()
    compute = [s for s in tracer.spans(clock=VIRTUAL) if s.name == "compute"]
    waits = [s for s in tracer.spans(clock=VIRTUAL) if s.name == "ssp.wait"]
    assert len(compute) == cfg.rounds * cfg.n_clients
    assert {s.track for s in compute} == {
        f"client/{k}" for k in range(cfg.n_clients)}
    # staleness=0 with a 5x-faster client 0 must gate it at least once
    assert waits and all(s.t1 >= s.t0 for s in waits)
    # every wait closed within the simulated horizon
    assert all(s.t1 <= sim.clock.now for s in waits)


def test_scale_engine_recompile_guard(setup):
    from repro.scale import ScaleEngine

    task, clients, cfg = setup
    # the annealing strategy sweeps lr AND prune-rate scalars every round —
    # exactly the traced-scalar path that must never retrigger a compile
    eng = ScaleEngine(make_strategy("dispfl_anneal"), task, clients, cfg)
    eng.run()
    assert eng.step_compiles == 1
    snap = snapshot_counters("scale.engine")
    assert snap["scale.engine/step_calls"] >= cfg.rounds
    assert snapshot_counters("jax")["jax/backend_compiles"] >= 1


# ---------------------------------------------------------------------------
# serve instrumentation
# ---------------------------------------------------------------------------


def _mlp_store(n_users=6, density=0.5, cache_size=4, seed=0):
    from repro.core.masks import apply_mask, init_mask
    from repro.serve import MLPModel, ModelStore

    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    base = model.init(jax.random.PRNGKey(seed))
    store = ModelStore(base, cache_size=cache_size)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), 2 * n_users)
    for u in range(n_users):
        p = model.init(keys[2 * u])
        store.put(u, apply_mask(p, init_mask(keys[2 * u + 1], p, density)),
                  init_mask(keys[2 * u + 1], p, density))
    return store, model


def test_store_counters_and_residency_spans(tracer):
    store, _ = _mlp_store(n_users=6, cache_size=2)
    for u in (0, 1, 0, 2, 0):      # miss, miss, hit, miss+evict, hit
        store.acquire(u)
    assert (store.hits, store.misses) == (2, 3)
    assert store.evictions >= 1
    decodes = [s for s in tracer.spans() if s.name == "store.miss_decode"]
    assert len(decodes) == 3
    assert all(s.attrs["nbytes"] > 0 for s in decodes)
    tracer.end_all()
    resident = [s for s in tracer.spans() if s.name.startswith("user:")]
    assert len(resident) == 3                     # one per miss
    assert all(s.track.startswith("slot/") for s in resident)
    store.reset_counters()
    assert (store.hits, store.misses, store.evictions) == (0, 0, 0)


def test_serve_engine_component_spans_and_summary(tracer):
    from repro.serve import RequestStream, ServeEngine

    store, model = _mlp_store(n_users=6, cache_size=4)
    eng = ServeEngine(store, model, backend="vmap", max_batch=4,
                      max_wait=0.01)
    res = eng.serve(RequestStream(n_users=6, n_requests=24, seed=0,
                                  rate=500.0))
    s = res.summary
    n_batches = s["batches"]
    for phase in ("serve.launch", "serve.acquire", "serve.scatter",
                  "serve.forward"):
        assert phase_summary(tracer, clock=WALL)[phase]["count"] == n_batches
    waits = tracer.spans(clock=VIRTUAL)
    assert sum(1 for w in waits if w.name == "request.wait") == 24
    # honest latency components: wait + service decompose the percentile
    for key in ("p50_wait_ms", "p99_wait_ms", "p50_service_ms",
                "p99_service_ms"):
        assert key in s
    assert s["p50_ms"] >= s["p50_wait_ms"]


# ---------------------------------------------------------------------------
# MetricsStream fixes
# ---------------------------------------------------------------------------


def test_metrics_stream_append_resumes_without_clobber(tmp_path):
    from repro.sim.report import MetricsStream

    path = str(tmp_path / "m.jsonl")
    with MetricsStream(path) as ms:
        ms.emit({"event": "a"})
    with MetricsStream(path, append=True) as ms:
        ms.emit({"event": "b"})
    events = [json.loads(l)["event"] for l in open(path)]
    assert events == ["a", "b"]
    with MetricsStream(path) as ms:               # mode "w": fresh run
        ms.emit({"event": "c"})
    assert [json.loads(l)["event"] for l in open(path)] == ["c"]


def test_metrics_stream_never_closes_stdout(capsys):
    from repro.sim.report import MetricsStream

    ms = MetricsStream("-")
    ms.emit({"event": "x"})
    ms.close()
    ms.close()                                    # idempotent
    assert not sys.stdout.closed
    print("still alive")
    out = capsys.readouterr().out
    assert '"event": "x"' in out and "still alive" in out


def test_metrics_stream_schema_header(tmp_path):
    from repro.sim.report import MetricsStream

    path = str(tmp_path / "h.jsonl")
    with MetricsStream(path, header=True) as ms:
        ms.emit({"event": "a"})
        ms.emit({"event": "b"})
    recs = [json.loads(l) for l in open(path)]
    assert recs[0] == {"event": "schema", "version": 1}
    assert [r["event"] for r in recs[1:]] == ["a", "b"]


# ---------------------------------------------------------------------------
# codec counters + roofline measured rows
# ---------------------------------------------------------------------------


def test_codec_counters_and_spans(tracer):
    from repro.core.masks import init_mask
    from repro.serve import MLPModel
    from repro.sparse import TreeSpec, decode, encode, pack_tree

    model = MLPModel(d_in=16, widths=(32,), n_out=8)
    p = model.init(jax.random.PRNGKey(0))
    m = init_mask(jax.random.PRNGKey(1), p, 0.5)
    before = snapshot_counters("sparse.codec")
    frame = encode(pack_tree(p, m))
    decode(frame, TreeSpec.from_tree(p))
    after = snapshot_counters("sparse.codec")
    assert after["sparse.codec/encodes"] == before.get(
        "sparse.codec/encodes", 0) + 1
    assert after["sparse.codec/bytes_out"] - before.get(
        "sparse.codec/bytes_out", 0) == len(frame)
    assert after["sparse.codec/bytes_in"] - before.get(
        "sparse.codec/bytes_in", 0) == len(frame)
    names = {s.name for s in tracer.spans(track="codec")}
    assert {"codec.pack_tree", "codec.encode", "codec.decode"} <= names


def test_measured_phase_rows_prices_analytic_cost():
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS, measured_phase_rows

    summary = {"round.local": {"count": 2, "total_s": 4.0, "mean_s": 2.0,
                               "max_s": 3.0},
               "round.mix": {"count": 2, "total_s": 1.0, "mean_s": 0.5,
                             "max_s": 0.6}}
    rows = measured_phase_rows(summary, {"round.local": (PEAK_FLOPS, "flops"),
                                         "round.mix": (HBM_BW, "bytes")})
    by = {r["phase"]: r for r in rows}
    assert by["round.local"]["predicted_ms_per_call"] == 1000.0
    assert by["round.local"]["achieved_per_s"] == PEAK_FLOPS / 2.0
    assert by["round.mix"]["predicted_ms_per_call"] == 1000.0
    assert by["round.mix"]["observed_ms_per_call"] == 500.0
    with pytest.raises(ValueError):
        measured_phase_rows(summary, {"round.mix": (1.0, "pixels")})


# ---------------------------------------------------------------------------
# CLI smokes: --trace artifacts reconcile with the counters inside them
# ---------------------------------------------------------------------------


def _run_cli(args, cwd):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    return subprocess.run([sys.executable, "-m"] + args, cwd=cwd,
                          env=env, capture_output=True, text=True,
                          timeout=600)


@pytest.mark.slow
def test_train_sim_trace_cli_reconciles(tmp_path):
    trace = str(tmp_path / "sim_trace.json")
    r = _run_cli(["repro.launch.train", "simulate", "--sim",
                  "--clients", "4", "--rounds", "2", "--local-epochs", "1",
                  "--batch-size", "16", "--samples-per-class", "20",
                  "--hw", "8", "--width", "4", "--degree", "2",
                  "--eval-every", "2", "--exec", "loop",
                  "--loss-prob", "0.3", "--retransmit-timeout", "0.05",
                  "--uplink-mode", "fifo",
                  "--trace", trace, "--trace-mode", "full"], REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    with open(trace) as f:
        doc = json.load(f)
    assert validate_trace(doc) == []
    counters = doc["otherData"]["counters"]
    xfers = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] in ("transfer", "retransmit")]
    assert counters["sim.links/transfers"] == len(xfers)
    assert counters["sim.links/bytes_wire"] == sum(
        e["args"]["bytes_wire"] for e in xfers)
    assert counters["sim.links/n_retransmits"] == sum(
        1 for e in xfers if e["name"] == "retransmit")


@pytest.mark.slow
def test_serve_trace_cli_reconciles(tmp_path):
    trace = str(tmp_path / "serve_trace.json")
    metrics = str(tmp_path / "serve.jsonl")
    r = _run_cli(["repro.launch.serve", "--users", "8", "--cache-size", "4",
                  "--max-batch", "4", "--requests", "32", "--model", "mlp",
                  "--metrics-jsonl", metrics,
                  "--trace", trace, "--trace-mode", "full"], REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    with open(trace) as f:
        doc = json.load(f)
    assert validate_trace(doc) == []
    counters = doc["otherData"]["counters"]
    # every request acquires a slot exactly once
    assert (counters["serve.store/hits"]
            + counters["serve.store/misses"]) == 32
    waits = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "request.wait"]
    assert len(waits) == 32
    summary = [json.loads(l) for l in open(metrics)][-1]
    assert summary["event"] == "summary"
    assert summary["store_hits"] == counters["serve.store/hits"]
    launches = [e for e in doc["traceEvents"]
                if e["ph"] == "X" and e["name"] == "serve.launch"]
    assert len(launches) == summary["batches"]
