"""Substrate tests: optimizer masking, checkpoint round-trip, data
partitioners, HLO collective parser, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_pytree, save_pytree
from repro.data.partition import (
    dirichlet_partition,
    label_distribution,
    matched_test_indices,
    pathological_partition,
)
from repro.optim import SGDConfig, init_sgd, masked_sgd_step, sgd_step
from repro.utils import hlo


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_masked_sgd_keeps_dormant_zero():
    params = {"w": jnp.ones((8,))}
    mask = {"w": jnp.array([1, 1, 0, 0, 1, 0, 1, 1], jnp.float32)}
    params = {"w": params["w"] * mask["w"]}
    grads = {"w": jnp.full((8,), 0.5)}
    cfg = SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0)
    state = init_sgd(params, cfg)
    for _ in range(3):
        params, state = masked_sgd_step(params, grads, mask, state, cfg)
    w = np.asarray(params["w"])
    assert np.all(w[np.asarray(mask["w"]) == 0] == 0.0)
    assert np.all(w[np.asarray(mask["w"]) == 1] != 1.0)


def test_sgd_momentum_accelerates():
    params = {"w": jnp.array([1.0])}
    grads = {"w": jnp.array([1.0])}
    plain = SGDConfig(lr=0.1, momentum=0.0, weight_decay=0.0)
    mom = SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0)
    p1, s1 = params, init_sgd(params, plain)
    p2, s2 = params, init_sgd(params, mom)
    for _ in range(5):
        p1, s1 = sgd_step(p1, grads, s1, plain)
        p2, s2 = sgd_step(p2, grads, s2, mom)
    assert float(p2["w"][0]) < float(p1["w"][0])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((3,))},
            "m": {"x": jnp.array([1, 2, 3], jnp.int8)}}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree)
    back = load_pytree(path)
    for (p1, x1), (p2, x2) in zip(
            jax.tree_util.tree_leaves_with_path(tree),
            jax.tree_util.tree_leaves_with_path(back)):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
        assert x1.dtype == x2.dtype


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------


def test_dirichlet_partition_covers_all():
    labels = np.repeat(np.arange(10), 50)
    parts = dirichlet_partition(labels, 8, 0.3, seed=1)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)


def test_dirichlet_alpha_controls_skew():
    labels = np.repeat(np.arange(10), 200)
    skewed = dirichlet_partition(labels, 8, 0.05, seed=0)
    uniform = dirichlet_partition(labels, 8, 100.0, seed=0)

    def skew(parts):
        ents = []
        for idx in parts:
            d = label_distribution(labels, idx, 10)
            d = d[d > 0]
            ents.append(-(d * np.log(d)).sum())
        return np.mean(ents)

    assert skew(skewed) < skew(uniform)


def test_pathological_partition_class_count():
    labels = np.repeat(np.arange(10), 100)
    parts = pathological_partition(labels, 10, 2, seed=0)
    for idx in parts:
        assert len(np.unique(labels[idx])) <= 2
        assert len(idx) > 0


def test_matched_test_distribution():
    test_labels = np.repeat(np.arange(10), 100)
    dist = np.zeros(10)
    dist[3] = 0.75
    dist[7] = 0.25
    idx = matched_test_indices(test_labels, dist, 40, seed=0)
    got = label_distribution(test_labels, idx, 10)
    assert got[3] == pytest.approx(0.75, abs=0.05)
    assert got[7] == pytest.approx(0.25, abs=0.05)
    assert len(idx) == 40


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

FAKE_HLO = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p0), replica_groups={}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %p1), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(f32[256]{0} %p2), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %p3), source_target_pairs={{0,1}}
  %a2a = f32[4,64]{1,0} all-to-all(f32[4,64]{1,0} %p4), dimensions={0}
  %dead = f32[9]{0} add(f32[9]{0} %x, f32[9]{0} %y)
"""


def test_collective_parser():
    stats = hlo.collective_bytes(FAKE_HLO)
    assert stats.count_by_kind == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "collective-permute": 1, "all-to-all": 1}
    expected = (16 * 1024 * 2          # all-gather out
                + 2 * 256 * 4          # all-reduce 2x in
                + 256 * 4              # reduce-scatter in
                + 8 * 8 * 2            # collective-permute in
                + 4 * 64 * 4)          # all-to-all in
    assert stats.total_bytes == expected


# ---------------------------------------------------------------------------
# sharding rules (pure python — no devices needed)
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape.keys())


def test_param_spec_rules():
    from repro.sharding.rules import param_spec
    mesh = _FakeMesh({"data": 16, "model": 16})
    # stacked (K, in, out) default: out over model
    assert param_spec("blocks/p0/attn/wq/w", (16, 4, 1024, 2048), mesh,
                      fsdp2d=False) == P(("data",), None, None, "model")
    # row-sharded matrices
    assert param_spec("blocks/p0/attn/wo/w", (16, 4, 2048, 1024), mesh,
                      fsdp2d=False) == P(("data",), None, "model", None)
    # norms replicated
    assert param_spec("blocks/p0/norm1/scale", (16, 4, 1024), mesh,
                      fsdp2d=False) == P(("data",), None, None)
    # moe experts over model
    assert param_spec("blocks/p0/moe/w_gate", (16, 4, 64, 128, 256), mesh,
                      fsdp2d=False) == P(("data",), None, "model", None, None)
    # fsdp2d: 2-D weight sharding, no client axes
    spec = param_spec("blocks/p0/attn/wq/w", (1, 9, 8192, 8192), mesh,
                      fsdp2d=True)
    assert spec == P(None, None, "data", "model")


def test_cache_spec_rules():
    from repro.sharding.rules import cache_spec
    mesh = _FakeMesh({"data": 16, "model": 16})
    # kv cache: head_dim over model; seq over data only in long-ctx K=1 mode
    assert cache_spec("blocks/p0/k", (16, 4, 8, 32768, 8, 128), mesh,
                      seq_data=False) == P(("data",), None, None, None, None, "model")
    assert cache_spec("blocks/p0/k", (1, 4, 1, 524288, 1, 256), mesh,
                      seq_data=True, fsdp2d=True) == P(
        None, None, None, "data", None, "model")
    assert cache_spec("blocks/p0/ssm_state", (16, 4, 8, 64, 64, 128), mesh,
                      seq_data=False) == P(("data",), None, None, "model", None, None)


def test_all_archs_tp_divisibility():
    """Every arch's TP-sharded dims divide the model axis (16)."""
    from repro.configs import ARCHS
    for name, cfg in ARCHS.items():
        dh = cfg.resolved_head_dim
        assert (cfg.n_heads * dh) % 16 == 0, name
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, name
        if cfg.moe is not None:
            assert cfg.moe.n_experts % 16 == 0, name
        if cfg.ssm is not None:
            d_inner = cfg.ssm.expand * cfg.d_model
            assert d_inner % 16 == 0, name
