"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (2 layers / one pattern period, d_model<=512, <=4 experts) runs one
forward/train step on CPU; output shapes and finiteness asserted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.models import bind
from repro.utils.tree import check_finite, tree_size

ALL = sorted(ARCHS)


def _batch(cfg, b=2, s=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    if cfg.enc_layers > 0:
        enc, dec = s // 2, s // 2
        return {
            "frames": jax.random.normal(ks[0], (b, enc, cfg.d_model)),
            "tokens": jax.random.randint(ks[1], (b, dec), 0, cfg.vocab),
            "labels": jax.random.randint(ks[2], (b, dec), 0, cfg.vocab),
        }
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s - cfg.prefix_len), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.prefix_len:
        batch["prefix"] = jax.random.normal(ks[2], (b, cfg.prefix_len, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ALL)
def test_smoke_reduced_variant_limits(name):
    cfg = SMOKE_ARCHS[name]
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 8
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", ALL)
def test_smoke_forward_and_train_step(name):
    cfg = SMOKE_ARCHS[name]
    api = bind(cfg)
    params = api.init(jax.random.PRNGKey(0))
    assert tree_size(params) > 0
    batch = _batch(cfg)
    loss, metrics = api.train_loss(params, batch)
    assert np.isfinite(float(loss)), f"{name} loss not finite"
    # one SGD step changes the params and stays finite
    grads = jax.grad(lambda p: api.train_loss(p, batch)[0])(params)
    assert check_finite(grads), f"{name} grads not finite"
    new = jax.tree.map(lambda w, g: w - 0.01 * g, params, grads)
    loss2, _ = api.train_loss(new, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", ALL)
def test_smoke_prefill_decode_shapes(name):
    cfg = SMOKE_ARCHS[name]
    api = bind(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s, max_len = 2, 16, 24
    if cfg.enc_layers > 0:
        cache = api.init_cache(b, max_len, enc_len=8)
        batch = {"frames": jax.random.normal(jax.random.PRNGKey(1), (b, 8, cfg.d_model)),
                 "tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)}
    else:
        cache = api.init_cache(b, max_len)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2),
                                              (b, s - cfg.prefix_len), 0, cfg.vocab)}
        if cfg.prefix_len:
            batch["prefix"] = jnp.zeros((b, cfg.prefix_len, cfg.d_model))
    logits, cache = api.prefill(params, batch, cache)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    logits2, cache = api.decode(params, tok, jnp.int32(s), cache)
    assert logits2.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("name", ["gemma3-1b", "qwen3-8b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b", "deepseek-moe-16b"])
def test_decode_matches_teacher_forcing(name):
    """Prefill+decode logits must match the full forward pass at the same
    positions (validates KV caches, window masks, SSM recurrent states)."""
    cfg = SMOKE_ARCHS[name]
    api = bind(cfg, moe_dense=True, remat=False)  # exact MoE for comparison
    params = api.init(jax.random.PRNGKey(0))
    b, s0, steps = 2, 12, 4
    s = s0 + steps
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab)
    from repro.models import lm as lm_mod
    full_logits, _ = lm_mod.forward_train(params, toks, cfg, remat=False,
                                          moe_dense=True)

    cache = api.init_cache(b, s)
    logits, cache = api.prefill(params, {"tokens": toks[:, :s0]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, s0 - 1], np.float32), rtol=2e-3, atol=2e-3)
    for i in range(steps):
        pos = jnp.int32(s0 + i)
        logits, cache = api.decode(params, toks[:, s0 + i][:, None], pos, cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, s0 + i], np.float32),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{name} decode step {i} diverges from teacher forcing")


def test_encdec_decode_matches_teacher_forcing():
    cfg = SMOKE_ARCHS["seamless-m4t-large-v2"]
    api = bind(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(0))
    b, enc_len, s0, steps = 2, 8, 10, 3
    s = s0 + steps
    frames = jax.random.normal(jax.random.PRNGKey(1), (b, enc_len, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    from repro.models import encdec as ed
    full_logits, _ = ed.decode_train(params, frames, toks, cfg, remat=False)
    cache = api.init_cache(b, s, enc_len=enc_len)
    logits, cache = api.prefill(params, {"frames": frames, "tokens": toks[:, :s0]}, cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(full_logits[:, s0 - 1], np.float32),
                               rtol=2e-3, atol=2e-3)
    for i in range(steps):
        logits, cache = api.decode(params, toks[:, s0 + i][:, None],
                                   jnp.int32(s0 + i), cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(full_logits[:, s0 + i], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_moe_capacity_dispatch_close_to_dense():
    """With generous capacity, gather dispatch == dense reference."""
    from repro.configs.base import MoESpec
    from repro.models import moe as moe_mod
    spec = MoESpec(n_experts=4, top_k=2, d_expert=32, capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(key, 16, spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y1, _ = moe_mod.moe_apply(p, x, spec)
    y2, _ = moe_mod.moe_dense_ref(p, x, spec)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
