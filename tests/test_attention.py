"""Attention-path equivalence tests: banded local attention (§Perf C2) vs
the masked full-attention oracle; prefill/decode window behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models import attention as attn_mod


def _cfg(n_heads=4, n_kv=2, head_dim=16):
    return SMOKE_ARCHS["qwen3-8b"].replace(
        n_heads=n_heads, n_kv_heads=n_kv, head_dim=head_dim, qk_norm=False)


@pytest.mark.parametrize("s,window", [(64, 16), (128, 32), (96, 32)])
@pytest.mark.parametrize("n_kv", [1, 2, 4])
def test_local_attention_matches_masked_full(s, window, n_kv):
    cfg = _cfg(n_kv=n_kv)
    key = jax.random.PRNGKey(s + window + n_kv)
    ks = jax.random.split(key, 3)
    b, h, dh = 2, cfg.n_heads, cfg.resolved_head_dim
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, n_kv, dh))
    v = jax.random.normal(ks[2], (b, s, n_kv, dh))
    banded = attn_mod._local_attention(q, k, v, cfg, window)
    mask = attn_mod.causal_mask(s, s, 0, window)
    full = attn_mod._sdpa(q, k, v, cfg, mask)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_attention_dispatches_to_banded_path():
    """End-to-end: a windowed layer gives identical outputs whether the seq
    divides the window (banded path) or not (full path), on overlapping
    prefixes."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = attn_mod.attn_init(key, cfg, jnp.float32)
    s, w = 64, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (2, s))
    y_banded, _ = attn_mod.attention(p, x, positions, cfg, window=w)
    # force the full path by passing window only via mask (s == window+rest)
    q, k, v = attn_mod._qkv(p, x, cfg, positions)
    mask = attn_mod.causal_mask(s, s, 0, w)
    out = attn_mod._sdpa(q, k, v, cfg, mask)
    from repro.models.common import dense
    y_full = dense(p["wo"], out.reshape(2, s, -1))
    np.testing.assert_allclose(np.asarray(y_banded), np.asarray(y_full),
                               rtol=2e-5, atol=2e-5)


def test_decode_respects_window():
    """A token outside the window must not influence decode logits."""
    cfg = _cfg(n_kv=1)
    p = attn_mod.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, smax, w = 1, 32, 4
    cache = attn_mod.init_kv_cache(cfg, b, smax, jnp.float32)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (b, 8, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(8)[None], (b, 8))
    _, cache = attn_mod.attention(p, x0, positions, cfg, window=w, cache=cache)
    xq = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model))
    y1, _ = attn_mod.attention(p, xq, jnp.full((b, 1), 8), cfg, window=w,
                               cache=cache, pos=jnp.int32(8))
    # perturb a cache slot far outside the window (position 0)
    cache2 = dict(cache)
    cache2["k"] = cache["k"].at[:, 0].add(100.0)
    cache2["v"] = cache["v"].at[:, 0].add(100.0)
    y2, _ = attn_mod.attention(p, xq, jnp.full((b, 1), 8), cfg, window=w,
                               cache=cache2, pos=jnp.int32(8))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
