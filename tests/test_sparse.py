"""repro.sparse: pack/unpack roundtrip properties, codec-vs-accounting byte
exactness across the strategy zoo, packed-gossip golden equivalence (engine
and simulator), Pallas kernel parity, mix_one degree (not K) scaling, and
the density-annealing strategy's shrinking payloads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hypothesis_fallback import given, settings, st

from repro.core.accounting import message_bytes
from repro.core.gossip import gossip_average_one
from repro.core.masks import annealed_density, mask_density
from repro.data import build_federated_image_task
from repro.fl import (
    FLConfig,
    RoundEngine,
    make_cnn_task,
    make_strategy,
    run_strategy,
    strategy_names,
)
from repro.sparse import (
    PackedSparse,
    TreeSpec,
    decode,
    encode,
    encoded_nbytes,
    pack,
    pack_tree,
    packed_gossip_one,
    tree_packed_nnz,
    unpack,
    unpack_mask_tree,
    unpack_tree,
)
from repro.sparse import ops as sparse_ops

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def setup():
    clients, _ = build_federated_image_task(
        0, n_clients=4, partition="pathological", classes_per_client=2,
        n_train_per_class=24, n_test_per_client=16, hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    cfg = FLConfig(n_clients=4, rounds=3, local_epochs=2, batch_size=16,
                   degree=2, eval_every=1)
    return task, clients, cfg


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# pack/unpack roundtrip (property)
# ---------------------------------------------------------------------------

_SHAPES = [(3, 5, 7), (1, 129), (33,), (2, 4, 8), (31,), (128, 3)]


@settings(max_examples=24, deadline=None)
@given(shape_i=st.integers(min_value=0, max_value=len(_SHAPES) - 1),
       density=st.sampled_from([0.0, 1.0, 0.37, 0.5]),
       fp16=st.sampled_from([False, True]),
       seed=st.integers(min_value=0, max_value=999))
def test_pack_unpack_roundtrip(shape_i, density, fp16, seed):
    shape = _SHAPES[shape_i]
    rng = np.random.default_rng(seed)
    dtype = np.float16 if fp16 else np.float32
    w = jnp.asarray(rng.normal(size=shape).astype(dtype))
    m = jnp.asarray((rng.random(shape) < density).astype(np.float32))
    ps = pack(w * m.astype(w.dtype), m)
    assert ps.nnz == int(m.sum())
    assert ps.bitmap.shape[0] == -(-int(np.prod(shape)) // 32)
    assert ps.values.dtype == w.dtype
    # exact reconstruction: held values bit for bit, exact zeros elsewhere
    assert jnp.array_equal(unpack(ps), w * m.astype(w.dtype))


def test_pack_dense_and_empty_edge_cases():
    w = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    full = pack(w)                                   # mask=None -> dense
    assert full.nnz == 6 and jnp.array_equal(unpack(full), w)
    empty = pack(w, jnp.zeros_like(w))
    assert empty.nnz == 0 and jnp.array_equal(unpack(empty), jnp.zeros_like(w))


# ---------------------------------------------------------------------------
# codec: roundtrip + byte-exactness vs accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16])
def test_codec_roundtrip_and_exact_frame_size(dtype):
    rng = np.random.default_rng(7)
    tree = {"a": {"w": jnp.asarray(rng.normal(size=(5, 7)).astype(np.float32))},
            "b": jnp.asarray(rng.normal(size=(13,)).astype(np.float32))}
    mask = {"a": {"w": jnp.asarray((rng.random((5, 7)) < 0.4).astype(np.float32))},
            "b": jnp.ones(13, jnp.float32)}
    masked = jax.tree.map(lambda w, m: w * m, tree, mask)
    pt = pack_tree(jax.tree.map(lambda x: x.astype(dtype), masked), mask)
    frame = encode(pt)
    assert len(frame) == encoded_nbytes(pt)         # exact, not approximate
    itemsize = jnp.dtype(dtype).itemsize
    assert encoded_nbytes(pt) == message_bytes(
        tree_packed_nnz(pt), 5 * 7 + 13, with_bitmap=True,
        value_nbytes=itemsize)
    back = decode(frame, TreeSpec.from_tree(pt))
    assert _trees_equal(unpack_tree(back), unpack_tree(pt))
    assert _trees_equal(unpack_mask_tree(back), mask)


def test_measured_comm_matches_analytic_and_tracks_dtype(setup):
    # measured mode: a CommReport built from real encoded frame sizes is
    # bit-equal to the analytic decentralized_comm for fp32 payloads, and
    # diverges exactly when the payload does (fp16 halves the value column)
    from repro.core.accounting import decentralized_comm, measured_comm
    from repro.core.topology import make_adjacency
    task, clients, cfg = setup
    strat = make_strategy("dispfl")
    state = strat.init_state(task, clients, cfg)
    a = make_adjacency(cfg.topology, 4, 0, cfg.degree, cfg.seed)
    packs = [strat.snapshot_message(state, k)["packed"] for k in range(4)]
    nnz = [strat.message_nnz(state, k) for k in range(4)]
    analytic = decentralized_comm(a, nnz, strat.message_coords(state, 0))
    measured = measured_comm(a, [n * 4 for n in nnz],
                             [encoded_nbytes(p) for p in packs])
    assert measured == analytic
    half = [pack_tree(unpack_tree(p), unpack_mask_tree(p),
                      dtype=jnp.float16) for p in packs]
    measured16 = measured_comm(a, [n * 2 for n in nnz],
                               [encoded_nbytes(p) for p in half])
    assert measured16.busiest_mb == pytest.approx(analytic.busiest_mb / 2)
    assert measured16.busiest_mb_with_bitmap < analytic.busiest_mb_with_bitmap


def test_encoded_nbytes_matches_accounting_all_strategies(setup):
    # the satellite contract: for every registered strategy, the codec frame
    # of what it would transmit == the analytic with-bitmap message size
    task, clients, cfg = setup
    for name in strategy_names():
        strat = make_strategy(name)
        state = strat.init_state(task, clients, cfg)
        payload = strat.snapshot_message(state, 0)
        assert "packed" in payload, name
        enc = encoded_nbytes(payload["packed"])
        assert enc == len(encode(payload["packed"])), name
        assert enc == message_bytes(strat.message_nnz(state, 0),
                                    strat.message_coords(state, 0),
                                    with_bitmap=True), name


# ---------------------------------------------------------------------------
# packed ops: gossip golden vs dense oracle, Pallas kernel parity
# ---------------------------------------------------------------------------


def _gossip_world(seed=0, n_nbrs=3):
    rng = np.random.default_rng(seed)
    shapes = {"conv/w": (3, 3, 2, 4), "fc/w": (17, 10), "fc/b": (10,)}

    def tree(density):
        w = {k: jnp.asarray(rng.normal(size=s).astype(np.float32))
             for k, s in shapes.items()}
        m = {k: jnp.asarray((rng.random(s) < d).astype(np.float32))
             for (k, s), d in zip(shapes.items(), [density, density, 1.0])}
        return jax.tree.map(lambda x, y: x * y, w, m), m

    own_w, own_m = tree(0.5)
    nbrs = [tree(d) for d in (0.3, 0.7, 0.5)[:n_nbrs]]
    return own_w, own_m, [w for w, _ in nbrs], [m for _, m in nbrs]


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_packed_gossip_bit_identical_to_dense(backend):
    own_w, own_m, nbr_w, nbr_m = _gossip_world()
    dense = gossip_average_one(own_w, own_m, nbr_w, nbr_m)
    packs = [pack_tree(w, m) for w, m in zip(nbr_w, nbr_m)]
    got = packed_gossip_one(own_w, own_m, packs, backend=backend)
    assert _trees_equal(dense, got)


def test_packed_accum_kernel_matches_ref():
    from repro.kernels.packed_accum import BLOCK_N, packed_accum_flat
    from repro.kernels.ref import packed_accum_ref
    from repro.sparse.packed import _unpack_bits, n_words

    rng = np.random.default_rng(3)
    n = 3 * BLOCK_N
    flags = rng.random(n) < 0.3
    values = rng.normal(size=int(flags.sum())).astype(np.float32)
    num0 = jnp.asarray(rng.normal(size=n).astype(np.float32))
    den0 = jnp.asarray(rng.random(n).astype(np.float32))
    ps = pack(jnp.zeros(n).at[np.flatnonzero(flags)].set(values),
              jnp.asarray(flags.astype(np.float32)))
    words = np.zeros(n // 32, np.uint32)
    words[: n_words(n)] = np.asarray(ps.bitmap)
    pc = _unpack_bits(words, n).reshape(-1, BLOCK_N).sum(axis=1)
    offsets = np.concatenate([[0], np.cumsum(pc)[:-1]]).astype(np.int32)
    vals_pad = np.concatenate([values, np.zeros(BLOCK_N, np.float32)])
    num_k, den_k = packed_accum_flat(
        num0, den0, jnp.asarray(words), jnp.asarray(vals_pad),
        jnp.asarray(offsets), jnp.float32(0.75))
    num_r, den_r = packed_accum_ref(num0, den0, jnp.asarray(flags),
                                    jnp.asarray(values), 0.75)
    # the jitted kernel may fuse the alpha multiply-add (FMA); the eager
    # oracle does not — identical up to 1 ulp, dens exactly
    np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r),
                               rtol=1e-6, atol=1e-7)
    assert jnp.array_equal(den_k, den_r)


# ---------------------------------------------------------------------------
# golden equivalence: dispfl packed == dense, engine and sync simulator
# ---------------------------------------------------------------------------


def test_dispfl_packed_golden_round_engine(setup):
    task, clients, cfg = setup
    ref = RoundEngine(make_strategy("dispfl", packed=False), task, clients,
                      cfg, local_exec="loop")
    rows_ref = [m.to_dict() for m in ref.rounds()]
    eng = RoundEngine(make_strategy("dispfl", packed=True), task, clients,
                      cfg, local_exec="loop")
    rows = [m.to_dict() for m in eng.rounds()]
    for a, b in zip(rows, rows_ref):
        a.pop("wall_s"), b.pop("wall_s")
        assert a == b          # every per-round metric, comm rows included
    assert _trees_equal(eng.state, ref.state)


def test_dispfl_packed_golden_sim_sync(setup):
    from repro.sim import SimEngine
    task, clients, cfg = setup
    ref = SimEngine(make_strategy("dispfl", packed=False), task, clients,
                    cfg, local_exec="loop", mode="sync")
    res_ref = ref.run()
    sim = SimEngine(make_strategy("dispfl", packed=True), task, clients,
                    cfg, local_exec="loop", mode="sync")
    res = sim.run()
    assert res.acc_history == res_ref.acc_history
    assert res.final_accs == res_ref.final_accs
    assert sim.stats.total_mb == pytest.approx(ref.stats.total_mb)
    assert _trees_equal(sim.state, ref.state)


# ---------------------------------------------------------------------------
# async: wire bytes are codec-exact; mix_one scales with degree, not K
# ---------------------------------------------------------------------------


def test_async_transfer_bytes_are_codec_exact(setup):
    from repro.sim import SimEngine, measure_payload
    task, clients, cfg = setup
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=1, round_s=1.0)
    sim.run()
    # dispfl conserves per-layer nnz budgets, so every client's frame size
    # is constant over the run: each recorded transfer must equal the codec
    # frame of that sender's final snapshot, byte for byte
    expect = {k: measure_payload(sim.strategy.snapshot_message(sim.state, k))
              for k in range(len(clients))}
    assert len(sim.stats.transfers) > 0
    for tr in sim.stats.transfers:
        v, w = expect[tr.src]
        assert tr.bytes_values == v
        assert tr.bytes_wire == w
        assert float(tr.bytes_wire).is_integer()    # real frames, real bytes


def _async_accum_work(k_clients: int, degree: int, seed: int = 0) -> dict:
    clients, _ = build_federated_image_task(
        seed, n_clients=k_clients, partition="pathological",
        classes_per_client=2, n_train_per_class=8, n_test_per_client=4,
        hw=8, noise=0.7)
    task = make_cnn_task("smallcnn", 10, 8, width=4)
    topo = "fc" if degree >= k_clients - 1 else "ring"
    cfg = FLConfig(n_clients=k_clients, rounds=2, local_epochs=1,
                   batch_size=8, degree=degree, topology=topo, eval_every=4)
    from repro.sim import SimEngine, hetero_speeds
    sparse_ops.reset_counters()
    # heterogeneous compute so messages physically arrive before the SSP
    # waiters activate (with uniform speeds a 2-round run mixes nothing)
    sim = SimEngine(make_strategy("dispfl"), task, clients, cfg,
                    mode="async", staleness=1, round_s=1.0,
                    compute_speeds=hetero_speeds(k_clients, seed=2))
    sim.run()
    assert sim.mixed_messages > 0
    work = dict(sparse_ops.COUNTERS)
    work["n_leaves"] = len(jax.tree.leaves(sim.state["masks"][0]))
    work["per_activation_values"] = (
        work["accum_values"] / (cfg.rounds * k_clients))
    return work


@pytest.mark.slow
def test_mix_one_cost_scales_with_degree_not_k():
    # K=32: ring-like (degree 2) vs fully-connected (degree 31) push gossip.
    # Per activation, mix_one folds only the arrived packed payloads — the
    # old swap-in/restore path did O(K) tree work regardless of degree.
    k = 32
    ring = _async_accum_work(k, degree=2)
    fc = _async_accum_work(k, degree=k - 1)
    # the work ratio tracks the degree ratio, not K
    assert fc["accum_values"] / max(ring["accum_values"], 1) > 4.0
    # a sender publishes `degree` messages per round and a message can be
    # re-mixed once per staleness window: folds stay O(degree), never O(K)
    assert ring["accum_calls"] <= 2 * 2 * k * (2 * 2 + 1) * ring["n_leaves"]
    # per-activation cost is K-independent at fixed degree (an O(K) mix
    # would make the K=32 run ~4x the K=8 run per activation)
    small = _async_accum_work(8, degree=2)
    assert (ring["per_activation_values"]
            <= 2.5 * max(small["per_activation_values"], 1.0))


# ---------------------------------------------------------------------------
# density annealing: variable-size packed payloads
# ---------------------------------------------------------------------------


def test_dispfl_anneal_shrinks_payloads(setup):
    task, clients, cfg = setup
    import dataclasses
    cfg = dataclasses.replace(cfg, rounds=4, density=0.5, density_final=0.25,
                              eval_every=4)
    strat = make_strategy("dispfl_anneal")
    eng = RoundEngine(strat, task, clients, cfg, local_exec="loop")
    sizes = []
    for m in eng.rounds():
        payload = strat.snapshot_message(eng.state, 0)
        sizes.append(encoded_nbytes(payload["packed"]))
    assert sizes == sorted(sizes, reverse=True)     # monotone shrinking
    assert sizes[-1] < sizes[0]
    # the final mask sits at the annealed ERK budget (exact counts)
    d_end = annealed_density(0.5, 0.25, cfg.rounds - 1, cfg.rounds)
    got = mask_density(eng.state["masks"][0], eng.state["params"][0])
    assert got == pytest.approx(d_end, rel=0.05)
    # and the engine's comm accounting shrinks with the payloads
    assert eng._comm["busiest_mb"][-1] < eng._comm["busiest_mb"][0]


def test_anneal_density_schedule_endpoints():
    assert annealed_density(0.5, 0.125, 0, 100) == pytest.approx(0.5)
    assert annealed_density(0.5, 0.125, 100, 100) == pytest.approx(0.125)
    with pytest.raises(ValueError):
        annealed_density(0.5, 0.6, 0, 10)


# ---------------------------------------------------------------------------
# fp16 wire payloads: dispfl(payload_dtype="fp16")
# ---------------------------------------------------------------------------


def test_dispfl_fp16_payload_cast_tolerant_golden(setup):
    """The cast-tolerant golden contract: shipping fp16 values changes no
    bitmap (masks bit-identical to the fp32 run) and perturbs the
    trajectory only within fp16 tolerance."""
    task, clients, cfg = setup
    a = RoundEngine(make_strategy("dispfl"), task, clients, cfg,
                    local_exec="loop")
    ra = a.run()
    b = RoundEngine(make_strategy("dispfl", payload_dtype="fp16"),
                    task, clients, cfg, local_exec="loop")
    rb = b.run()
    assert _trees_equal(a.state["masks"], b.state["masks"])
    for x, y in zip(jax.tree.leaves(a.state["params"]),
                    jax.tree.leaves(b.state["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=5e-3, rtol=0)
    np.testing.assert_allclose(rb.acc_history, ra.acc_history, atol=5e-2)


def test_dispfl_fp16_codec_frame_is_half_the_values(setup):
    """Wire contract: the fp16 frame == header + bitmap + 2*nnz — exactly
    2 bytes/value less than the fp32 frame (the bitmap is dtype-free)."""
    task, clients, cfg = setup
    s32 = make_strategy("dispfl")
    s16 = make_strategy("dispfl", payload_dtype="fp16")
    st32 = s32.init_state(task, clients, cfg)
    st16 = s16.init_state(task, clients, cfg)
    p32 = s32.snapshot_message(st32, 0)["packed"]
    p16 = s16.snapshot_message(st16, 0)["packed"]
    nnz = tree_packed_nnz(p16)
    assert tree_packed_nnz(p32) == nnz          # identical bitmaps
    assert encoded_nbytes(p32) == message_bytes(
        s32.message_nnz(st32, 0), s32.message_coords(st32, 0),
        with_bitmap=True)
    assert encoded_nbytes(p32) - encoded_nbytes(p16) == 2 * nnz
    assert encoded_nbytes(p16) == len(encode(p16))
    # the simulator stamps the halved frame automatically
    from repro.sim.links import measure_payload
    _, wire16 = measure_payload({"packed": p16})
    _, wire32 = measure_payload({"packed": p32})
    assert wire32 - wire16 == 2 * nnz


def test_dispfl_fp16_requires_packed():
    with pytest.raises(ValueError, match="packed=True"):
        make_strategy("dispfl", packed=False, payload_dtype="fp16")
    with pytest.raises(ValueError, match="fp32|fp16"):
        make_strategy("dispfl", payload_dtype="bf16")
