# Repo verification entry points (see ROADMAP.md "Tier-1 verify").
#
#   make verify   - full test suite + a smoke run of the training launcher
#   make tier1    - only the tier1-marked fast core tests
#   make test     - full test suite

PY := PYTHONPATH=src python

.PHONY: verify test tier1 smoke

verify: test smoke

test:
	$(PY) -m pytest -x -q

tier1:
	$(PY) -m pytest -x -q -m tier1

smoke:
	$(PY) -m repro.launch.train simulate --strategy dispfl --rounds 2 \
	    --clients 4 --local-epochs 1 --samples-per-class 20 --eval-every 2
