# Repo verification entry points (see ROADMAP.md "Tier-1 verify").
#
#   make verify      - full test suite + smoke runs of the launchers
#   make tier1       - tier1-marked fast core tests (excludes `slow`; the
#                      CI fast job runs this + codec-smoke)
#   make test        - full test suite (includes slow golden tests)
#   make sim-smoke   - event-driven async network simulator smoke run
#                      (lossy links + shared FIFO uplink + retransmits)
#   make scale-smoke - ScaleEngine smoke: the whole round as one jitted
#                      stacked program, K=8 sharded over 4 host devices
#   make codec-smoke - packed payload codec/gossip benchmark (bytes vs density)
#   make serve-smoke - multi-tenant serving smoke: packed store + slot-pool
#                      cache + batched masked-matmul launches over the CLI
#   make bench-gate  - benchmark regression gate: fresh codec/vmap/sim rows
#                      vs benchmarks/baselines/*.json (CI full job; refresh
#                      deliberately with `python -m benchmarks.check_regression
#                      --update`)
#   make obs-smoke   - observability smoke: a traced sim run writes a run
#                      archive, the dashboard renders from it, and --check
#                      reconciles the page's rollups against the archived
#                      counters exactly

PY := PYTHONPATH=src python

.PHONY: verify test tier1 smoke sim-smoke scale-smoke codec-smoke \
	serve-smoke bench-gate obs-smoke

verify: test smoke sim-smoke scale-smoke codec-smoke serve-smoke obs-smoke

test:
	$(PY) -m pytest -x -q

tier1:
	$(PY) -m pytest -x -q -m "tier1 and not slow"

smoke:
	$(PY) -m repro.launch.train simulate --strategy dispfl --rounds 2 \
	    --clients 4 --local-epochs 1 --samples-per-class 20 --eval-every 2

sim-smoke:
	$(PY) -m repro.launch.train simulate --sim --async --strategy dispfl \
	    --rounds 3 --clients 4 --local-epochs 1 --samples-per-class 20 \
	    --eval-every 3 --staleness 2 --compute-hetero --bandwidth-skew 10 \
	    --uplink-mode fifo --loss-prob 0.1 --retransmit-timeout 0.3

scale-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PY) -m repro.launch.train simulate --scale --mesh-shape 4x1 \
	    --strategy dispfl --rounds 2 --clients 8 --batch-size 8 \
	    --local-epochs 1 --samples-per-class 20 --eval-every 2

codec-smoke:
	$(PY) -m benchmarks.run --only sparse_codec

serve-smoke:
	$(PY) -m repro.launch.serve --users 16 --cache-size 8 --max-batch 8 \
	    --requests 64 --backend ref --model mlp --density 0.3

bench-gate:
	$(PY) -m benchmarks.check_regression --out BENCH_latest.json --attribute

obs-smoke:
	rm -rf /tmp/repro_obs_smoke
	$(PY) -m repro.launch.train simulate --sim --strategy dispfl_anneal \
	    --rounds 2 --clients 4 --local-epochs 1 --samples-per-class 20 \
	    --eval-every 2 --loss-prob 0.1 --uplink-mode fair \
	    --run-dir /tmp/repro_obs_smoke/run --trace-mode full
	$(PY) -m repro.launch.dash render --run-dir /tmp/repro_obs_smoke/run \
	    -o /tmp/repro_obs_smoke/dash.html --check
