# Repo verification entry points (see ROADMAP.md "Tier-1 verify").
#
#   make verify      - full test suite + smoke runs of the launchers
#   make tier1       - only the tier1-marked fast core tests
#   make test        - full test suite
#   make sim-smoke   - event-driven async network simulator smoke run
#   make codec-smoke - packed payload codec/gossip benchmark (bytes vs density)

PY := PYTHONPATH=src python

.PHONY: verify test tier1 smoke sim-smoke codec-smoke

verify: test smoke sim-smoke codec-smoke

test:
	$(PY) -m pytest -x -q

tier1:
	$(PY) -m pytest -x -q -m tier1

smoke:
	$(PY) -m repro.launch.train simulate --strategy dispfl --rounds 2 \
	    --clients 4 --local-epochs 1 --samples-per-class 20 --eval-every 2

sim-smoke:
	$(PY) -m repro.launch.train simulate --sim --async --strategy dispfl \
	    --rounds 3 --clients 4 --local-epochs 1 --samples-per-class 20 \
	    --eval-every 3 --staleness 2 --compute-hetero --bandwidth-skew 10

codec-smoke:
	$(PY) -m benchmarks.run --only sparse_codec
